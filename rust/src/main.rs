//! `rsds` — command-line launcher for the RSDS reproduction.
//!
//! Subcommands:
//! - `server`   — run the central server (RSDS, or the Dask-emulation baseline)
//! - `worker`   — run a real worker against a server
//! - `zero-worker` — run the paper's idealized worker (§IV-D)
//! - `submit`   — submit a benchmark graph as a client and print the result
//! - `sim`      — run a benchmark in the discrete-event simulator
//! - `suite`    — print Table I for the generated benchmark suite

use anyhow::{anyhow, bail, Context, Result};
use rsds::graphgen;
use rsds::metrics::Measurement;
use rsds::overhead::RuntimeProfile;
use rsds::server::{serve, ServerConfig};
use rsds::sim::{simulate, SimConfig};
use rsds::taskgraph::GraphStats;
use rsds::util::cli::Args;
use rsds::worker::{run_worker, zero::run_zero_worker, WorkerConfig};

const USAGE: &str = "\
rsds — reproduction of 'Runtime vs Scheduler: Analyzing Dask's Overheads'

USAGE:
  rsds server  [--addr 127.0.0.1:8786] [--scheduler ws|random|dask-ws]
               [--profile rsds|dask] [--emulate-python] [--seed N]
               [--fairness rr|arrival|weighted] [--max-runs-per-client N]
               [--max-recoveries N] [--shards N] [--replication K]
               [--replication-fanout N]
  rsds worker  --server ADDR [--ncores 1] [--node 0] [--name w0] [--count N]
               [--memory-limit BYTES]
  rsds zero-worker --server ADDR [--count N]
  rsds submit  --server ADDR --graph SPEC  (e.g. merge-10000, xarray-25)
  rsds sim     --graph SPEC [--workers 24] [--scheduler ws] [--profile rsds]
               [--zero-worker] [--seed N] [--timeout-s 300]
               [--fairness rr|arrival|weighted] [--replication K]
  rsds suite   (prints generated-vs-paper Table I)
";

fn main() {
    env_logger_lite();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal env_logger substitute: honour RSDS_LOG=debug|info|warn.
fn env_logger_lite() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let level = match std::env::var("RSDS_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("info") => log::LevelFilter::Info,
        _ => log::LevelFilter::Warn,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

fn run() -> Result<()> {
    let args = Args::from_env(&[
        "addr", "scheduler", "profile", "seed", "server", "ncores", "node", "name", "count",
        "graph", "workers", "timeout-s", "workers-per-node", "fairness",
        "max-runs-per-client", "max-recoveries", "shards", "replication",
        "replication-fanout", "memory-limit",
    ])?;
    match args.subcommand() {
        Some("server") => cmd_server(&args),
        Some("worker") => cmd_worker(&args, false),
        Some("zero-worker") => cmd_worker(&args, true),
        Some("submit") => cmd_submit(&args),
        Some("sim") => cmd_sim(&args),
        Some("suite") => cmd_suite(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn profile_arg(args: &Args) -> Result<RuntimeProfile> {
    let name = args.get("profile").unwrap_or("rsds");
    RuntimeProfile::by_name(name).ok_or_else(|| anyhow!("unknown profile {name:?}"))
}

fn cmd_server(args: &Args) -> Result<()> {
    let config = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:8786").to_string(),
        scheduler: args.get("scheduler").unwrap_or("ws").to_string(),
        seed: args.get_parsed_or("seed", 2020u64)?,
        profile: profile_arg(args)?,
        emulate: args.flag("emulate-python"),
        fairness: args.get("fairness").unwrap_or("rr").to_string(),
        max_live_runs_per_client: args.get_parsed_or(
            "max-runs-per-client",
            rsds::server::DEFAULT_MAX_LIVE_RUNS_PER_CLIENT,
        )?,
        max_recoveries: args.get_parsed_or(
            "max-recoveries",
            rsds::server::DEFAULT_MAX_RECOVERIES,
        )?,
        shards: args.get_parsed_or("shards", ServerConfig::default().shards)?,
        replication: args.get_parsed_or("replication", 1usize)?,
        replication_fanout: args.get_parsed_or(
            "replication-fanout",
            rsds::server::DEFAULT_REPLICATION_FANOUT,
        )?,
        ..ServerConfig::default()
    };
    if config.replication == 0 {
        bail!("--replication counts the primary copy; minimum is 1");
    }
    let emulate = config.emulate;
    let scheduler = config.scheduler.clone();
    let fairness = config.fairness.clone();
    let shards = config.shards;
    let handle = serve(config)?;
    println!(
        "rsds server listening on {} (scheduler={scheduler}, fairness={fairness}, \
         shards={shards}, emulate-python={emulate})",
        handle.addr
    );
    // Run until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_worker(args: &Args, zero: bool) -> Result<()> {
    let server = args.require("server")?.to_string();
    let count: u32 = args.get_parsed_or("count", 1u32)?;
    let base = args.get("name").unwrap_or(if zero { "zero" } else { "worker" });
    let mut handles = Vec::new();
    for i in 0..count {
        let cfg = WorkerConfig {
            server_addr: server.clone(),
            name: format!("{base}-{i}"),
            ncores: args.get_parsed_or("ncores", 1u32)?,
            node: args.get_parsed_or("node", 0u32)?,
            memory_limit: match args.get("memory-limit") {
                Some(s) => Some(s.parse().context("parse --memory-limit (bytes)")?),
                None => None,
            },
            data_plane: Default::default(),
        };
        if zero {
            let h = run_zero_worker(cfg)?;
            println!("zero worker {} registered", h.id);
        } else {
            let h = run_worker(cfg)?;
            println!("worker {} registered (data {})", h.id, h.data_addr);
            handles.push(h);
        }
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_submit(args: &Args) -> Result<()> {
    let server = args.require("server")?;
    let spec = args.require("graph")?;
    let graph = graphgen::parse(spec)?;
    let stats = GraphStats::of(&graph);
    println!("submitting {} ({} tasks, {} deps)", graph.name, stats.n_tasks, stats.n_deps);
    let mut client = rsds::client::Client::connect(server, "rsds-cli")?;
    let result = client.run_graph(&graph)?;
    println!(
        "done: makespan {:.3} s  ({:.1} µs/task, client wall {:.3} s)",
        result.makespan_us as f64 / 1e6,
        result.makespan_us as f64 / result.n_tasks as f64,
        result.wall_us as f64 / 1e6,
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let spec = args.require("graph")?;
    let graph = graphgen::parse(spec)?;
    let profile = profile_arg(args)?;
    let scheduler = args.get("scheduler").unwrap_or("ws").to_string();
    let cfg = SimConfig {
        n_workers: args.get_parsed_or("workers", 24usize)?,
        workers_per_node: args.get_parsed_or("workers-per-node", 24usize)?,
        profile,
        scheduler,
        seed: args.get_parsed_or("seed", 2020u64)?,
        zero_worker: args.flag("zero-worker"),
        timeout_us: args.get_parsed_or("timeout-s", 300f64)? * 1e6,
        fairness: args.get("fairness").unwrap_or("rr").to_string(),
        replication: args.get_parsed_or("replication", 1usize)?,
        ..SimConfig::default()
    };
    if cfg.n_workers == 0 {
        bail!("--workers must be positive");
    }
    let r = simulate(&graph, &cfg);
    let m = Measurement {
        benchmark: graph.name.clone(),
        server: cfg.profile.name.to_string(),
        scheduler: cfg.scheduler.clone(),
        n_workers: cfg.n_workers,
        n_nodes: cfg.n_workers.div_ceil(cfg.workers_per_node),
        makespan_us: r.makespan_us,
        reps: 1,
        aot_us: r.aot_us,
    };
    rsds::metrics::print_series(&format!("sim {}", graph.name), &[m]);
    println!(
        "msgs={} steals={}/{} transferred={} timed_out={}",
        r.msgs,
        r.steals_failed,
        r.steals_attempted,
        rsds::util::stats::fmt_bytes(r.bytes_transferred),
        r.timed_out
    );
    Ok(())
}

fn cmd_suite() -> Result<()> {
    println!(
        "{:<28} {:>8} {:>8} {:>10} {:>10} {:>4}   (paper: #T #I S AD LP)",
        "benchmark", "#T", "#I", "S[KiB]", "AD[ms]", "LP"
    );
    for entry in graphgen::paper_suite() {
        let stats = GraphStats::of(&entry.graph());
        println!(
            "{}   [{} {} {} {} {}]",
            stats.row(entry.name),
            entry.paper.n_tasks,
            entry.paper.n_deps,
            entry.paper.avg_output_kib,
            entry.paper.avg_duration_ms,
            entry.paper.longest_path
        );
    }
    Ok(())
}
