//! The random scheduler (§III-E): "eagerly assigns each task to a random
//! worker using a uniform random distribution", maintains no task-graph
//! state, never steals. Mirrors both the Dask-side and RSDS-side random
//! scheduler of the paper; its per-task cost is constant in the worker
//! count — which is exactly why it ages well on large clusters (§VI-A).

use super::{Action, Assignment, SchedCost, Scheduler, WorkerId, WorkerInfo};
use crate::overhead::SchedKind;
use crate::taskgraph::{TaskGraph, TaskId};
use crate::util::Rng;

pub struct RandomScheduler {
    rng: Rng,
    workers: Vec<WorkerInfo>,
    /// Per-task core widths copied from the graph — the one sliver of
    /// graph state random keeps, needed so a uniform draw never lands a
    /// multi-core task on a worker too narrow to ever start it.
    task_cores: Vec<u32>,
    cost: SchedCost,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        RandomScheduler {
            rng: Rng::new(seed),
            workers: Vec::new(),
            task_cores: Vec::new(),
            cost: SchedCost::default(),
        }
    }

    fn copy_cores(&mut self, graph: &TaskGraph) {
        self.task_cores = graph.tasks().iter().map(|t| t.cores).collect();
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn kind(&self) -> SchedKind {
        SchedKind::Random
    }

    fn add_worker(&mut self, info: WorkerInfo) {
        self.workers.push(info);
    }

    fn remove_worker(&mut self, worker: WorkerId) {
        self.workers.retain(|w| w.id != worker);
    }

    fn graph_submitted(&mut self, graph: &TaskGraph) {
        // Deliberately (nearly) stateless (§IV-C: "does not maintain any
        // task graph state") — only the core widths are copied, because a
        // draw must be uniform over workers that *can* run the task.
        self.copy_cores(graph);
    }

    fn graph_extended(&mut self, graph: &TaskGraph) {
        self.copy_cores(graph);
    }

    fn tasks_ready(&mut self, tasks: &[TaskId], out: &mut Vec<Action>) {
        assert!(!self.workers.is_empty(), "no workers registered");
        for &t in tasks {
            let cores = self.task_cores.get(t.idx()).copied().unwrap_or(1);
            let eligible: Vec<WorkerId> =
                self.workers.iter().filter(|i| i.ncores >= cores).map(|i| i.id).collect();
            assert!(!eligible.is_empty(), "no registered worker has enough cores");
            let w = *self.rng.choose(&eligible);
            self.cost.decisions += 1;
            out.push(Action::Assign(Assignment { task: t, worker: w, priority: t.0 as i64 }));
        }
    }

    fn task_finished(
        &mut self,
        _task: TaskId,
        _worker: WorkerId,
        _nbytes: u64,
        _duration_us: u64,
        _out: &mut Vec<Action>,
    ) {
    }

    fn steal_result(
        &mut self,
        _task: TaskId,
        _from: WorkerId,
        _to: WorkerId,
        _success: bool,
        _out: &mut Vec<Action>,
    ) {
        unreachable!("random scheduler never emits steals");
    }

    fn take_cost(&mut self) -> SchedCost {
        std::mem::take(&mut self.cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::merge;

    fn workers(s: &mut RandomScheduler, n: u32) {
        for i in 0..n {
            s.add_worker(WorkerInfo { id: WorkerId(i), ncores: 1, node: i / 24 });
        }
    }

    #[test]
    fn assigns_every_task_exactly_once() {
        let mut s = RandomScheduler::new(42);
        workers(&mut s, 8);
        let g = merge(500);
        s.graph_submitted(&g);
        let ready: Vec<TaskId> = g.roots();
        let mut out = Vec::new();
        s.tasks_ready(&ready, &mut out);
        assert_eq!(out.len(), 500);
        let mut seen = std::collections::HashSet::new();
        for a in &out {
            match a {
                Action::Assign(a) => assert!(seen.insert(a.task)),
                _ => panic!("random never steals"),
            }
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut s = RandomScheduler::new(7);
        workers(&mut s, 4);
        let g = merge(4000);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        let mut counts = [0usize; 4];
        for a in &out {
            if let Action::Assign(a) = a {
                counts[a.worker.idx()] += 1;
            }
        }
        for c in counts {
            assert!((800..=1200).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn cost_is_one_decision_per_task_no_scans() {
        let mut s = RandomScheduler::new(1);
        workers(&mut s, 100);
        let g = merge(50);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        let c = s.take_cost();
        assert_eq!(c.decisions, 50);
        assert_eq!(c.workers_scanned, 0);
        assert_eq!(c.steal_cycles, 0);
        assert_eq!(s.take_cost(), SchedCost::default());
    }

    #[test]
    fn removed_worker_never_chosen_again() {
        let mut s = RandomScheduler::new(9);
        workers(&mut s, 4);
        s.remove_worker(WorkerId(2));
        let g = merge(200);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        for a in &out {
            if let Action::Assign(a) = a {
                assert_ne!(a.worker, WorkerId(2));
            }
        }
    }

    #[test]
    fn multicore_tasks_only_land_on_wide_workers() {
        use crate::taskgraph::{GraphBuilder, Payload};
        let mut s = RandomScheduler::new(3);
        s.add_worker(WorkerInfo { id: WorkerId(0), ncores: 1, node: 0 });
        s.add_worker(WorkerInfo { id: WorkerId(1), ncores: 4, node: 0 });
        s.add_worker(WorkerInfo { id: WorkerId(2), ncores: 2, node: 0 });
        let mut b = GraphBuilder::new();
        for i in 0..50 {
            b.add_with_cores(format!("t{i}"), vec![], 10, 1, Payload::NoOp, 2);
        }
        let g = b.build("g").unwrap();
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        assert_eq!(out.len(), 50);
        let mut hit_wide = [false; 3];
        for a in &out {
            if let Action::Assign(a) = a {
                assert_ne!(a.worker, WorkerId(0), "1-core worker can't run 2-core tasks");
                hit_wide[a.worker.idx()] = true;
            }
        }
        assert!(hit_wide[1] && hit_wide[2], "uniform over the eligible pair");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut s = RandomScheduler::new(seed);
            workers(&mut s, 8);
            let g = merge(100);
            s.graph_submitted(&g);
            let mut out = Vec::new();
            s.tasks_ready(&g.roots(), &mut out);
            out
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
