//! Emulation of Dask's work-stealing scheduler (§III-D).
//!
//! "When a task becomes ready ... it is immediately assigned to a worker
//! according to a heuristic that tries to minimize an estimated start time
//! of the task. The estimate is based on potential data transfers and the
//! current occupancy of workers. When an imbalance occurs ... the scheduler
//! tries to steal tasks from overloaded nodes."
//!
//! Faithful to the *algorithmic shape* that matters for the paper's
//! analysis: the placement scan touches **every worker** (cost grows with
//! cluster size — §VI-A), uses occupancy from *duration estimates learned
//! per task-key prefix* (like Dask's `TaskPrefix` averages) and a network
//! bandwidth estimate for transfer times, and performs periodic steal
//! balancing between saturated and idle workers.

use super::{Action, Assignment, ClusterModel, SchedCost, Scheduler, WorkerId, WorkerInfo};
use crate::overhead::SchedKind;
use crate::taskgraph::{TaskGraph, TaskId};
use std::collections::{HashMap, HashSet};

/// Dask's default bandwidth estimate (100 MB/s) in bytes/µs.
const BANDWIDTH_BYTES_PER_US: f64 = 100.0;
/// Latency estimate per remote fetch, µs.
const FETCH_LATENCY_US: f64 = 100.0;
/// Default duration estimate before any observation (Dask: 0.5 s).
const DEFAULT_DURATION_US: f64 = 500_000.0;

/// Running mean of observed durations per task-key prefix (Dask's
/// `TaskPrefix.duration_average`).
#[derive(Debug, Default)]
struct DurationEstimator {
    by_prefix: HashMap<String, (f64, u64)>,
}

impl DurationEstimator {
    fn prefix(key: &str) -> &str {
        key.split('-').next().unwrap_or(key)
    }

    fn observe(&mut self, key: &str, duration_us: u64) {
        let e = self.by_prefix.entry(Self::prefix(key).to_string()).or_insert((0.0, 0));
        e.1 += 1;
        // Exponential moving average, like Dask's.
        let alpha = if e.1 == 1 { 1.0 } else { 0.5 };
        e.0 = e.0 * (1.0 - alpha) + duration_us as f64 * alpha;
    }

    fn estimate(&self, key: &str) -> f64 {
        self.by_prefix
            .get(Self::prefix(key))
            .map(|(avg, _)| *avg)
            .unwrap_or(DEFAULT_DURATION_US)
    }
}

pub struct DaskWsScheduler {
    model: ClusterModel,
    durations: DurationEstimator,
    /// Occupancy in *estimated* µs (distinct from the model's exact one —
    /// Dask only has estimates).
    est_occupancy_us: Vec<f64>,
    in_flight_steals: HashSet<TaskId>,
    cost: SchedCost,
}

impl DaskWsScheduler {
    pub fn new() -> Self {
        DaskWsScheduler {
            model: ClusterModel::new(),
            durations: DurationEstimator::default(),
            est_occupancy_us: Vec::new(),
            in_flight_steals: HashSet::new(),
            cost: SchedCost::default(),
        }
    }

    fn ensure_occ(&mut self, idx: usize) {
        if self.est_occupancy_us.len() <= idx {
            self.est_occupancy_us.resize(idx + 1, 0.0);
        }
    }

    /// Earliest-estimated-start-time placement: scans ALL workers (with
    /// enough core slots for the task — a narrower worker can never start
    /// it, whatever its occupancy says).
    fn place(&mut self, task: TaskId) -> WorkerId {
        let cores = self.model.graph().task(task).cores;
        let ids: Vec<WorkerId> =
            self.model.worker_ids().filter(|&w| self.model.can_fit(w, cores)).collect();
        assert!(!ids.is_empty(), "no registered worker has enough cores");
        self.cost.decisions += 1;
        self.cost.workers_scanned += ids.len() as u64;
        let mut best = ids[0];
        let mut best_est = f64::INFINITY;
        for &w in &ids {
            let transfer_bytes = self.model.transfer_cost(task, w) as f64;
            let n_missing = if transfer_bytes > 0.0 { 1.0 } else { 0.0 };
            let transfer_us =
                transfer_bytes / BANDWIDTH_BYTES_PER_US + n_missing * FETCH_LATENCY_US;
            let est = self.est_occupancy_us[w.idx()] + transfer_us;
            if est < best_est {
                best_est = est;
                best = w;
            }
        }
        best
    }

    /// Steal balancing: move queued tasks from workers whose estimated
    /// occupancy far exceeds the average to idle ones.
    fn balance(&mut self, out: &mut Vec<Action>) {
        self.cost.steal_cycles += 1;
        let ids: Vec<WorkerId> = self.model.worker_ids().collect();
        // Occupancy scan over the whole cluster (like Dask's stealing pass).
        self.cost.workers_scanned += ids.len() as u64;
        if ids.len() < 2 {
            return;
        }
        let avg: f64 =
            ids.iter().map(|w| self.est_occupancy_us[w.idx()]).sum::<f64>() / ids.len() as f64;
        loop {
            let idle = ids
                .iter()
                .copied()
                .filter(|w| self.model.workers[w.idx()].queued.is_empty())
                .min_by(|a, b| {
                    self.est_occupancy_us[a.idx()].total_cmp(&self.est_occupancy_us[b.idx()])
                });
            let Some(idle) = idle else { return };
            let sat = ids
                .iter()
                .copied()
                .filter(|w| {
                    self.model.workers[w.idx()].queued.len() >= 2
                        && self.est_occupancy_us[w.idx()] > avg.max(1.0)
                })
                .max_by(|a, b| {
                    self.est_occupancy_us[a.idx()].total_cmp(&self.est_occupancy_us[b.idx()])
                });
            let Some(sat) = sat else { return };
            let victim = self.model.workers[sat.idx()]
                .queued
                .iter()
                .filter(|t| !self.in_flight_steals.contains(t))
                .filter(|&&t| self.model.can_fit(idle, self.model.graph().task(t).cores))
                .max_by_key(|t| t.0)
                .copied();
            let Some(task) = victim else { return };
            let dur = self.durations.estimate(&self.model.graph().task(task).key);
            if !self.model.move_task(task, sat, idle) {
                return; // raced with a finish
            }
            self.in_flight_steals.insert(task);
            self.ensure_occ(sat.idx().max(idle.idx()));
            self.est_occupancy_us[sat.idx()] = (self.est_occupancy_us[sat.idx()] - dur).max(0.0);
            self.est_occupancy_us[idle.idx()] += dur;
            out.push(Action::Steal { task, from: sat, to: idle });
        }
    }
}

impl Default for DaskWsScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for DaskWsScheduler {
    fn name(&self) -> &'static str {
        "dask-ws"
    }

    fn kind(&self) -> SchedKind {
        SchedKind::WorkStealing
    }

    fn add_worker(&mut self, info: WorkerInfo) {
        self.model.add_worker(info);
        self.ensure_occ(info.id.idx());
    }

    fn remove_worker(&mut self, worker: WorkerId) {
        self.model.remove_worker(worker);
        if let Some(occ) = self.est_occupancy_us.get_mut(worker.idx()) {
            *occ = 0.0;
        }
    }

    fn task_lost(&mut self, task: TaskId, worker: WorkerId) {
        let dur = self.durations.estimate(&self.model.graph().task(task).key);
        self.model.forget_task(task);
        self.in_flight_steals.remove(&task);
        // Estimated occupancy is a heuristic; if an optimistic steal moved
        // the estimate to another worker this drifts slightly — acceptable,
        // it is reset on the next graph.
        if let Some(occ) = self.est_occupancy_us.get_mut(worker.idx()) {
            *occ = (*occ - dur).max(0.0);
        }
    }

    fn graph_submitted(&mut self, graph: &TaskGraph) {
        self.model.set_graph(graph);
        self.in_flight_steals.clear();
        for occ in &mut self.est_occupancy_us {
            *occ = 0.0;
        }
    }

    fn graph_extended(&mut self, graph: &TaskGraph) {
        // Ids are stable across extensions: queues, placement, learned
        // duration averages and estimated occupancy all stay valid.
        self.model.extend_graph(graph);
    }

    fn tasks_ready(&mut self, tasks: &[TaskId], out: &mut Vec<Action>) {
        for &t in tasks {
            let w = self.place(t);
            let dur = self.durations.estimate(&self.model.graph().task(t).key);
            self.model.assign(t, w);
            self.ensure_occ(w.idx());
            self.est_occupancy_us[w.idx()] += dur;
            out.push(Action::Assign(Assignment { task: t, worker: w, priority: t.0 as i64 }));
        }
        self.balance(out);
    }

    fn task_finished(
        &mut self,
        task: TaskId,
        worker: WorkerId,
        _nbytes: u64,
        duration_us: u64,
        out: &mut Vec<Action>,
    ) {
        // Disjoint field borrows: the key stays borrowed from the graph
        // (`model`) while the duration table (`durations`) mutates — no
        // per-finish clone on this path.
        let key = &self.model.graph().task(task).key;
        let est = self.durations.estimate(key);
        self.durations.observe(key, duration_us);
        self.model.finish(task, worker);
        self.ensure_occ(worker.idx());
        self.est_occupancy_us[worker.idx()] =
            (self.est_occupancy_us[worker.idx()] - est).max(0.0);
        self.balance(out);
    }

    fn steal_result(
        &mut self,
        task: TaskId,
        from: WorkerId,
        to: WorkerId,
        success: bool,
        out: &mut Vec<Action>,
    ) {
        self.in_flight_steals.remove(&task);
        if !success {
            let dur = self.durations.estimate(&self.model.graph().task(task).key);
            // No-op if the task finished while the retraction was in flight.
            if self.model.move_task(task, to, from) {
                self.est_occupancy_us[to.idx()] = (self.est_occupancy_us[to.idx()] - dur).max(0.0);
                self.est_occupancy_us[from.idx()] += dur;
            }
            self.balance(out);
        }
    }

    fn take_cost(&mut self) -> SchedCost {
        std::mem::take(&mut self.cost)
    }

    fn queued_tasks(&self) -> Option<Vec<(WorkerId, Vec<TaskId>)>> {
        Some(self.model.queued_snapshot())
    }

    fn in_flight_steal_count(&self) -> usize {
        self.in_flight_steals.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::merge;
    use crate::taskgraph::{GraphBuilder, Payload};

    fn sched(n: u32) -> DaskWsScheduler {
        let mut s = DaskWsScheduler::new();
        for i in 0..n {
            // One worker per node: remote transfers are at full price, which
            // is the regime where EST placement piles consumers onto the
            // data holder and stealing has to kick in.
            s.add_worker(WorkerInfo { id: WorkerId(i), ncores: 1, node: i });
        }
        s
    }

    fn assignments(out: &[Action]) -> Vec<Assignment> {
        out.iter()
            .filter_map(|a| match a {
                Action::Assign(a) => Some(*a),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn scan_cost_proportional_to_cluster_size() {
        for n in [4u32, 64] {
            let mut s = sched(n);
            let g = merge(10);
            s.graph_submitted(&g);
            let mut out = Vec::new();
            s.tasks_ready(&g.roots(), &mut out);
            let c = s.take_cost();
            assert_eq!(c.decisions, 10);
            // 10 placement scans over all workers, plus ≥1 balance scan.
            assert!(c.workers_scanned >= 10 * n as u64, "dask scans all workers");
            assert!(c.workers_scanned <= (10 + c.steal_cycles) * n as u64);
        }
    }

    #[test]
    fn occupancy_spreads_independent_tasks() {
        // With equal (default) duration estimates, EST placement must
        // spread independent tasks across workers instead of piling up.
        let mut s = sched(4);
        let g = merge(16);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        let mut counts = [0usize; 4];
        for a in assignments(&out) {
            counts[a.worker.idx()] += 1;
        }
        for c in counts {
            assert_eq!(c, 4, "EST heuristic balances equal tasks: {counts:?}");
        }
    }

    #[test]
    fn duration_estimates_learn_from_observations() {
        let mut d = DurationEstimator::default();
        assert_eq!(d.estimate("task-5"), DEFAULT_DURATION_US);
        d.observe("task-1", 1000);
        assert!((d.estimate("task-9") - 1000.0).abs() < 1e-9, "prefix sharing");
        d.observe("task-2", 3000);
        let e = d.estimate("task-0");
        assert!(e > 1000.0 && e < 3000.0, "EMA between observations: {e}");
    }

    #[test]
    fn transfer_estimate_influences_placement() {
        // Big output on w0; consumer should go to w0 despite equal occupancy.
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 10, 50_000_000, Payload::NoOp);
        let c = b.add("c", vec![a], 10, 1, Payload::MergeInputs);
        let g = b.build("g").unwrap();
        let mut s = sched(4);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[a], &mut out);
        let w = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(a, w, 50_000_000, 10, &mut out);
        out.clear();
        s.tasks_ready(&[c], &mut out);
        assert_eq!(assignments(&out)[0].worker, w);
    }

    #[test]
    fn steals_to_idle_workers() {
        // All tasks depend on data at w0, so EST places them all on w0
        // (transfer dominates); balance must then steal for idle workers.
        let mut b = GraphBuilder::new();
        // Output so large that the transfer estimate dwarfs any occupancy:
        // EST pins every consumer to the data holder, forcing steals.
        let root = b.add("root", vec![], 10, 10_000_000_000, Payload::NoOp);
        let mids: Vec<TaskId> = (0..8)
            .map(|i| b.add(format!("m-{i}"), vec![root], 1_000_000, 10, Payload::BusyWait))
            .collect();
        let g = b.build("g").unwrap();
        let mut s = sched(4);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[root], &mut out);
        let w = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(root, w, 100_000_000, 10, &mut out);
        out.clear();
        s.tasks_ready(&mids, &mut out);
        let steals = out.iter().filter(|a| matches!(a, Action::Steal { .. })).count();
        assert!(steals > 0, "expected steals towards idle workers");
    }

    #[test]
    fn multicore_task_skips_narrow_workers() {
        // EST would pick the data holder; capacity excludes it from the
        // scan entirely.
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 10, 50_000_000, Payload::NoOp);
        let wide = b.add_with_cores("wide", vec![a], 10, 1, Payload::MergeInputs, 2);
        let g = b.build("g").unwrap();
        let mut s = DaskWsScheduler::new();
        s.add_worker(WorkerInfo { id: WorkerId(0), ncores: 1, node: 0 });
        s.add_worker(WorkerInfo { id: WorkerId(1), ncores: 2, node: 1 });
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[a], &mut out);
        let wa = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(a, wa, 50_000_000, 10, &mut out);
        out.clear();
        s.tasks_ready(&[wide], &mut out);
        assert_eq!(assignments(&out)[0].worker, WorkerId(1), "only the wide worker fits");
    }

    #[test]
    fn extension_preserves_estimates_and_placement() {
        use crate::taskgraph::TaskSpec;
        let mut s = sched(2);
        let mut b = GraphBuilder::new();
        let a = b.add("x-1", vec![], 10, 50_000_000, Payload::NoOp);
        let g = b.build("g").unwrap();
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[a], &mut out);
        let w = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(a, w, 50_000_000, 1234, &mut out);
        let mut grown = g.clone();
        grown
            .extend(vec![TaskSpec {
                id: TaskId(1),
                key: "x-2".into(),
                inputs: vec![a],
                duration_us: 10,
                output_size: 1,
                payload: Payload::MergeInputs,
                cores: 1,
            }])
            .unwrap();
        s.graph_extended(&grown);
        out.clear();
        s.tasks_ready(&[TaskId(1)], &mut out);
        assert_eq!(assignments(&out)[0].worker, w, "big input pins the extension task");
        assert!(
            (s.durations.estimate("x-9") - 1234.0).abs() < 1e-9,
            "learned durations survive the extension"
        );
    }

    #[test]
    fn failed_steal_keeps_task_exactly_once_and_rebalances() {
        let mut s = sched(2);
        let mut b = GraphBuilder::new();
        let r = b.add("r", vec![], 10, 10_000_000_000, Payload::NoOp);
        let t1 = b.add("x-1", vec![r], 1000, 1, Payload::BusyWait);
        let t2 = b.add("x-2", vec![r], 1000, 1, Payload::BusyWait);
        let g = b.build("g").unwrap();
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[r], &mut out);
        let w = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(r, w, 10_000_000_000, 10, &mut out);
        out.clear();
        s.tasks_ready(&[t1, t2], &mut out);
        let steal = out.iter().find_map(|a| match a {
            Action::Steal { task, from, to } => Some((*task, *from, *to)),
            _ => None,
        });
        let (task, from, to) = steal.expect("EST pins both tasks to the holder ⇒ steal");
        let mut out2 = Vec::new();
        s.steal_result(task, from, to, false, &mut out2);
        // §IV-C: a failed retraction puts the task back and "initiates
        // balancing again if necessary" — the task must live in exactly one
        // queue afterwards (possibly with a fresh steal in flight).
        let queued_at: Vec<_> = s
            .model
            .workers
            .iter()
            .enumerate()
            .filter(|(_, ws)| ws.queued.contains(&task))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(queued_at.len(), 1, "task must be queued exactly once: {queued_at:?}");
        // Any follow-up action must again be a steal, already optimistically
        // moved to its destination queue in the model.
        for a in &out2 {
            match a {
                Action::Steal { task, to, .. } => {
                    assert!(s.model.workers[to.idx()].queued.contains(task))
                }
                Action::Assign(_) => panic!("failed steal must not re-assign"),
            }
        }
    }
}
