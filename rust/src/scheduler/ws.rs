//! RSDS's work-stealing scheduler (§IV-C).
//!
//! "When a task becomes ready ... it is immediately assigned to a worker.
//! The scheduler chooses a worker where the task may be executed with
//! minimal data transfer costs, while it deliberately ignores the load of
//! the worker." Under-load is fixed afterwards by *balancing*: stealing
//! from workers with a sufficient number of queued tasks to under-loaded
//! ones, with the reactor performing retraction and reporting failures
//! back. Deliberately simple: no duration estimates, no network-speed
//! estimates.

use super::{Action, Assignment, ClusterModel, SchedCost, Scheduler, WorkerId, WorkerInfo};
use crate::overhead::SchedKind;
use crate::taskgraph::{TaskGraph, TaskId};
use std::collections::HashSet;

/// A worker with fewer queued tasks than this is under-loaded.
const UNDERLOAD_THRESHOLD: usize = 1;
/// Only steal from workers with at least this many queued tasks.
const STEAL_MIN_QUEUE: usize = 2;

pub struct WsScheduler {
    model: ClusterModel,
    /// Tasks with an outstanding steal request (avoid double-stealing).
    in_flight_steals: HashSet<TaskId>,
    cost: SchedCost,
    /// Ablation knob: disable the balance/steal pass entirely (pure
    /// locality placement). Exercised by `benches/ablations.rs`.
    balance_enabled: bool,
    /// Ablation knob: invert priorities so workers pop the *most recently*
    /// submitted ready task first. Also exercises every execution-layer
    /// queue against priorities that differ from task ids.
    lifo: bool,
}

impl WsScheduler {
    pub fn new() -> Self {
        WsScheduler {
            model: ClusterModel::new(),
            in_flight_steals: HashSet::new(),
            cost: SchedCost::default(),
            balance_enabled: true,
            lifo: false,
        }
    }

    /// Locality-only variant without stealing (ablation baseline).
    pub fn without_balancing() -> Self {
        WsScheduler { balance_enabled: false, ..Self::new() }
    }

    /// LIFO-priority variant (newest ready task first).
    pub fn lifo() -> Self {
        WsScheduler { lifo: true, ..Self::new() }
    }

    fn priority(&self, task: TaskId) -> i64 {
        if self.lifo {
            -(task.0 as i64)
        } else {
            task.0 as i64
        }
    }

    /// Pick the worker with minimal transfer cost (§IV-C), scanning only
    /// candidate holders of inputs; falls back to round-robin for
    /// input-less tasks. Load is deliberately ignored, worker *capacity*
    /// is not: a worker with fewer cores than the task needs can never
    /// start it, so it is excluded before the cost scan.
    fn place(&mut self, task: TaskId) -> WorkerId {
        let cores = self.model.graph().task(task).cores;
        let mut candidates = self.model.candidate_workers(task);
        candidates.retain(|&w| self.model.can_fit(w, cores));
        self.cost.decisions += 1;
        if candidates.is_empty() {
            return self
                .model
                .next_round_robin_fitting(cores)
                .expect("no registered worker has enough cores");
        }
        self.cost.workers_scanned += candidates.len() as u64;
        let mut best = candidates[0];
        let mut best_cost = self.model.transfer_cost(task, best);
        for &w in &candidates[1..] {
            let c = self.model.transfer_cost(task, w);
            if c < best_cost {
                best = w;
                best_cost = c;
            }
        }
        best
    }

    /// Balance pass (§IV-C): if some worker is under-loaded, move queued
    /// tasks from loaded workers to it. Emits steal requests; the reactor
    /// retracts and reports back.
    fn balance(&mut self, out: &mut Vec<Action>) {
        if !self.balance_enabled {
            return;
        }
        self.cost.steal_cycles += 1;
        // The load scan touches every worker — this is what makes RSDS's
        // work-stealing overhead eventually grow with cluster size (§VI-D:
        // "in the case of RSDS, work-stealing overhead stays constant for
        // up to 100 workers, then it also starts to grow").
        self.cost.workers_scanned += self.model.n_workers() as u64;
        loop {
            let Some((hi, lo)) = self.model.load_extremes() else { return };
            let hi_q = self.model.workers[hi.idx()].queued_slots as usize;
            let lo_q = self.model.workers[lo.idx()].queued_slots as usize;
            if lo_q > UNDERLOAD_THRESHOLD || hi_q < STEAL_MIN_QUEUE || hi_q - lo_q < 2 {
                return;
            }
            // Steal the most recently queued (lowest-priority) task that is
            // not already being stolen and that the under-loaded worker has
            // the core capacity to run.
            let victim = self.model.workers[hi.idx()]
                .queued
                .iter()
                .filter(|t| !self.in_flight_steals.contains(t))
                .filter(|&&t| self.model.can_fit(lo, self.model.graph().task(t).cores))
                .max_by_key(|t| t.0)
                .copied();
            let Some(task) = victim else { return };
            // Optimistically move it in the model so the next iteration
            // sees updated loads; a failed retraction moves it back.
            if !self.model.move_task(task, hi, lo) {
                return; // raced with a finish; next event rebalances
            }
            self.in_flight_steals.insert(task);
            out.push(Action::Steal { task, from: hi, to: lo });
        }
    }
}

impl Default for WsScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for WsScheduler {
    fn name(&self) -> &'static str {
        "ws"
    }

    fn kind(&self) -> SchedKind {
        SchedKind::WorkStealing
    }

    fn add_worker(&mut self, info: WorkerInfo) {
        self.model.add_worker(info);
    }

    fn remove_worker(&mut self, worker: WorkerId) {
        self.model.remove_worker(worker);
    }

    fn task_lost(&mut self, task: TaskId, _worker: WorkerId) {
        // The model purge is worker-agnostic: an optimistic steal move may
        // have parked the task on a different worker than the reactor saw.
        self.model.forget_task(task);
        self.in_flight_steals.remove(&task);
    }

    fn graph_submitted(&mut self, graph: &TaskGraph) {
        self.model.set_graph(graph);
        self.in_flight_steals.clear();
    }

    fn graph_extended(&mut self, graph: &TaskGraph) {
        // Ids are stable across extensions: keep queues, placement and
        // in-flight steal bookkeeping, just learn the new tasks.
        self.model.extend_graph(graph);
    }

    fn tasks_ready(&mut self, tasks: &[TaskId], out: &mut Vec<Action>) {
        for &t in tasks {
            let w = self.place(t);
            self.model.assign(t, w);
            out.push(Action::Assign(Assignment { task: t, worker: w, priority: self.priority(t) }));
        }
        // "When a new task is scheduled ... the scheduler checks if there
        // are nodes that are under-loaded."
        self.balance(out);
    }

    fn task_finished(
        &mut self,
        task: TaskId,
        worker: WorkerId,
        _nbytes: u64,
        _duration_us: u64,
        out: &mut Vec<Action>,
    ) {
        self.model.finish(task, worker);
        self.balance(out);
    }

    fn steal_result(
        &mut self,
        task: TaskId,
        from: WorkerId,
        to: WorkerId,
        success: bool,
        out: &mut Vec<Action>,
    ) {
        self.in_flight_steals.remove(&task);
        if !success {
            // Retraction failed: the task is running/finished on `from`;
            // undo the optimistic move (no-op if it finished meanwhile) and
            // rebalance if still needed (§IV-C: "the scheduler is notified
            // and it then initiates balancing again if necessary").
            self.model.move_task(task, to, from);
            self.balance(out);
        }
    }

    fn take_cost(&mut self) -> SchedCost {
        std::mem::take(&mut self.cost)
    }

    fn queued_tasks(&self) -> Option<Vec<(WorkerId, Vec<TaskId>)>> {
        Some(self.model.queued_snapshot())
    }

    fn in_flight_steal_count(&self) -> usize {
        self.in_flight_steals.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{merge, tree};
    use crate::taskgraph::{GraphBuilder, Payload};

    fn sched(n_workers: u32, per_node: u32) -> WsScheduler {
        let mut s = WsScheduler::new();
        for i in 0..n_workers {
            s.add_worker(WorkerInfo { id: WorkerId(i), ncores: 1, node: i / per_node });
        }
        s
    }

    fn assignments(out: &[Action]) -> Vec<Assignment> {
        out.iter()
            .filter_map(|a| match a {
                Action::Assign(a) => Some(*a),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn prefers_data_locality() {
        // Graph: a -> c, b -> c with |a| >> |b|: c must go where a is.
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 10, 1_000_000, Payload::NoOp);
        let bb = b.add("b", vec![], 10, 10, Payload::NoOp);
        let c = b.add("c", vec![a, bb], 10, 1, Payload::MergeInputs);
        let g = b.build("g").unwrap();

        let mut s = sched(4, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[a, bb], &mut out);
        let asg = assignments(&out);
        let wa = asg.iter().find(|x| x.task == a).unwrap().worker;
        let wb = asg.iter().find(|x| x.task == bb).unwrap().worker;
        out.clear();
        s.task_finished(a, wa, 1_000_000, 10, &mut out);
        s.task_finished(bb, wb, 10, 10, &mut out);
        out.clear();
        s.tasks_ready(&[c], &mut out);
        let asg = assignments(&out);
        assert_eq!(asg[0].worker, wa, "c should be placed with the big input");
    }

    #[test]
    fn ignores_load_on_placement() {
        // One worker already holds all the data; ws places there even
        // though it is the most loaded (the paper's deliberate choice).
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 10, 1000, Payload::NoOp);
        let deps: Vec<TaskId> =
            (0..4).map(|i| b.add(format!("d{i}"), vec![a], 10, 1000, Payload::BusyWait)).collect();
        let g = b.build("g").unwrap();

        let mut s = sched(2, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[a], &mut out);
        let w = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(a, w, 1000, 10, &mut out);
        out.clear();
        s.tasks_ready(&deps, &mut out);
        // All four consumers initially placed on the data holder, but the
        // balance pass must have stolen some for the idle worker.
        let asg = assignments(&out);
        assert_eq!(asg.len(), 4);
        assert!(asg.iter().all(|x| x.worker == w));
        let steals: Vec<_> = out
            .iter()
            .filter(|a| matches!(a, Action::Steal { .. }))
            .collect();
        assert!(!steals.is_empty(), "balance must redistribute to the idle worker");
    }

    #[test]
    fn every_ready_task_assigned_exactly_once() {
        let g = tree(8);
        let mut s = sched(6, 3);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        let asg = assignments(&out);
        assert_eq!(asg.len(), g.roots().len());
        let unique: HashSet<TaskId> = asg.iter().map(|a| a.task).collect();
        assert_eq!(unique.len(), asg.len());
    }

    #[test]
    fn steal_failure_restores_model_and_rebalances() {
        let g = merge(10);
        let mut s = sched(2, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        let steals: Vec<(TaskId, WorkerId, WorkerId)> = out
            .iter()
            .filter_map(|a| match a {
                Action::Steal { task, from, to } => Some((*task, *from, *to)),
                _ => None,
            })
            .collect();
        // Round-robin should make the initial placement balanced; force a
        // state where a steal happened or skip.
        for (task, from, to) in steals {
            let before_from = s.model.workers[from.idx()].queued.len();
            let before_to = s.model.workers[to.idx()].queued.len();
            let mut out2 = Vec::new();
            s.steal_result(task, from, to, false, &mut out2);
            assert_eq!(s.model.workers[from.idx()].queued.len(), before_from + 1);
            assert_eq!(s.model.workers[to.idx()].queued.len(), before_to - 1);
        }
    }

    #[test]
    fn balance_moves_work_to_idle_workers() {
        // 20 independent tasks, no inputs ⇒ round-robin spreads them; then
        // all finish on w0 to create imbalance for successors.
        let mut b = GraphBuilder::new();
        let root = b.add("root", vec![], 10, 100, Payload::NoOp);
        let mids: Vec<TaskId> =
            (0..20).map(|i| b.add(format!("m{i}"), vec![root], 1000, 100, Payload::BusyWait)).collect();
        let g = b.build("g").unwrap();
        let mut s = sched(4, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[root], &mut out);
        let w = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(root, w, 100, 10, &mut out);
        out.clear();
        s.tasks_ready(&mids, &mut out);
        // RSDS's balance fixes *under-load*, not global imbalance (§IV-C):
        // after balancing, no worker may sit (nearly) idle while another
        // still has a deep queue.
        let loads: Vec<usize> = s.model.workers.iter().map(|w| w.queued.len()).collect();
        let max = *loads.iter().max().unwrap();
        let min = *loads.iter().min().unwrap();
        assert!(min >= 2 || max - min < 2, "under-loaded worker left: {loads:?}");
    }

    #[test]
    fn removed_worker_never_placed_and_lost_tasks_reassign() {
        let g = merge(12);
        let mut s = sched(3, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        // Kill w1: model forgets it, its tasks are reported lost and
        // re-offered — every re-placement must land on a survivor.
        let dead = WorkerId(1);
        let lost: Vec<TaskId> =
            s.model.workers[dead.idx()].queued.iter().copied().collect();
        s.remove_worker(dead);
        for &t in &lost {
            s.task_lost(t, dead);
        }
        out.clear();
        s.tasks_ready(&lost, &mut out);
        let asg = assignments(&out);
        assert_eq!(asg.len(), lost.len());
        assert!(asg.iter().all(|a| a.worker != dead), "{asg:?}");
        // Steal targets avoid the corpse too.
        for a in &out {
            if let Action::Steal { from, to, .. } = a {
                assert_ne!(*from, dead);
                assert_ne!(*to, dead);
            }
        }
    }

    #[test]
    fn task_lost_resolves_pending_steal_bookkeeping() {
        // A task lost while a steal was in flight must leave no ghost in
        // either the queue model or the in-flight set.
        let g = merge(10);
        let mut s = sched(2, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        let steal = out.iter().find_map(|a| match a {
            Action::Steal { task, from, .. } => Some((*task, *from)),
            _ => None,
        });
        if let Some((task, from)) = steal {
            s.task_lost(task, from);
            assert!(!s.in_flight_steals.contains(&task));
            for w in &s.model.workers {
                assert!(!w.queued.contains(&task));
            }
        }
    }

    #[test]
    fn multicore_task_skips_narrow_workers() {
        // Locality points at the 1-core data holder, capacity forbids it:
        // the 4-core task must land on the wide worker.
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 10, 1_000_000, Payload::NoOp);
        let wide = b.add_with_cores("wide", vec![a], 10, 1, Payload::MergeInputs, 4);
        let g = b.build("g").unwrap();
        let mut s = WsScheduler::new();
        s.add_worker(WorkerInfo { id: WorkerId(0), ncores: 1, node: 0 });
        s.add_worker(WorkerInfo { id: WorkerId(1), ncores: 4, node: 1 });
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[a], &mut out);
        let wa = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(a, wa, 1_000_000, 10, &mut out);
        out.clear();
        s.tasks_ready(&[wide], &mut out);
        assert_eq!(assignments(&out)[0].worker, WorkerId(1), "capacity beats locality");
        // And a balance pass must never steal it back to the narrow worker.
        for act in &out {
            if let Action::Steal { task, to, .. } = act {
                assert!(!(*task == wide && *to == WorkerId(0)));
            }
        }
    }

    #[test]
    fn extension_keeps_locality_against_resident_placement() {
        use crate::taskgraph::TaskSpec;
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 10, 1_000_000, Payload::NoOp);
        let g = b.build("g").unwrap();
        let mut s = sched(3, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&[a], &mut out);
        let w = assignments(&out)[0].worker;
        out.clear();
        s.task_finished(a, w, 1_000_000, 10, &mut out);
        let mut grown = g.clone();
        grown
            .extend(vec![TaskSpec {
                id: TaskId(1),
                key: "b".into(),
                inputs: vec![a],
                duration_us: 10,
                output_size: 1,
                payload: Payload::MergeInputs,
                cores: 1,
            }])
            .unwrap();
        s.graph_extended(&grown);
        out.clear();
        s.tasks_ready(&[TaskId(1)], &mut out);
        assert_eq!(assignments(&out)[0].worker, w, "locality survives the extension");
    }

    #[test]
    fn cost_counters_accumulate() {
        let g = merge(100);
        let mut s = sched(4, 24);
        s.graph_submitted(&g);
        let mut out = Vec::new();
        s.tasks_ready(&g.roots(), &mut out);
        let c = s.take_cost();
        assert_eq!(c.decisions, 100);
        assert!(c.steal_cycles >= 1);
    }
}
