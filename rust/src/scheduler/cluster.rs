//! Shared cluster bookkeeping for the work-stealing schedulers: per-worker
//! queues, data placement (who has which task output), and in-flight
//! transfers. Both [`super::WsScheduler`] and [`super::DaskWsScheduler`]
//! build on this model; the random scheduler deliberately keeps none of it
//! (§IV-C: "does not maintain any task graph state").

use super::{WorkerId, WorkerInfo};
use crate::taskgraph::{TaskGraph, TaskId};
use std::collections::{HashMap, HashSet};

/// Per-worker mutable scheduling state.
#[derive(Debug, Clone, Default)]
pub struct WorkerState {
    pub info: Option<WorkerInfo>,
    /// Tasks assigned but not yet reported finished.
    pub queued: HashSet<TaskId>,
    /// Core slots those queued tasks occupy — a `cores`-wide task counts
    /// its full width, so the balance passes see a 4-core task as four
    /// slots of load, not one queue entry.
    pub queued_slots: u64,
    /// Sum of expected durations of queued tasks (µs) — Dask-style occupancy.
    pub occupancy_us: u64,
    /// Task outputs present on this worker.
    pub has_data: HashSet<TaskId>,
    /// Task outputs that *will* be present (in transit / produced by a task
    /// assigned here) — §IV-C counts these when scoring transfers.
    pub incoming: HashSet<TaskId>,
}

/// Cluster + graph model maintained inside a scheduler.
#[derive(Debug, Default)]
pub struct ClusterModel {
    pub workers: Vec<WorkerState>,
    /// Where each finished task's output lives (possibly several workers).
    pub placement: HashMap<TaskId, Vec<WorkerId>>,
    /// The current graph (the scheduler's own copy, per §IV-A).
    pub graph: Option<TaskGraph>,
    round_robin: usize,
}

impl ClusterModel {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_worker(&mut self, info: WorkerInfo) {
        let idx = info.id.idx();
        if self.workers.len() <= idx {
            self.workers.resize_with(idx + 1, WorkerState::default);
        }
        self.workers[idx].info = Some(info);
    }

    /// Forget a (dead) worker: wipe its per-worker state — so it stops
    /// being a placement/steal candidate — and drop it from every
    /// placement list. Tasks that were queued on it are the caller's
    /// responsibility (the execution layer reports each one via
    /// `Scheduler::task_lost` and re-submits it).
    pub fn remove_worker(&mut self, id: WorkerId) {
        if let Some(w) = self.workers.get_mut(id.idx()) {
            *w = WorkerState::default();
        }
        for holders in self.placement.values_mut() {
            holders.retain(|&h| h != id);
        }
        self.placement.retain(|_, holders| !holders.is_empty());
    }

    /// Drop a task from every queue without recording an output — its
    /// assignment evaporated (worker death or an input-loss cancel). The
    /// steal-race purge in [`ClusterModel::finish`] has the same shape:
    /// an optimistic move may have parked the task on any worker.
    pub fn forget_task(&mut self, task: TaskId) {
        let (dur, cores) = {
            let s = self.graph().task(task);
            (s.duration_us, s.cores as u64)
        };
        for ws in &mut self.workers {
            if ws.queued.remove(&task) {
                ws.occupancy_us = ws.occupancy_us.saturating_sub(dur);
                ws.queued_slots = ws.queued_slots.saturating_sub(cores);
            }
            ws.incoming.remove(&task);
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.info.is_some()).count()
    }

    pub fn worker_ids(&self) -> impl Iterator<Item = WorkerId> + '_ {
        self.workers
            .iter()
            .filter_map(|w| w.info.map(|i| i.id))
    }

    pub fn set_graph(&mut self, graph: &TaskGraph) {
        self.graph = Some(graph.clone());
        self.placement.clear();
        for w in &mut self.workers {
            w.queued.clear();
            w.queued_slots = 0;
            w.occupancy_us = 0;
            w.has_data.clear();
            w.incoming.clear();
        }
    }

    /// Swap in a grown version of the *same* graph (a `submit-extend`
    /// epoch). Task ids are stable across extensions — every existing
    /// queue entry and placement record stays valid — so, unlike
    /// [`ClusterModel::set_graph`], nothing is cleared.
    pub fn extend_graph(&mut self, graph: &TaskGraph) {
        self.graph = Some(graph.clone());
    }

    /// Whether `worker` has enough core slots to ever run a `cores`-wide
    /// task. This is *capacity*, not current load: workers queue beyond
    /// their core count, but a task wider than the worker can never start.
    pub fn can_fit(&self, worker: WorkerId, cores: u32) -> bool {
        self.workers
            .get(worker.idx())
            .and_then(|w| w.info)
            .map(|i| i.ncores >= cores)
            .unwrap_or(false)
    }

    pub fn graph(&self) -> &TaskGraph {
        self.graph.as_ref().expect("graph_submitted must precede scheduling events")
    }

    /// Record an assignment in the model.
    pub fn assign(&mut self, task: TaskId, worker: WorkerId) {
        let (dur, cores) = {
            let s = self.graph().task(task);
            (s.duration_us, s.cores as u64)
        };
        let w = &mut self.workers[worker.idx()];
        w.queued.insert(task);
        w.queued_slots += cores;
        w.occupancy_us += dur;
        w.incoming.insert(task);
    }

    /// Record a finished task and its output placement.
    ///
    /// Steal races make the queue position uncertain: a task optimistically
    /// moved to a steal target can finish on its *original* worker. The
    /// finished task is therefore purged from every queue, so the model can
    /// never propose stealing a completed task.
    pub fn finish(&mut self, task: TaskId, worker: WorkerId) {
        let (dur, cores) = {
            let s = self.graph().task(task);
            (s.duration_us, s.cores as u64)
        };
        let w = &mut self.workers[worker.idx()];
        if w.queued.remove(&task) {
            w.occupancy_us = w.occupancy_us.saturating_sub(dur);
            w.queued_slots = w.queued_slots.saturating_sub(cores);
        } else {
            // Rare steal-race path: find and purge wherever it sits.
            for ws in &mut self.workers {
                if ws.queued.remove(&task) {
                    ws.occupancy_us = ws.occupancy_us.saturating_sub(dur);
                    ws.queued_slots = ws.queued_slots.saturating_sub(cores);
                    ws.incoming.remove(&task);
                    break;
                }
            }
        }
        let w = &mut self.workers[worker.idx()];
        w.incoming.remove(&task);
        w.has_data.insert(task);
        self.placement.entry(task).or_default().push(worker);
    }

    /// Move a queued task between workers (steal bookkeeping). Returns
    /// `false` (and does nothing) if the task is no longer queued at `from`
    /// — e.g. it finished while the retraction was in flight.
    pub fn move_task(&mut self, task: TaskId, from: WorkerId, to: WorkerId) -> bool {
        let (dur, cores) = {
            let s = self.graph().task(task);
            (s.duration_us, s.cores as u64)
        };
        let f = &mut self.workers[from.idx()];
        if !f.queued.remove(&task) {
            return false;
        }
        f.occupancy_us = f.occupancy_us.saturating_sub(dur);
        f.queued_slots = f.queued_slots.saturating_sub(cores);
        f.incoming.remove(&task);
        let t = &mut self.workers[to.idx()];
        t.queued.insert(task);
        t.queued_slots += cores;
        t.occupancy_us += dur;
        t.incoming.insert(task);
        true
    }

    /// Bytes of `task`'s inputs that would have to be fetched if it ran on
    /// `worker`; same-node data is discounted 10× (§IV-C). Counts data that
    /// is present *or incoming* on the worker as free.
    pub fn transfer_cost(&self, task: TaskId, worker: WorkerId) -> u64 {
        let graph = self.graph();
        let spec = graph.task(task);
        let w = &self.workers[worker.idx()];
        let node = w.info.map(|i| i.node);
        let mut cost = 0u64;
        for &input in &spec.inputs {
            if w.has_data.contains(&input) || w.incoming.contains(&input) {
                continue;
            }
            let size = graph.task(input).output_size.max(1);
            // Same-node copy is ~10× cheaper than a network transfer.
            let same_node = self
                .placement
                .get(&input)
                .map(|holders| {
                    holders.iter().any(|h| {
                        self.workers[h.idx()].info.map(|i| Some(i.node) == node).unwrap_or(false)
                    })
                })
                .unwrap_or(false);
            cost += if same_node { size / 10 } else { size };
        }
        cost
    }

    /// Workers holding (or about to hold) any input of `task` — the §IV-C
    /// candidate set that keeps RSDS's decision cheap.
    pub fn candidate_workers(&self, task: TaskId) -> Vec<WorkerId> {
        let graph = self.graph();
        let mut out: Vec<WorkerId> = Vec::new();
        for &input in &graph.task(task).inputs {
            if let Some(holders) = self.placement.get(&input) {
                for &h in holders {
                    if !out.contains(&h) {
                        out.push(h);
                    }
                }
            }
            // Workers with the input incoming (producer assigned there).
            for (idx, w) in self.workers.iter().enumerate() {
                if w.info.is_some() && w.incoming.contains(&input) {
                    let id = WorkerId(idx as u32);
                    if !out.contains(&id) {
                        out.push(id);
                    }
                }
            }
        }
        out
    }

    /// Sorted per-worker queue snapshot (diagnostics / invariant tests).
    pub fn queued_snapshot(&self) -> Vec<(WorkerId, Vec<TaskId>)> {
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.info.is_some())
            .map(|(idx, w)| {
                let mut q: Vec<TaskId> = w.queued.iter().copied().collect();
                q.sort_unstable();
                (WorkerId(idx as u32), q)
            })
            .collect()
    }

    /// Next worker in round-robin order (for input-less tasks).
    pub fn next_round_robin(&mut self) -> Option<WorkerId> {
        self.next_round_robin_fitting(1)
    }

    /// Round-robin restricted to workers with at least `cores` core slots
    /// — placement for input-less multi-core tasks under heterogeneity.
    /// `None` when no registered worker is wide enough.
    pub fn next_round_robin_fitting(&mut self, cores: u32) -> Option<WorkerId> {
        let ids: Vec<WorkerId> =
            self.worker_ids().filter(|&w| self.can_fit(w, cores)).collect();
        if ids.is_empty() {
            return None;
        }
        let id = ids[self.round_robin % ids.len()];
        self.round_robin += 1;
        Some(id)
    }

    /// (most-loaded worker by queued core slots, least-loaded) — used by
    /// balance passes. Returns `None` with fewer than 2 workers.
    pub fn load_extremes(&self) -> Option<(WorkerId, WorkerId)> {
        let mut max_w = None;
        let mut min_w = None;
        for (idx, w) in self.workers.iter().enumerate() {
            if w.info.is_none() {
                continue;
            }
            let id = WorkerId(idx as u32);
            let q = w.queued_slots as usize;
            if max_w.map(|(_, mq)| q > mq).unwrap_or(true) {
                max_w = Some((id, q));
            }
            if min_w.map(|(_, mq)| q < mq).unwrap_or(true) {
                min_w = Some((id, q));
            }
        }
        match (max_w, min_w) {
            (Some((a, _)), Some((b, _))) if a != b => Some((a, b)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::{GraphBuilder, Payload};

    fn graph() -> TaskGraph {
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 100, 1000, Payload::NoOp);
        let c = b.add("c", vec![], 100, 500, Payload::NoOp);
        b.add("d", vec![a, c], 100, 10, Payload::MergeInputs);
        b.build("g").unwrap()
    }

    fn model(nodes: &[u32]) -> ClusterModel {
        let mut m = ClusterModel::new();
        for (i, &node) in nodes.iter().enumerate() {
            m.add_worker(WorkerInfo { id: WorkerId(i as u32), ncores: 1, node });
        }
        m.set_graph(&graph());
        m
    }

    #[test]
    fn transfer_cost_counts_missing_inputs() {
        let mut m = model(&[0, 1]);
        m.finish(TaskId(0), WorkerId(0)); // a on w0
        m.finish(TaskId(1), WorkerId(1)); // c on w1
        // d on w0: must fetch c (500) from another node
        assert_eq!(m.transfer_cost(TaskId(2), WorkerId(0)), 500);
        // d on w1: must fetch a (1000)
        assert_eq!(m.transfer_cost(TaskId(2), WorkerId(1)), 1000);
    }

    #[test]
    fn same_node_discount() {
        let mut m = model(&[0, 0]); // both workers on node 0
        m.finish(TaskId(0), WorkerId(0));
        m.finish(TaskId(1), WorkerId(1));
        // d on w0: c is on the same node ⇒ 500/10
        assert_eq!(m.transfer_cost(TaskId(2), WorkerId(0)), 50);
    }

    #[test]
    fn incoming_counts_as_present() {
        let mut m = model(&[0, 1]);
        m.assign(TaskId(0), WorkerId(1)); // a will be produced on w1
        m.finish(TaskId(1), WorkerId(1));
        assert_eq!(m.transfer_cost(TaskId(2), WorkerId(1)), 0);
        let cands = m.candidate_workers(TaskId(2));
        assert_eq!(cands, vec![WorkerId(1)]);
    }

    #[test]
    fn occupancy_tracks_assign_finish_move() {
        let mut m = model(&[0, 1]);
        m.assign(TaskId(0), WorkerId(0));
        m.assign(TaskId(1), WorkerId(0));
        assert_eq!(m.workers[0].occupancy_us, 200);
        m.move_task(TaskId(1), WorkerId(0), WorkerId(1));
        assert_eq!(m.workers[0].occupancy_us, 100);
        assert_eq!(m.workers[1].occupancy_us, 100);
        m.finish(TaskId(0), WorkerId(0));
        assert_eq!(m.workers[0].occupancy_us, 0);
        assert!(m.workers[0].has_data.contains(&TaskId(0)));
    }

    #[test]
    fn round_robin_cycles() {
        let mut m = model(&[0, 1]);
        let a = m.next_round_robin().unwrap();
        let b = m.next_round_robin().unwrap();
        let c = m.next_round_robin().unwrap();
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn remove_worker_clears_state_and_placement() {
        let mut m = model(&[0, 1]);
        m.assign(TaskId(0), WorkerId(0));
        m.finish(TaskId(0), WorkerId(0));
        m.finish(TaskId(1), WorkerId(1));
        m.remove_worker(WorkerId(0));
        assert_eq!(m.n_workers(), 1);
        assert!(m.worker_ids().all(|w| w != WorkerId(0)));
        assert!(!m.placement.contains_key(&TaskId(0)), "sole replica purged");
        assert_eq!(m.placement[&TaskId(1)], vec![WorkerId(1)]);
        // Candidates for d never include the corpse.
        assert_eq!(m.candidate_workers(TaskId(2)), vec![WorkerId(1)]);
    }

    #[test]
    fn forget_task_purges_every_queue() {
        let mut m = model(&[0, 1]);
        m.assign(TaskId(0), WorkerId(0));
        m.move_task(TaskId(0), WorkerId(0), WorkerId(1)); // optimistic steal
        m.forget_task(TaskId(0));
        for w in &m.workers {
            assert!(!w.queued.contains(&TaskId(0)));
            assert!(!w.incoming.contains(&TaskId(0)));
        }
        assert_eq!(m.workers[1].occupancy_us, 0);
    }

    #[test]
    fn load_extremes() {
        let mut m = model(&[0, 1]);
        m.assign(TaskId(0), WorkerId(0));
        m.assign(TaskId(1), WorkerId(0));
        let (hi, lo) = m.load_extremes().unwrap();
        assert_eq!(hi, WorkerId(0));
        assert_eq!(lo, WorkerId(1));
    }

    #[test]
    fn multicore_tasks_occupy_multiple_slots() {
        let mut b = GraphBuilder::new();
        let wide = b.add_with_cores("wide", vec![], 100, 10, Payload::NoOp, 4);
        let narrow = b.add("narrow", vec![], 100, 10, Payload::NoOp);
        let g = b.build("g").unwrap();
        let mut m = ClusterModel::new();
        m.add_worker(WorkerInfo { id: WorkerId(0), ncores: 4, node: 0 });
        m.add_worker(WorkerInfo { id: WorkerId(1), ncores: 1, node: 0 });
        m.set_graph(&g);
        assert!(m.can_fit(WorkerId(0), 4));
        assert!(!m.can_fit(WorkerId(1), 2));
        assert!(!m.can_fit(WorkerId(9), 1), "unknown worker never fits");
        m.assign(wide, WorkerId(0));
        m.assign(narrow, WorkerId(1));
        assert_eq!(m.workers[0].queued_slots, 4);
        assert_eq!(m.workers[1].queued_slots, 1);
        // One queued task each, but the 4-core task makes w0 the loaded one.
        let (hi, lo) = m.load_extremes().unwrap();
        assert_eq!(hi, WorkerId(0));
        assert_eq!(lo, WorkerId(1));
        m.move_task(wide, WorkerId(0), WorkerId(0));
        m.finish(wide, WorkerId(0));
        assert_eq!(m.workers[0].queued_slots, 0);
        m.forget_task(narrow);
        assert_eq!(m.workers[1].queued_slots, 0);
    }

    #[test]
    fn round_robin_fitting_skips_narrow_workers() {
        let mut m = ClusterModel::new();
        m.add_worker(WorkerInfo { id: WorkerId(0), ncores: 1, node: 0 });
        m.add_worker(WorkerInfo { id: WorkerId(1), ncores: 4, node: 0 });
        m.set_graph(&graph());
        for _ in 0..4 {
            assert_eq!(m.next_round_robin_fitting(2), Some(WorkerId(1)));
        }
        assert_eq!(m.next_round_robin_fitting(8), None);
    }

    #[test]
    fn extend_graph_keeps_placement_and_queues() {
        use crate::taskgraph::TaskSpec;
        let mut m = model(&[0, 1]);
        m.assign(TaskId(0), WorkerId(0));
        m.finish(TaskId(0), WorkerId(0));
        m.assign(TaskId(1), WorkerId(1));
        let mut grown = m.graph().clone();
        grown
            .extend(vec![TaskSpec {
                id: TaskId(3),
                key: "e".into(),
                inputs: vec![TaskId(2)],
                duration_us: 100,
                output_size: 1,
                payload: Payload::MergeInputs,
                cores: 1,
            }])
            .unwrap();
        m.extend_graph(&grown);
        assert_eq!(m.graph().len(), 4, "model sees the extension");
        assert_eq!(m.placement[&TaskId(0)], vec![WorkerId(0)], "placement survives");
        assert!(m.workers[0].has_data.contains(&TaskId(0)));
        assert!(m.workers[1].queued.contains(&TaskId(1)), "queue survives");
        assert_eq!(m.workers[1].queued_slots, 1);
    }
}
