//! Scheduler interface and implementations.
//!
//! The paper's RSDS separates the server into a *reactor* and an isolated
//! *scheduler* that "receives a task graph and outputs assignments of tasks
//! to workers" without touching connections or protocol state (§IV-A).
//! This module is that boundary: [`Scheduler`] is driven by events and
//! emits [`Action`]s; the same implementations run unchanged under the real
//! TCP server ([`crate::server`]) and the discrete-event simulator
//! ([`crate::sim`]) — which is what makes the paper's scheduler-vs-runtime
//! comparison controlled.
//!
//! Ownership and threading: a scheduler instance is owned by exactly one
//! driver — the reactor thread (one instance per run, via the server's
//! `SchedulerPool`) or a sim engine — and is never shared or locked; the
//! trait requires `Send` only so the owning thread can be spawned. All
//! methods take `&mut self` and run to completion on the caller's thread
//! (the paper's GIL-vs-thread distinction is priced by
//! [`crate::overhead::RuntimeProfile`], not by real concurrency).
//!
//! Implementations:
//! - [`RandomScheduler`] — uniform random assignment (§III-E),
//! - [`WsScheduler`] — RSDS's simplified work-stealing (§IV-C): minimal
//!   transfer cost, deliberately ignores load, fixes imbalance by stealing,
//! - [`DaskWsScheduler`] — an emulation of Dask's work-stealing heuristic
//!   (§III-D): earliest-estimated-start-time over *all* workers using
//!   occupancy and duration/bandwidth estimates, plus stealing.

mod cluster;
mod dask_ws;
mod random;
mod ws;

pub use cluster::ClusterModel;
pub use dask_ws::DaskWsScheduler;
pub use random::RandomScheduler;
pub use ws::WsScheduler;

use crate::overhead::SchedKind;
use crate::taskgraph::{TaskGraph, TaskId};

/// Worker identifier assigned by the server at registration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkerId(pub u32);

impl WorkerId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for WorkerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "w{}", self.0)
    }
}

/// Static facts about a worker, provided at registration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerInfo {
    pub id: WorkerId,
    /// Cores == max concurrently running tasks (paper runs 1-core workers).
    pub ncores: u32,
    /// Physical node index: transfers within a node are cheap (§IV-C:
    /// "transfer cost is smaller for data transfers between workers
    /// residing on the same node").
    pub node: u32,
}

/// A scheduling decision: run `task` on `worker`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Assignment {
    pub task: TaskId,
    pub worker: WorkerId,
    /// Lower value = execute earlier (graph order, like Dask's priorities).
    pub priority: i64,
}

/// What the scheduler asks the reactor to do.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// Send the task to the worker.
    Assign(Assignment),
    /// Try to retract `task` from `from` and move it to `to`. The reactor
    /// performs the retraction protocol and reports back via
    /// [`Scheduler::steal_result`] (§IV-C).
    Steal { task: TaskId, from: WorkerId, to: WorkerId },
}

/// Work performed by the scheduler since the last [`Scheduler::take_cost`],
/// in algorithm-level units. The execution backend converts these to CPU
/// time with a [`crate::overhead::RuntimeProfile`] — this is how the same
/// scheduling *algorithm* can be priced as a Python or a Rust
/// *implementation*.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SchedCost {
    /// Number of per-task placement decisions taken.
    pub decisions: u64,
    /// Total workers examined across those decisions.
    pub workers_scanned: u64,
    /// Balance/steal scan cycles performed.
    pub steal_cycles: u64,
}

impl SchedCost {
    pub fn add(&mut self, other: SchedCost) {
        self.decisions += other.decisions;
        self.workers_scanned += other.workers_scanned;
        self.steal_cycles += other.steal_cycles;
    }

    /// Convert to µs of scheduler CPU under `profile`.
    pub fn to_us(&self, profile: &crate::overhead::RuntimeProfile, kind: SchedKind) -> f64 {
        let per_decision = match kind {
            SchedKind::Random => profile.random_decision_us * self.decisions as f64,
            SchedKind::WorkStealing => {
                profile.ws_decision_base_us * self.decisions as f64
                    + profile.ws_decision_per_worker_us * self.workers_scanned as f64
            }
        };
        per_decision + profile.steal_cycle_us * self.steal_cycles as f64
    }
}

/// The scheduler ↔ reactor interface (paper Fig 1).
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Which cost family the profile charges for this scheduler.
    fn kind(&self) -> SchedKind;

    /// A worker joined the cluster (all workers join before the graph in
    /// the paper's fixed-cluster experiments, but late joins are allowed).
    fn add_worker(&mut self, info: WorkerInfo);

    /// A worker left the cluster (disconnect). The scheduler must stop
    /// proposing it for placement and may forget any model state about it;
    /// tasks it was responsible for are reported separately, one
    /// [`Scheduler::task_lost`] each, and then re-offered through
    /// [`Scheduler::tasks_ready`] by the execution layer's lineage
    /// recovery. Default: no-op (for schedulers without a cluster model the
    /// execution layer's re-submission is all that is needed).
    fn remove_worker(&mut self, _worker: WorkerId) {}

    /// A previously emitted assignment of `task` to `worker` evaporated —
    /// the worker died, or the execution layer cancelled the queued copy
    /// because an input was lost. The scheduler must drop the task from its
    /// queue model (wherever an optimistic steal move may have put it); the
    /// task will come back via [`Scheduler::tasks_ready`] once its inputs
    /// are available again. Default: no-op.
    fn task_lost(&mut self, _task: TaskId, _worker: WorkerId) {}

    /// A new task graph arrived. The scheduler builds its own copy of the
    /// state it needs (the paper notes reactor and scheduler each keep
    /// their own task graph).
    fn graph_submitted(&mut self, graph: &TaskGraph);

    /// The current run's graph grew in place (`submit-extend`): `graph` is
    /// the same graph with a batch of new tasks appended. Task ids are
    /// stable across the extension, so schedulers with a cluster model
    /// refresh their graph copy *without* clearing placement or queue
    /// state; newly ready tasks follow via [`Scheduler::tasks_ready`].
    /// Default: no-op (stateless schedulers and test probes need nothing).
    fn graph_extended(&mut self, _graph: &TaskGraph) {}

    /// Tasks whose dependencies are all finished; the scheduler must
    /// eventually assign each exactly once.
    fn tasks_ready(&mut self, tasks: &[TaskId], out: &mut Vec<Action>);

    /// A task finished on a worker producing `nbytes`; `duration_us` is the
    /// measured execution time (Dask's heuristic feeds its estimates with
    /// it; RSDS's deliberately does not use it).
    fn task_finished(
        &mut self,
        task: TaskId,
        worker: WorkerId,
        nbytes: u64,
        duration_us: u64,
        out: &mut Vec<Action>,
    );

    /// Outcome of a previously emitted steal: on success the task now runs
    /// on `to`; on failure it stayed on `from` (already running/finished).
    fn steal_result(
        &mut self,
        task: TaskId,
        from: WorkerId,
        to: WorkerId,
        success: bool,
        out: &mut Vec<Action>,
    );

    /// Drain accumulated algorithmic cost counters.
    fn take_cost(&mut self) -> SchedCost;

    /// The scheduler's internal view of per-worker queued (assigned, not
    /// yet finished) tasks, for diagnostics and invariant tests. `None` for
    /// schedulers that keep no cluster model (e.g. random).
    fn queued_tasks(&self) -> Option<Vec<(WorkerId, Vec<TaskId>)>> {
        None
    }

    /// Steals emitted but not yet resolved via [`Scheduler::steal_result`].
    /// A value that never returns to 0 at quiescence indicates the
    /// execution layer dropped a steal notification.
    fn in_flight_steal_count(&self) -> usize {
        0
    }
}

/// Construct a scheduler by CLI name.
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn Scheduler>> {
    match name {
        "random" => Some(Box::new(RandomScheduler::new(seed))),
        "ws" => Some(Box::new(WsScheduler::new())),
        "ws-nobalance" => Some(Box::new(WsScheduler::without_balancing())),
        "ws-lifo" => Some(Box::new(WsScheduler::lifo())),
        "dask-ws" | "dask_ws" => Some(Box::new(DaskWsScheduler::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overhead::RuntimeProfile;

    #[test]
    fn cost_conversion() {
        let c = SchedCost { decisions: 10, workers_scanned: 240, steal_cycles: 2 };
        let p = RuntimeProfile::rust();
        let ws_us = c.to_us(&p, SchedKind::WorkStealing);
        let want_ws = 10.0 * p.ws_decision_base_us
            + 240.0 * p.ws_decision_per_worker_us
            + 2.0 * p.steal_cycle_us;
        assert!((ws_us - want_ws).abs() < 1e-9);
        let rand_us = c.to_us(&p, SchedKind::Random);
        let want_rand = 10.0 * p.random_decision_us + 2.0 * p.steal_cycle_us;
        assert!((rand_us - want_rand).abs() < 1e-9);
    }

    #[test]
    fn by_name_constructs_all() {
        for (n, kind) in [
            ("random", SchedKind::Random),
            ("ws", SchedKind::WorkStealing),
            ("ws-lifo", SchedKind::WorkStealing),
            ("dask-ws", SchedKind::WorkStealing),
        ] {
            let s = by_name(n, 1).unwrap();
            assert_eq!(s.kind(), kind);
        }
        assert!(by_name("fifo", 1).is_none());
    }
}
