//! `groupby-d-f-p` / `join-d-f-p` — dask.dataframe workloads over a time-
//! indexed table: `d` days of records `f` time-units apart, partitioned into
//! `p`-hour windows (§V).
//!
//! `groupby` lowers the way dask lowers `df.groupby(...).agg(...)`:
//! per-partition read → per-partition chunk-aggregation → fan-in tree of
//! combines → final agg. `join` lowers a sorted self-join: per-partition
//! read → per-output-partition merge consuming the aligned partition and its
//! successor (interval overlap) → result collection tree.

use crate::taskgraph::{GraphBuilder, Payload, TaskGraph, TaskId};

const COMBINE_FAN: usize = 8;

/// Number of partitions for d days with p-hour windows.
fn npartitions(days: u32, part_hours: f64) -> usize {
    ((days as f64 * 24.0 / part_hours).ceil() as usize).max(1)
}

/// Records per partition: one record every `freq_us` simulated time-units.
fn records_per_partition(part_hours: f64, freq_us: u64) -> f64 {
    // f is the record spacing in (simulated) seconds when given as `1s`.
    let records_per_hour = 3600.0 / (freq_us as f64 / 1e6);
    records_per_hour * part_hours
}

pub fn groupby(days: u32, freq_us: u64, part_hours: f64) -> TaskGraph {
    let np = npartitions(days, part_hours);
    let rpp = records_per_partition(part_hours, freq_us);
    // Calibrated to Table I's groupby rows: rpp = 3600 ⇒ AD ≈ 11.8 ms,
    // S ≈ 1 MiB (wide table rows, ~600 B materialized per record).
    let read_us = (rpp * 1.4).max(1.0) as u64;
    let chunk_us = (rpp * 5.6).max(1.0) as u64; // hash-agg pass
    let part_bytes = (rpp * 600.0) as u64;
    let agg_bytes = (part_bytes / 16).max(64);

    let mut b = GraphBuilder::new();
    let mut chunks: Vec<TaskId> = Vec::with_capacity(np);
    for i in 0..np {
        let read = b.add(format!("read-{i}"), vec![], read_us, part_bytes, Payload::BusyWait);
        chunks.push(b.add(
            format!("chunk-{i}"),
            vec![read],
            chunk_us,
            agg_bytes,
            Payload::BusyWait,
        ));
    }
    let mut level = chunks;
    let mut depth = 0;
    while level.len() > 1 {
        depth += 1;
        level = level
            .chunks(COMBINE_FAN)
            .enumerate()
            .map(|(k, c)| {
                b.add(
                    format!("combine-{depth}-{k}"),
                    c.to_vec(),
                    (chunk_us / 4).max(1),
                    agg_bytes,
                    Payload::MergeInputs,
                )
            })
            .collect();
    }
    b.add("agg", vec![level[0]], (chunk_us / 4).max(1), agg_bytes, Payload::MergeInputs);
    b.build(format!("groupby-{days}-{freq_us}us-{part_hours}h"))
        .expect("groupby graph valid by construction")
}

pub fn join(days: u32, freq_us: u64, part_hours: f64) -> TaskGraph {
    let np = npartitions(days, part_hours);
    let rpp = records_per_partition(part_hours, freq_us);
    // Calibrated to Table I's join rows: rpp = 3600 ⇒ AD ≈ 8 ms, S ≈ 0.5 MiB.
    let read_us = (rpp * 1.4).max(1.0) as u64;
    let join_us = (rpp * 3.5).max(1.0) as u64; // sorted merge-join pass
    let part_bytes = (rpp * 300.0) as u64;
    let joined_bytes = (rpp * 60.0) as u64;

    let mut b = GraphBuilder::new();
    let reads: Vec<TaskId> = (0..np)
        .map(|i| b.add(format!("read-{i}"), vec![], read_us, part_bytes, Payload::BusyWait))
        .collect();
    // Sorted self-join: output partition i overlaps input partitions i and i+1.
    let joins: Vec<TaskId> = (0..np)
        .map(|i| {
            let mut inputs = vec![reads[i]];
            if i + 1 < np {
                inputs.push(reads[i + 1]);
            }
            b.add(format!("join-{i}"), inputs, join_us, joined_bytes, Payload::BusyWait)
        })
        .collect();
    // collect results
    let mut level = joins;
    let mut depth = 0;
    while level.len() > 1 {
        depth += 1;
        level = level
            .chunks(COMBINE_FAN)
            .enumerate()
            .map(|(k, c)| {
                b.add(format!("collect-{depth}-{k}"), c.to_vec(), 2, 128, Payload::MergeInputs)
            })
            .collect();
    }
    b.build(format!("join-{days}-{freq_us}us-{part_hours}h"))
        .expect("join graph valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::GraphStats;

    #[test]
    fn partition_arithmetic() {
        assert_eq!(npartitions(90, 1.0), 2160);
        assert_eq!(npartitions(2880, 16.0), 4320);
        let rpp = records_per_partition(1.0, 1_000_000); // 1 s spacing, 1 h window
        assert!((rpp - 3600.0).abs() < 1e-6);
    }

    #[test]
    fn groupby_fig5_matches_prose() {
        // Fig 5's groupby graph: §VI-C says "average computation time is
        // still only around 10ms while the average task output is 1 MiB".
        // With 16 s record spacing and 16 h windows: rpp = 3600, np = 4320.
        let s = GraphStats::of(&groupby(2880, 16_000_000, 16.0));
        assert!((9_000..=10_000).contains(&s.n_tasks), "tasks {}", s.n_tasks);
        assert!((5.0..=20.0).contains(&s.avg_duration_ms), "AD {}", s.avg_duration_ms);
        assert!((500.0..=2_000.0).contains(&s.avg_output_kib), "S {}", s.avg_output_kib);
    }

    #[test]
    fn groupby_table1_shape() {
        // Table I groupby rows have deps/tasks ≈ 1.38 and LP ≈ 9.
        let s = GraphStats::of(&groupby(90, 1_000_000, 1.0));
        let ratio = s.n_deps as f64 / s.n_tasks as f64;
        assert!((0.9..=1.6).contains(&ratio), "deps/tasks {ratio}");
        assert!((4..=12).contains(&s.longest_path), "lp {}", s.longest_path);
    }

    #[test]
    fn join_shape() {
        let s = GraphStats::of(&join(90, 1_000_000, 1.0));
        let ratio = s.n_deps as f64 / s.n_tasks as f64;
        // Table I join rows: ratio ≈ 1.38
        assert!((1.1..=1.6).contains(&ratio), "deps/tasks {ratio}");
        let g = join(90, 1_000_000, 1.0);
        assert_eq!(g.sinks().len(), 1);
    }

    #[test]
    fn coarser_partitions_fewer_tasks() {
        let fine = GraphStats::of(&groupby(90, 1_000_000, 1.0));
        let coarse = GraphStats::of(&groupby(90, 1_000_000, 8.0));
        assert!(coarse.n_tasks < fine.n_tasks / 4);
        assert!(coarse.avg_duration_ms > fine.avg_duration_ms * 4.0);
    }
}
