//! Incremental-submission and heterogeneous-resource variants of the
//! benchmark graphs (PR 9).
//!
//! The paper's workloads are one-shot: the whole task graph is known at
//! submission. Real interactive sessions grow graphs as results come back
//! — the `submit-extend` protocol op streams task batches into a live run.
//! [`split_incremental`] turns any benchmark graph into that shape: a base
//! graph plus extension batches, split in id order (which the
//! [`crate::taskgraph::TaskGraph`] invariant guarantees is topological, so
//! every batch only depends on earlier batches). Replaying base + batches
//! must produce byte-identical outputs to the one-shot submission — the
//! `fig_dynamic` bench and the sim/TCP parity tests assert exactly that.
//!
//! [`with_cores`] stamps a cyclic multi-core requirement pattern onto a
//! graph (dslab-dag-style resource demands), producing the heterogeneous
//! workloads `fig_dynamic` measures random placement under.

use crate::taskgraph::{TaskGraph, TaskSpec};

/// Split `g` into a base graph plus extension batches, in id (topological)
/// order. `n_batches` counts the base, so `split_incremental(g, 4)` yields
/// the base plus up to 3 extension batches (fewer if the graph is tiny).
/// Submitting the base open and extending with each batch in order —
/// closing on the final one — computes exactly the tasks of `g`.
pub fn split_incremental(g: &TaskGraph, n_batches: usize) -> (TaskGraph, Vec<Vec<TaskSpec>>) {
    assert!(n_batches >= 1, "need at least one batch");
    let n = g.len();
    assert!(n_batches <= n, "more batches ({n_batches}) than tasks ({n})");
    let chunk = n.div_ceil(n_batches);
    let tasks = g.tasks();
    let base = TaskGraph::new(g.name.clone(), tasks[..chunk].to_vec())
        .expect("an id-order prefix of a valid graph is a valid graph");
    let exts: Vec<Vec<TaskSpec>> = tasks[chunk..].chunks(chunk).map(<[TaskSpec]>::to_vec).collect();
    (base, exts)
}

/// Rebuild `g` with core requirements cycled from `pattern` over the task
/// id (`pattern[id % len]`, clamped to ≥ 1). Structure, durations and
/// output sizes are untouched, so results stay byte-identical to the
/// 1-core graph — only placement constraints change.
pub fn with_cores(g: &TaskGraph, pattern: &[u32]) -> TaskGraph {
    assert!(!pattern.is_empty(), "empty core pattern");
    let tasks: Vec<TaskSpec> = g
        .tasks()
        .iter()
        .cloned()
        .map(|mut t| {
            t.cores = pattern[t.id.idx() % pattern.len()].max(1);
            t
        })
        .collect();
    TaskGraph::new(g.name.clone(), tasks).expect("core widths do not affect validity")
}

/// One `fig_dynamic` workload: a benchmark graph grown incrementally over
/// a heterogeneous cluster.
#[derive(Debug, Clone, Copy)]
pub struct DynamicEntry {
    pub name: &'static str,
    /// Spec accepted by [`crate::graphgen::parse`].
    pub spec: &'static str,
    /// Batches the graph is submitted in (base + extensions).
    pub batches: usize,
    /// Task core-requirement pattern fed to [`with_cores`] (`[1]` keeps
    /// the workload homogeneous).
    pub task_cores: &'static [u32],
}

/// The `fig_dynamic` suite: incrementally-grown graphs, with and without
/// multi-core tasks, sized to finish quickly under the sim. The worker
/// side of the heterogeneity (the 1/2/4-core mix) is the bench's axis,
/// not the suite's.
pub fn dynamic_suite() -> Vec<DynamicEntry> {
    vec![
        DynamicEntry { name: "merge-2K-inc4", spec: "merge-2000", batches: 4, task_cores: &[1] },
        DynamicEntry { name: "tree-9-inc3", spec: "tree-9", batches: 3, task_cores: &[1] },
        DynamicEntry {
            name: "xarray-5-inc3-hetero",
            spec: "xarray-5",
            batches: 3,
            task_cores: &[1, 1, 2, 1, 4],
        },
        DynamicEntry {
            name: "merge-2K-inc4-hetero",
            spec: "merge-2000",
            batches: 4,
            task_cores: &[1, 2],
        },
    ]
}

impl DynamicEntry {
    /// Build the full (one-shot) graph, core pattern applied.
    pub fn graph(&self) -> TaskGraph {
        with_cores(&super::parse(self.spec).expect("dynamic suite specs are valid"), self.task_cores)
    }

    /// Build the incremental form: base graph + extension batches.
    pub fn incremental(&self) -> (TaskGraph, Vec<Vec<TaskSpec>>) {
        split_incremental(&self.graph(), self.batches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{merge, tree};

    #[test]
    fn split_covers_every_task_in_order() {
        let g = tree(6);
        let (base, exts) = split_incremental(&g, 4);
        let mut rebuilt = base.tasks().to_vec();
        for b in &exts {
            rebuilt.extend(b.iter().cloned());
        }
        assert_eq!(rebuilt, g.tasks().to_vec(), "split must partition the graph in id order");
        assert!(exts.len() >= 3, "tree-6 is large enough for 4 batches");
    }

    #[test]
    fn split_base_revalidates_and_extends_back_to_original() {
        let g = merge(100);
        let (mut base, exts) = split_incremental(&g, 3);
        for b in exts {
            base.extend(b).expect("batches extend in order");
        }
        assert_eq!(base.len(), g.len());
        assert_eq!(base.n_deps(), g.n_deps());
        for t in g.tasks() {
            assert_eq!(base.consumers(t.id), g.consumers(t.id));
        }
    }

    #[test]
    #[should_panic(expected = "more batches")]
    fn split_rejects_more_batches_than_tasks() {
        let g = merge(2); // 3 tasks
        let _ = split_incremental(&g, 10);
    }

    #[test]
    fn with_cores_cycles_pattern_and_keeps_structure() {
        let g = merge(50);
        let h = with_cores(&g, &[1, 2, 4]);
        assert_eq!(h.len(), g.len());
        for t in h.tasks() {
            assert_eq!(t.cores, [1u32, 2, 4][t.id.idx() % 3]);
            assert_eq!(t.inputs, g.task(t.id).inputs);
        }
        assert_eq!(h.max_cores(), 4);
    }

    #[test]
    fn dynamic_suite_entries_build_and_split() {
        for e in dynamic_suite() {
            let g = e.graph();
            assert!(!g.is_empty(), "{}", e.name);
            let (base, exts) = e.incremental();
            assert_eq!(
                base.len() + exts.iter().map(Vec::len).sum::<usize>(),
                g.len(),
                "{}",
                e.name
            );
            assert!(!exts.is_empty(), "{}: no extensions", e.name);
        }
    }
}
