//! `bag-n-p` — dask.bag workload: cartesian product of a dataset with
//! itself, filtering, and fold aggregation (§V).
//!
//! Structure (matches Table I's #T ≈ 2p² + 2p and #I ≈ 4p²):
//! p `load` roots → p² `product` tasks (one per ordered partition pair,
//! 2 deps off-diagonal) → p² `filter` tasks (1 dep) → per-row fold (fan 32
//! tree) → final fold. Costs scale with records-per-partition r = n/p:
//! a product touches r² pairs.

use crate::taskgraph::{GraphBuilder, Payload, TaskGraph, TaskId};

const FOLD_FAN: usize = 32;

/// `n` records split into `p` partitions.
pub fn bag(n: u64, p: u32) -> TaskGraph {
    assert!(p > 0 && n > 0);
    let p = p as usize;
    let r = (n as f64 / p as f64).max(1.0); // records per partition
    let product_us = (r * r * 0.55).max(1.0) as u64; // ~0.55 µs per record pair
    let filter_us = (product_us / 50).max(1);
    let load_us = (r * 2.0).max(1.0) as u64;
    let part_bytes = (r * 64.0) as u64; // ~64 B/record
    let product_bytes = ((r * r * 0.15) as u64).max(16); // surviving pairs
    let folded_bytes = (product_bytes / 10).max(16);

    let mut b = GraphBuilder::new();
    let loads: Vec<TaskId> = (0..p)
        .map(|i| b.add(format!("load-{i}"), vec![], load_us, part_bytes, Payload::BusyWait))
        .collect();
    let mut row_folds: Vec<TaskId> = Vec::with_capacity(p);
    for i in 0..p {
        let filters: Vec<TaskId> = (0..p)
            .map(|j| {
                let prod = b.add(
                    format!("prod-{i}-{j}"),
                    if i == j { vec![loads[i]] } else { vec![loads[i], loads[j]] },
                    product_us,
                    product_bytes,
                    Payload::BusyWait,
                );
                b.add(format!("filt-{i}-{j}"), vec![prod], filter_us, product_bytes, Payload::BusyWait)
            })
            .collect();
        row_folds.push(fold_tree(&mut b, filters, &format!("fold-{i}"), filter_us, folded_bytes));
    }
    fold_tree(&mut b, row_folds, "final", filter_us, folded_bytes);
    b.build(format!("bag-{n}-{p}")).expect("bag graph valid by construction")
}

/// Fan-in fold; returns the root of the tree.
fn fold_tree(
    b: &mut GraphBuilder,
    mut level: Vec<TaskId>,
    prefix: &str,
    dur_us: u64,
    out_bytes: u64,
) -> TaskId {
    let mut depth = 0;
    while level.len() > 1 {
        depth += 1;
        level = level
            .chunks(FOLD_FAN)
            .enumerate()
            .map(|(k, c)| {
                b.add(format!("{prefix}-{depth}-{k}"), c.to_vec(), dur_us, out_bytes, Payload::MergeInputs)
            })
            .collect();
    }
    level[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::GraphStats;

    #[test]
    fn table1_small_row() {
        // Table I: 236 tasks, 415 deps, AD 1233 ms, S 292 KiB, LP 6.
        let s = GraphStats::of(&bag(21_000, 10));
        assert!((210..=260).contains(&s.n_tasks), "tasks {}", s.n_tasks);
        assert!((380..=460).contains(&s.n_deps), "deps {}", s.n_deps);
        assert!((2..=7).contains(&s.longest_path), "lp {}", s.longest_path);
        assert!((600.0..=2_500.0).contains(&s.avg_duration_ms), "ad {}", s.avg_duration_ms);
        assert!((150.0..=600.0).contains(&s.avg_output_kib), "s {}", s.avg_output_kib);
    }

    #[test]
    fn table1_large_row() {
        // Table I: 86116 tasks, 165715 deps, AD 3.6 ms, S 0.8 KiB, LP 9.
        let s = GraphStats::of(&bag(23_600, 207));
        assert!((80_000..=92_000).contains(&s.n_tasks), "tasks {}", s.n_tasks);
        assert!((150_000..=185_000).contains(&s.n_deps), "deps {}", s.n_deps);
        assert!((1.0..=9.0).contains(&s.avg_duration_ms), "ad {}", s.avg_duration_ms);
        assert!((0.2..=2.0).contains(&s.avg_output_kib), "s {}", s.avg_output_kib);
    }

    #[test]
    fn quadratic_in_partitions() {
        let s10 = GraphStats::of(&bag(10_000, 10));
        let s20 = GraphStats::of(&bag(10_000, 20));
        let ratio = s20.n_tasks as f64 / s10.n_tasks as f64;
        assert!((3.0..=5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn single_sink_and_roots() {
        let g = bag(1_000, 8);
        assert_eq!(g.sinks().len(), 1);
        assert_eq!(g.roots().len(), 8);
    }
}
