//! `merge-n` / `merge_slow-n-t` — the paper's scheduler/server stress test:
//! n independent trivial tasks merged by a single final task (§V).
//!
//! Table I: merge-n has #T = n+1, #I = n, S ≈ 0.027 KiB, AD ≈ 0.006 ms,
//! LP = 1. merge_slow-n-t is identical in shape with t-second tasks.

use crate::taskgraph::{GraphBuilder, Payload, TaskGraph};

/// Duration of one trivial merge task (Table I: AD = 0.006 ms).
pub const MERGE_TASK_US: u64 = 6;
/// Output size of one merge task (Table I: S = 0.027 KiB ≈ 28 B).
pub const MERGE_OUTPUT_BYTES: u64 = 28;

/// `merge-n`: n trivial independent tasks + one merging sink.
pub fn merge(n: u32) -> TaskGraph {
    merge_impl(format!("merge-{n}"), n, MERGE_TASK_US, MERGE_OUTPUT_BYTES)
}

/// `merge_slow-n-t`: same shape, each task takes `task_us` µs
/// (Table I: S = 0.023 KiB).
pub fn merge_slow(n: u32, task_us: u64) -> TaskGraph {
    merge_impl(format!("merge_slow-{n}-{task_us}us"), n, task_us, 24)
}

fn merge_impl(name: String, n: u32, task_us: u64, out_bytes: u64) -> TaskGraph {
    assert!(n > 0, "merge needs at least one task");
    let mut b = GraphBuilder::new();
    let leaves: Vec<_> = (0..n)
        .map(|i| b.add(format!("task-{i}"), vec![], task_us, out_bytes, Payload::BusyWait))
        .collect();
    // The merging task itself is trivial: it only touches n tiny outputs.
    b.add("merge", leaves, task_us, out_bytes, Payload::MergeInputs);
    b.build(name).expect("merge graph is valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::{longest_path, GraphStats};

    #[test]
    fn matches_table1_shape() {
        // Table I rows: merge-{10K,15K,20K,25K,30K,50K,100K}
        for n in [10_000u32, 25_000, 100_000] {
            let g = merge(n);
            let s = GraphStats::of(&g);
            assert_eq!(s.n_tasks, n as usize + 1);
            assert_eq!(s.n_deps, n as usize);
            assert_eq!(s.longest_path, 1);
            assert!((s.avg_duration_ms - 0.006).abs() < 1e-9);
            assert!((s.avg_output_kib - 0.027).abs() < 0.005);
        }
    }

    #[test]
    fn merge_slow_duration() {
        let g = merge_slow(5_000, 100_000); // 100 ms tasks — Table I row AD=100
        let s = GraphStats::of(&g);
        assert_eq!(s.n_tasks, 5_001);
        assert_eq!(s.n_deps, 5_000);
        assert!((s.avg_duration_ms - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_sink_consumes_all() {
        let g = merge(10);
        assert_eq!(g.sinks().len(), 1);
        assert_eq!(g.roots().len(), 10);
        assert_eq!(longest_path(&g), 1);
    }
}
