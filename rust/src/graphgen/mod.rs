//! Generators for the paper's benchmark task graphs (§V, Table I).
//!
//! Each generator reproduces the *structure* (task count, dependency shape,
//! longest path) and the *cost statistics* (average task duration AD,
//! average output size S) of the corresponding Dask program. The server and
//! schedulers only ever observe graph structure + costs, so matching Table I
//! is what makes the reproduction faithful — see DESIGN.md §1.
//!
//! Families:
//! - [`merge()`]/[`merge_slow`] — n independent tasks merged at the end
//! - [`tree()`] — binary tree reduction of 2^n numbers
//! - [`xarray()`] — chunked 3-D grid aggregation (mean/sum of air temps)
//! - [`bag()`] — cartesian product + filter + fold
//! - [`numpy()`] — distributed transpose + add + reduce
//! - [`groupby()`]/[`join`] — partitioned table groupby / self-join
//! - [`vectorizer`]/[`wordbag`] — text feature hashing / full text pipeline
//!
//! [`parse`] turns a spec string (`"merge-25000"`, `"groupby-90-1s-1h"`)
//! into a graph; [`paper_suite`] returns the paper's full benchmark set.
//! [`split_incremental`]/[`with_cores`]/[`dynamic_suite`] derive
//! incremental-submission and multi-core variants of any graph (PR 9).

mod bag;
mod groupby;
mod incremental;
mod merge;
mod numpy;
mod suite;
mod tree;
mod text;
mod xarray;

pub use bag::bag;
pub use groupby::{groupby, join};
pub use incremental::{dynamic_suite, split_incremental, with_cores, DynamicEntry};
pub use merge::{merge, merge_slow};
pub use numpy::numpy;
pub use suite::{concurrent, paper_suite, suite_subset_zero_worker, SuiteEntry, CONCURRENT_MIX_DEFAULT};
pub use text::{vectorizer, wordbag};
pub use tree::tree;
pub use xarray::xarray;

use crate::taskgraph::TaskGraph;

#[derive(Debug, thiserror::Error)]
pub enum ParseError {
    #[error("unknown benchmark family in {0:?}")]
    UnknownFamily(String),
    #[error("bad parameters in {spec:?}: {reason}")]
    BadParams { spec: String, reason: String },
}

fn param<T: std::str::FromStr>(spec: &str, part: Option<&str>, what: &str) -> Result<T, ParseError> {
    part.ok_or_else(|| ParseError::BadParams { spec: spec.into(), reason: format!("missing {what}") })?
        .parse()
        .map_err(|_| ParseError::BadParams { spec: spec.into(), reason: format!("invalid {what}") })
}

/// Parse a duration-ish suffix: `10`, `10ms`, `1s`, `100us` → µs.
fn parse_dur_us(spec: &str, s: &str) -> Result<u64, ParseError> {
    let (num, mult) = if let Some(x) = s.strip_suffix("ms") {
        (x, 1_000)
    } else if let Some(x) = s.strip_suffix("us") {
        (x, 1)
    } else if let Some(x) = s.strip_suffix('s') {
        (x, 1_000_000)
    } else {
        (s, 1_000) // bare number = milliseconds (paper's merge_slow-n-t uses seconds-scale t; suite spells units)
    };
    let v: f64 = num.parse().map_err(|_| ParseError::BadParams {
        spec: spec.into(),
        reason: format!("invalid duration {s:?}"),
    })?;
    Ok((v * mult as f64) as u64)
}

/// Build a benchmark graph from a spec string.
///
/// Grammar (case-insensitive family name, `-`-separated params):
/// `merge-N` | `merge_slow-N-T` | `tree-N` | `xarray-N` | `bag-N-P` |
/// `numpy-N-P` | `groupby-D-F-P` | `join-D-F-P` | `vectorizer-N-P` |
/// `wordbag-N-P`. `T`/`F`/`P`(time) accept `us`/`ms`/`s` suffixes.
pub fn parse(spec: &str) -> Result<TaskGraph, ParseError> {
    let mut it = spec.split('-');
    let family = it
        .next()
        .ok_or_else(|| ParseError::UnknownFamily(spec.into()))?
        .to_ascii_lowercase();
    let p1 = it.next();
    let p2 = it.next();
    let p3 = it.next();
    match family.as_str() {
        "merge" => Ok(merge(param(spec, p1, "n")?)),
        "merge_slow" | "mergeslow" => {
            let n = param(spec, p1, "n")?;
            let t = parse_dur_us(spec, p1.and(p2).ok_or_else(|| ParseError::BadParams {
                spec: spec.into(),
                reason: "missing t".into(),
            })?)?;
            Ok(merge_slow(n, t))
        }
        "tree" => Ok(tree(param(spec, p1, "n")?)),
        "xarray" => Ok(xarray(param(spec, p1, "n")?)),
        "bag" => Ok(bag(param(spec, p1, "n")?, param(spec, p2, "p")?)),
        "numpy" => Ok(numpy(param(spec, p1, "n")?, param(spec, p2, "p")?)),
        "groupby" => {
            let d: u32 = param(spec, p1, "days")?;
            let f = parse_dur_us(spec, p2.ok_or_else(|| missing(spec, "f"))?)?;
            let p = parse_time_h(spec, p3.ok_or_else(|| missing(spec, "p"))?)?;
            Ok(groupby(d, f, p))
        }
        "join" => {
            let d: u32 = param(spec, p1, "days")?;
            let f = parse_dur_us(spec, p2.ok_or_else(|| missing(spec, "f"))?)?;
            let p = parse_time_h(spec, p3.ok_or_else(|| missing(spec, "p"))?)?;
            Ok(join(d, f, p))
        }
        "vectorizer" => Ok(vectorizer(param(spec, p1, "n")?, param(spec, p2, "p")?)),
        "wordbag" => Ok(wordbag(param(spec, p1, "n")?, param(spec, p2, "p")?)),
        _ => Err(ParseError::UnknownFamily(spec.into())),
    }
}

fn missing(spec: &str, what: &str) -> ParseError {
    ParseError::BadParams { spec: spec.into(), reason: format!("missing {what}") }
}

/// Parse a partition window like `16h` / `1h` / `30m` → hours (f64).
fn parse_time_h(spec: &str, s: &str) -> Result<f64, ParseError> {
    let (num, mult) = if let Some(x) = s.strip_suffix('h') {
        (x, 1.0)
    } else if let Some(x) = s.strip_suffix('m') {
        (x, 1.0 / 60.0)
    } else {
        (s, 1.0)
    };
    let v: f64 = num.parse().map_err(|_| ParseError::BadParams {
        spec: spec.into(),
        reason: format!("invalid window {s:?}"),
    })?;
    Ok(v * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_families() {
        for spec in [
            "merge-100",
            "merge_slow-50-10ms",
            "tree-6",
            "xarray-25",
            "bag-1000-10",
            "numpy-1000-4",
            "groupby-30-1s-8h",
            "join-30-1s-8h",
            "vectorizer-300-50",
            "wordbag-250-50",
        ] {
            let g = parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(!g.is_empty(), "{spec} produced empty graph");
        }
    }

    #[test]
    fn parse_errors() {
        assert!(matches!(parse("bogus-1"), Err(ParseError::UnknownFamily(_))));
        assert!(matches!(parse("merge-xyz"), Err(ParseError::BadParams { .. })));
        assert!(matches!(parse("merge_slow-10"), Err(ParseError::BadParams { .. })));
    }

    #[test]
    fn duration_suffixes() {
        assert_eq!(parse_dur_us("x", "10ms").unwrap(), 10_000);
        assert_eq!(parse_dur_us("x", "1s").unwrap(), 1_000_000);
        assert_eq!(parse_dur_us("x", "250us").unwrap(), 250);
        assert_eq!(parse_dur_us("x", "5").unwrap(), 5_000);
    }
}
