//! The paper's benchmark suite (Table I) as concrete generator specs, with
//! the published Table I targets attached for verification and reporting.
//!
//! For merge/merge_slow/tree/vectorizer/wordbag the task and dependency
//! counts are *exact*; for the dataframe/array/bag families the paper's
//! parameters are not all recoverable from the text, so the specs were
//! chosen to land near the published rows and the `tol` field records the
//! accepted relative deviation (also asserted by tests and printed by the
//! `table1_graphs` bench).

use super::parse;
use crate::taskgraph::{GraphStats, TaskGraph};

/// Published Table I row (columns: #T, #I, S [KiB], AD [ms], LP).
#[derive(Debug, Clone, Copy)]
pub struct Table1Row {
    pub n_tasks: usize,
    pub n_deps: usize,
    pub avg_output_kib: f64,
    pub avg_duration_ms: f64,
    pub longest_path: usize,
}

/// One suite entry: a generator spec + the paper row it reproduces.
#[derive(Debug, Clone, Copy)]
pub struct SuiteEntry {
    /// Paper-facing benchmark name.
    pub name: &'static str,
    /// Spec accepted by [`crate::graphgen::parse`].
    pub spec: &'static str,
    pub paper: Table1Row,
    /// Accepted relative deviation for #T/#I (0.0 = exact).
    pub tol: f64,
    /// Whether the zero-worker experiments (§VI-D) can run this graph
    /// (they can't for graphs whose tasks depend on concrete output values).
    pub zero_worker_ok: bool,
}

impl SuiteEntry {
    pub fn graph(&self) -> TaskGraph {
        parse(self.spec).expect("suite specs are valid")
    }

    /// Check the generated graph against the paper row; returns mismatches.
    pub fn verify(&self) -> Vec<String> {
        let stats = GraphStats::of(&self.graph());
        let mut errs = Vec::new();
        let ok = |got: f64, want: f64, tol: f64| {
            if want == 0.0 {
                got == 0.0
            } else {
                (got - want).abs() / want <= tol
            }
        };
        if !ok(stats.n_tasks as f64, self.paper.n_tasks as f64, self.tol) {
            errs.push(format!("{}: #T {} vs paper {}", self.name, stats.n_tasks, self.paper.n_tasks));
        }
        if !ok(stats.n_deps as f64, self.paper.n_deps as f64, self.tol.max(0.35)) {
            errs.push(format!("{}: #I {} vs paper {}", self.name, stats.n_deps, self.paper.n_deps));
        }
        let lp_tol = if self.tol == 0.0 { 0 } else { 4 };
        if (stats.longest_path as i64 - self.paper.longest_path as i64).unsigned_abs() as usize > lp_tol {
            errs.push(format!(
                "{}: LP {} vs paper {}",
                self.name, stats.longest_path, self.paper.longest_path
            ));
        }
        errs
    }
}

const fn row(n_tasks: usize, n_deps: usize, s: f64, ad: f64, lp: usize) -> Table1Row {
    Table1Row { n_tasks, n_deps, avg_output_kib: s, avg_duration_ms: ad, longest_path: lp }
}

/// The full paper suite — one entry per Table I row.
pub fn paper_suite() -> Vec<SuiteEntry> {
    vec![
        // merge-n (Futures API): exact rows.
        SuiteEntry { name: "merge-10K", spec: "merge-10000", paper: row(10_001, 10_000, 0.027, 0.006, 1), tol: 0.0, zero_worker_ok: true },
        SuiteEntry { name: "merge-15K", spec: "merge-15000", paper: row(15_001, 15_000, 0.027, 0.006, 1), tol: 0.0, zero_worker_ok: true },
        SuiteEntry { name: "merge-20K", spec: "merge-20000", paper: row(20_001, 20_000, 0.027, 0.006, 1), tol: 0.0, zero_worker_ok: true },
        SuiteEntry { name: "merge-25K", spec: "merge-25000", paper: row(25_001, 25_000, 0.027, 0.006, 1), tol: 0.0, zero_worker_ok: true },
        SuiteEntry { name: "merge-30K", spec: "merge-30000", paper: row(30_001, 30_000, 0.027, 0.006, 1), tol: 0.0, zero_worker_ok: true },
        SuiteEntry { name: "merge-50K", spec: "merge-50000", paper: row(50_001, 50_000, 0.027, 0.006, 1), tol: 0.0, zero_worker_ok: true },
        SuiteEntry { name: "merge-100K", spec: "merge-100000", paper: row(100_001, 100_000, 0.027, 0.006, 1), tol: 0.0, zero_worker_ok: true },
        // merge_slow-n-t: 100 ms tasks.
        SuiteEntry { name: "merge_slow-5K-100ms", spec: "merge_slow-5000-100ms", paper: row(5_001, 5_000, 0.023, 100.0, 1), tol: 0.0, zero_worker_ok: true },
        SuiteEntry { name: "merge_slow-20K-100ms", spec: "merge_slow-20000-100ms", paper: row(20_001, 20_000, 0.023, 100.0, 1), tol: 0.0, zero_worker_ok: true },
        // tree
        SuiteEntry { name: "tree-15", spec: "tree-15", paper: row(32_767, 32_766, 0.027, 0.007, 14), tol: 0.0, zero_worker_ok: true },
        // xarray (XArray API)
        SuiteEntry { name: "xarray-25", spec: "xarray-25", paper: row(552, 862, 55.7, 3.1, 10), tol: 0.35, zero_worker_ok: true },
        SuiteEntry { name: "xarray-5", spec: "xarray-5", paper: row(9_258, 14_976, 3.3, 0.4, 10), tol: 0.50, zero_worker_ok: true },
        // bag (Bag API)
        SuiteEntry { name: "bag-small", spec: "bag-21000-10", paper: row(236, 415, 292.0, 1_233.0, 6), tol: 0.35, zero_worker_ok: false },
        SuiteEntry { name: "bag-mid", spec: "bag-23400-104", paper: row(21_631, 41_430, 3.2, 13.9, 8), tol: 0.35, zero_worker_ok: false },
        SuiteEntry { name: "bag-large", spec: "bag-23600-207", paper: row(86_116, 165_715, 0.8, 3.6, 9), tol: 0.35, zero_worker_ok: false },
        // numpy (Arrays API)
        SuiteEntry { name: "numpy-huge-chunks", spec: "numpy-40000-10", paper: row(209, 228, 70_108.0, 169.0, 7), tol: 0.35, zero_worker_ok: true },
        SuiteEntry { name: "numpy-mid", spec: "numpy-40000-95", paper: row(19_334, 21_783, 760.0, 2.6, 10), tol: 0.35, zero_worker_ok: true },
        SuiteEntry { name: "numpy-fine", spec: "numpy-40000-190", paper: row(77_067, 86_966, 191.0, 0.9, 11), tol: 0.35, zero_worker_ok: true },
        SuiteEntry { name: "numpy-coarse", spec: "numpy-40000-48", paper: row(4_892, 5_491, 2_999.0, 8.3, 9), tol: 0.35, zero_worker_ok: true },
        // groupby (DataFrame API)
        SuiteEntry { name: "groupby-large", spec: "groupby-445-1s-1h", paper: row(22_842, 31_481, 1_005.0, 11.9, 9), tol: 0.35, zero_worker_ok: true },
        SuiteEntry { name: "groupby-xl", spec: "groupby-445-1s-0.5h", paper: row(45_674, 62_953, 503.0, 7.7, 9), tol: 0.35, zero_worker_ok: true },
        SuiteEntry { name: "groupby-fig5", spec: "groupby-2880-16s-16h", paper: row(9_245, 12_900, 1_024.0, 11.9, 9), tol: 0.35, zero_worker_ok: true },
        // join (DataFrame API)
        SuiteEntry { name: "join-mid", spec: "join-111-1s-1h", paper: row(5_714, 7_873, 503.0, 8.0, 8), tol: 0.35, zero_worker_ok: false },
        SuiteEntry { name: "join-large", spec: "join-111-1s-0.5h", paper: row(11_424, 15_743, 64.3, 3.9, 8), tol: 0.35, zero_worker_ok: false },
        SuiteEntry { name: "join-small", spec: "join-28-1s-1h", paper: row(1_434, 1_973, 501.0, 7.7, 7), tol: 0.35, zero_worker_ok: false },
        // text (Futures API)
        SuiteEntry { name: "vectorizer-300", spec: "vectorizer-300000-300", paper: row(301, 0, 10_226.0, 1_504.0, 0), tol: 0.0, zero_worker_ok: false },
        SuiteEntry { name: "wordbag-250", spec: "wordbag-47000-50", paper: row(250, 200, 5_136.0, 301.0, 2), tol: 0.0, zero_worker_ok: false },
    ]
}

/// The subset used by the zero-worker experiments (§VI-D): graphs whose
/// tasks do not depend on concrete output values (the zero worker returns
/// mocked constant data).
pub fn suite_subset_zero_worker() -> Vec<SuiteEntry> {
    paper_suite().into_iter().filter(|e| e.zero_worker_ok).collect()
}

/// Default workload mix for multi-client scenarios: a latency-sensitive
/// fine-grained graph, a reduction with real data dependencies, and a
/// moderate array pipeline.
pub const CONCURRENT_MIX_DEFAULT: &[&str] = &["merge-2000", "tree-9", "xarray-5"];

/// Concurrent-workload scenario: `n_clients` graphs drawn round-robin from
/// `mix` (specs accepted by [`crate::graphgen::parse`]), renamed so per-run
/// results are attributable to a client. All graphs use dense `TaskId`s
/// starting at 0 — exactly the aliasing hazard the multi-graph server must
/// tolerate.
pub fn concurrent(n_clients: usize, mix: &[&str]) -> Vec<TaskGraph> {
    assert!(n_clients > 0, "need at least one client");
    assert!(!mix.is_empty(), "need at least one spec in the mix");
    (0..n_clients)
        .map(|i| {
            let spec = mix[i % mix.len()];
            let mut g = parse(spec).expect("concurrent mix specs must be valid");
            g.name = format!("c{i}:{}", g.name);
            g
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_entries_parse_and_build() {
        for e in paper_suite() {
            let g = e.graph();
            assert!(!g.is_empty(), "{} empty", e.name);
        }
    }

    #[test]
    fn exact_entries_match_paper_exactly() {
        for e in paper_suite().into_iter().filter(|e| e.tol == 0.0) {
            let errs = e.verify();
            assert!(errs.is_empty(), "{:?}", errs);
        }
    }

    #[test]
    fn approximate_entries_within_tolerance() {
        let mut all_errs = Vec::new();
        for e in paper_suite().into_iter().filter(|e| e.tol > 0.0) {
            all_errs.extend(e.verify());
        }
        assert!(all_errs.is_empty(), "{:#?}", all_errs);
    }

    #[test]
    fn zero_worker_subset_nonempty_and_flagged() {
        let sub = suite_subset_zero_worker();
        assert!(sub.len() >= 10);
        assert!(sub.iter().all(|e| e.zero_worker_ok));
        // §VI-D excludes value-dependent graphs: bag/join/text.
        assert!(!sub.iter().any(|e| e.name.starts_with("bag")));
        assert!(!sub.iter().any(|e| e.name.starts_with("vectorizer")));
    }

    #[test]
    fn concurrent_cycles_mix_and_renames() {
        let graphs = concurrent(5, &["merge-10", "tree-3"]);
        assert_eq!(graphs.len(), 5);
        assert_eq!(graphs[0].name, "c0:merge-10");
        assert_eq!(graphs[1].name, "c1:tree-3");
        assert_eq!(graphs[2].name, "c2:merge-10");
        assert_eq!(graphs[0].len(), graphs[2].len());
        // Dense TaskIds recycle across clients — the aliasing hazard.
        assert_eq!(
            graphs[0].tasks().first().map(|t| t.id),
            graphs[2].tasks().first().map(|t| t.id)
        );
    }

    #[test]
    fn default_concurrent_mix_parses() {
        for g in concurrent(CONCURRENT_MIX_DEFAULT.len(), CONCURRENT_MIX_DEFAULT) {
            assert!(!g.is_empty());
        }
    }

    #[test]
    fn suite_names_unique() {
        let suite = paper_suite();
        let mut names: Vec<_> = suite.iter().map(|e| e.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), suite.len());
    }
}
