//! `tree-n` — binary tree reduction of 2^n numbers (§V).
//!
//! Leaf tasks each combine two numbers (2^(n-1) leaves), interior tasks
//! combine two child results, so #T = 2^n − 1, #I = 2^n − 2, LP = n − 1.
//! Table I (tree-15): #T = 32767, #I = 32766, LP = 14, AD ≈ 0.007 ms.

use crate::taskgraph::{GraphBuilder, Payload, TaskGraph, TaskId};

pub const TREE_TASK_US: u64 = 7;
pub const TREE_OUTPUT_BYTES: u64 = 28;

/// Binary tree reduction of 2^n numbers; `n ≥ 1`.
pub fn tree(n: u32) -> TaskGraph {
    assert!((1..=26).contains(&n), "tree-n supports 1..=26, got {n}");
    let mut b = GraphBuilder::new();
    // Level 0: 2^(n-1) leaf tasks, each reducing two raw numbers.
    let mut level: Vec<TaskId> = (0..(1u64 << (n - 1)))
        .map(|i| b.add(format!("leaf-{i}"), vec![], TREE_TASK_US, TREE_OUTPUT_BYTES, Payload::BusyWait))
        .collect();
    let mut depth = 1;
    while level.len() > 1 {
        level = level
            .chunks(2)
            .enumerate()
            .map(|(i, pair)| {
                b.add(
                    format!("reduce-{depth}-{i}"),
                    pair.to_vec(),
                    TREE_TASK_US,
                    TREE_OUTPUT_BYTES,
                    Payload::MergeInputs,
                )
            })
            .collect();
        depth += 1;
    }
    b.build(format!("tree-{n}")).expect("tree graph is valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::GraphStats;

    #[test]
    fn matches_table1_tree15() {
        let g = tree(15);
        let s = GraphStats::of(&g);
        assert_eq!(s.n_tasks, 32_767);
        assert_eq!(s.n_deps, 32_766);
        assert_eq!(s.longest_path, 14);
        assert!((s.avg_duration_ms - 0.007).abs() < 1e-9);
    }

    #[test]
    fn small_trees() {
        // n=1: a single leaf reducing two numbers.
        let g = tree(1);
        assert_eq!(g.len(), 1);
        assert_eq!(g.n_deps(), 0);

        let g = tree(3);
        assert_eq!(g.len(), 7);
        assert_eq!(g.n_deps(), 6);
        assert_eq!(g.sinks().len(), 1);
        assert_eq!(g.roots().len(), 4);
    }

    #[test]
    fn every_interior_has_two_inputs() {
        let g = tree(6);
        for t in g.tasks() {
            assert!(t.inputs.len() == 0 || t.inputs.len() == 2);
        }
    }
}
