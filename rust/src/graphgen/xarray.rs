//! `xarray-n` — aggregations (mean, sum) over a chunked 3-D grid of air
//! temperatures (§V; the NCEP reanalysis dataset of the Dask examples).
//!
//! Structure mirrors the xarray/dask-array lowering: one `open` task per
//! chunk, an elementwise op per chunk, a fan-in tree reducing the time axis
//! per spatial chunk-column, a per-column finalize, and a final combine.
//! `n` is the chunk edge length: smaller n ⇒ more, smaller chunks — exactly
//! the partition-granularity knob the paper sweeps (xarray-25 ≈ 552 tasks,
//! xarray-5 ≈ 9k tasks).

use crate::taskgraph::{GraphBuilder, Payload, TaskGraph, TaskId};

/// Fan-in of the reduction tree (dask's `split_every` default-ish).
const SPLIT_EVERY: usize = 4;

pub fn xarray(n: u32) -> TaskGraph {
    assert!(n > 0);
    // Air-temperature grid: 2920 time steps, ~50 spatial tiles at n=1.
    let nt = (2920 / n).max(1) as usize; // time chunks
    let ns = (30 / n) as usize + 1; // spatial chunk columns (n=25 ⇒ 2, n=5 ⇒ 7)
    // Chunk compute cost and size scale with chunk area (~n²).
    let op_us = (48 * n as u64 * n as u64) / 10; // n=25: 3.0 ms; n=5: 120 µs
    let chunk_bytes = 90 * n as u64 * n as u64; // n=25: ~55 KiB; n=5: ~2.2 KiB
    let combine_us = (op_us / 4).max(1);

    let mut b = GraphBuilder::new();
    let mut col_results: Vec<TaskId> = Vec::with_capacity(ns);
    for s in 0..ns {
        // Per-column climatology (the mean each anomaly subtracts); having
        // every anomaly consume it reproduces the dense dependency pattern
        // of the xarray lowering (Table I: #I/#T ≈ 1.56).
        let clim = b.add(
            format!("clim-{s}"),
            vec![],
            (op_us / 3).max(1),
            chunk_bytes / 4,
            Payload::BusyWait,
        );
        // open + elementwise op per time chunk of this column
        let ops: Vec<TaskId> = (0..nt)
            .map(|t| {
                let open = b.add(
                    format!("open-{s}-{t}"),
                    vec![],
                    (op_us / 3).max(1),
                    chunk_bytes,
                    Payload::BusyWait,
                );
                b.add(
                    format!("anom-{s}-{t}"),
                    vec![clim, open],
                    op_us,
                    chunk_bytes,
                    Payload::HloReduce {
                        rows: (8 * n).max(8),
                        cols: 128,
                        seed: (s * nt + t) as u64,
                    },
                )
            })
            .collect();
        // tree-reduce the time axis
        let mut level = ops;
        let mut depth = 0;
        while level.len() > 1 {
            depth += 1;
            level = level
                .chunks(SPLIT_EVERY)
                .enumerate()
                .map(|(i, chunk)| {
                    b.add(
                        format!("comb-{s}-{depth}-{i}"),
                        chunk.to_vec(),
                        combine_us,
                        chunk_bytes / 2,
                        Payload::MergeInputs,
                    )
                })
                .collect();
        }
        let mean = b.add(
            format!("mean-{s}"),
            vec![level[0]],
            combine_us,
            chunk_bytes / 2,
            Payload::MergeInputs,
        );
        col_results.push(mean);
    }
    // combine spatial columns (mean + sum aggregations)
    let sum = b.add("sum", col_results.clone(), combine_us, 1024, Payload::MergeInputs);
    col_results.push(sum);
    b.add("agg", col_results, combine_us, 256, Payload::MergeInputs);
    b.build(format!("xarray-{n}")).expect("xarray graph valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::GraphStats;

    #[test]
    fn xarray25_near_table1() {
        // Table I: 552 tasks, 862 deps, S 55.7 KiB, AD 3.1 ms, LP 10.
        let s = GraphStats::of(&xarray(25));
        assert!((400..=750).contains(&s.n_tasks), "tasks {}", s.n_tasks);
        assert!((600..=1200).contains(&s.n_deps), "deps {}", s.n_deps);
        assert!((6..=13).contains(&s.longest_path), "lp {}", s.longest_path);
        assert!((1.5..=4.5).contains(&s.avg_duration_ms), "ad {}", s.avg_duration_ms);
        assert!((25.0..=80.0).contains(&s.avg_output_kib), "s {}", s.avg_output_kib);
    }

    #[test]
    fn xarray5_finer_partitions_grow_graph() {
        let s5 = GraphStats::of(&xarray(5));
        let s25 = GraphStats::of(&xarray(25));
        // Table I: 9258 vs 552 tasks (~17×); accept 10–30×.
        let ratio = s5.n_tasks as f64 / s25.n_tasks as f64;
        assert!((10.0..=30.0).contains(&ratio), "ratio {ratio}");
        // Finer partitions ⇒ smaller & faster tasks.
        assert!(s5.avg_duration_ms < s25.avg_duration_ms / 4.0);
        assert!(s5.avg_output_kib < s25.avg_output_kib / 4.0);
    }

    #[test]
    fn single_sink() {
        let g = xarray(25);
        assert_eq!(g.sinks().len(), 1);
        assert!(g.needs_runtime(), "xarray uses the Pallas reduce kernel");
    }
}
