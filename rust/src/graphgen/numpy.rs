//! `numpy-n-p` — dask.array workload: transpose and aggregate a distributed
//! (n, n) array split into a p×p grid of (n/p, n/p) chunks (§V).
//!
//! Structure mirrors dask.array's lowering of `(x + x.T).sum()`:
//! per-chunk create tasks, per-chunk transpose+add tasks (consuming the
//! mirrored chunk), fused per-chunk partial sums, and a fan-in reduction.

use crate::taskgraph::{GraphBuilder, Payload, TaskGraph, TaskId};

const REDUCE_FAN: usize = 8;

pub fn numpy(n: u32, p: u32) -> TaskGraph {
    assert!(p > 0 && n >= p);
    let pp = p as usize;
    let chunk = (n / p).max(1) as u64; // chunk edge
    let chunk_bytes = chunk * chunk * 8; // f64 elements
    // ~15 ns/element for transpose+add+partial-sum (calibrated to Table I's
    // AD column: numpy-mid chunk 421² ⇒ 2.7 ms ≈ paper's 2.6 ms), ≥1 µs.
    let op_us = ((chunk * chunk) as f64 * 0.015).max(1.0) as u64;

    let mut b = GraphBuilder::new();
    // create chunk (i, j)
    let mut creates = vec![vec![TaskId(0); pp]; pp];
    for i in 0..pp {
        for j in 0..pp {
            creates[i][j] = b.add(
                format!("create-{i}-{j}"),
                vec![],
                (op_us / 2).max(1),
                chunk_bytes,
                Payload::BusyWait,
            );
        }
    }
    // transpose+add+partial-sum of chunk (i, j) needs create(i,j) and create(j,i)
    let mut partials: Vec<TaskId> = Vec::with_capacity(pp * pp);
    for i in 0..pp {
        for j in 0..pp {
            let inputs = if i == j {
                vec![creates[i][j]]
            } else {
                vec![creates[i][j].min(creates[j][i]), creates[i][j].max(creates[j][i])]
            };
            partials.push(b.add(
                format!("tsum-{i}-{j}"),
                inputs,
                op_us,
                64, // a partial scalar sum
                Payload::HloTranspose { n: chunk.min(256) as u32, seed: (i * pp + j) as u64 },
            ));
        }
    }
    // fan-in reduction of p² partials
    let mut level = partials;
    let mut depth = 0;
    while level.len() > 1 {
        depth += 1;
        level = level
            .chunks(REDUCE_FAN)
            .enumerate()
            .map(|(k, c)| {
                b.add(format!("red-{depth}-{k}"), c.to_vec(), 2, 64, Payload::MergeInputs)
            })
            .collect();
    }
    b.build(format!("numpy-{n}-{p}")).expect("numpy graph valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::GraphStats;

    #[test]
    fn table1_small_row_shape() {
        // Table I (numpy small row): 209 tasks, 228 deps, LP 7, S huge (70 MiB).
        let s = GraphStats::of(&numpy(40_000, 10));
        assert!((180..=260).contains(&s.n_tasks), "tasks {}", s.n_tasks);
        assert!((190..=320).contains(&s.n_deps), "deps {}", s.n_deps);
        assert!((2..=9).contains(&s.longest_path), "lp {}", s.longest_path);
        // create tasks dominate size: chunk = 4000² × 8 B = 128 MB ⇒ avg tens of MiB
        assert!(s.avg_output_kib > 20_000.0, "S {}", s.avg_output_kib);
    }

    #[test]
    fn partials_depend_on_mirror_chunks() {
        let g = numpy(100, 4);
        // each off-diagonal tsum has 2 inputs, diagonal has 1
        let tsums: Vec<_> = g.tasks().iter().filter(|t| t.key.starts_with("tsum-")).collect();
        assert_eq!(tsums.len(), 16);
        let two = tsums.iter().filter(|t| t.inputs.len() == 2).count();
        let one = tsums.iter().filter(|t| t.inputs.len() == 1).count();
        assert_eq!(two, 12);
        assert_eq!(one, 4);
    }

    #[test]
    fn single_sink() {
        let g = numpy(1000, 7);
        assert_eq!(g.sinks().len(), 1);
        assert!(g.needs_runtime());
    }
}
