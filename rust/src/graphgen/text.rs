//! `vectorizer-n-p` / `wordbag-n-p` — Wordbatch-style text processing over a
//! reviews dataset (§V).
//!
//! `vectorizer` computes hashed features per partition: the paper's Table I
//! row shows **zero dependencies** (p+1 independent future tasks whose
//! results the client gathers directly), LP = 0, very heavy tasks (~1.5 s,
//! ~10 MiB outputs). `wordbag` is the full pipeline: per-partition read →
//! three processing stages (normalize / spell-correct / count+extract) →
//! per-partition aggregate; LP = 2.

use crate::taskgraph::{GraphBuilder, Payload, TaskGraph};

/// `n` reviews in `p` partitions; `p + 1` independent tasks.
pub fn vectorizer(n: u64, p: u32) -> TaskGraph {
    assert!(p > 0);
    let docs_per_part = (n as f64 / p as f64).max(1.0);
    // ~1.5 ms per review (hash + tokenize); Table I: AD ≈ 1.5 s at 1000
    // reviews/partition, output ≈ 10 MiB dense hashed feature block.
    let task_us = (docs_per_part * 1_500.0) as u64;
    let out_bytes = (docs_per_part * 10_240.0) as u64;

    let mut b = GraphBuilder::new();
    for i in 0..p {
        b.add(
            format!("vectorize-{i}"),
            vec![],
            task_us,
            out_bytes,
            Payload::HloHash {
                n_tokens: (docs_per_part as u32 * 64).max(64),
                buckets: 1 << 10,
                seed: i as u64,
            },
        );
    }
    // The paper's row has p+1 tasks with no dependencies (the +1 is the
    // client-side barrier future, also dependency-free on the server).
    b.add("barrier", vec![], 1_000, 64, Payload::NoOp);
    b.build(format!("vectorizer-{n}-{p}")).expect("vectorizer graph valid by construction")
}

/// Full text pipeline; `#T = 5p`, `#I = 4p`, LP = 2 (Table I: 250/200/2 at
/// p = 50). The three processing stages fan out from the read; feature
/// extraction consumes the word counts.
pub fn wordbag(n: u64, p: u32) -> TaskGraph {
    assert!(p > 0);
    let docs_per_part = (n as f64 / p as f64).max(1.0);
    let read_us = (docs_per_part * 200.0) as u64;
    let stage_us = (docs_per_part * 400.0) as u64;
    // ~14.5 KB of intermediate text data per review (Table I: S ≈ 5 MiB avg).
    let part_bytes = (docs_per_part * 14_500.0) as u64;

    let mut b = GraphBuilder::new();
    for i in 0..p {
        let read = b.add(format!("read-{i}"), vec![], read_us, part_bytes, Payload::BusyWait);
        let count = ["normalize", "spell", "count"]
            .iter()
            .map(|s| {
                b.add(
                    format!("{s}-{i}"),
                    vec![read],
                    stage_us,
                    part_bytes,
                    Payload::WordBag { n_docs: docs_per_part as u32, seed: i as u64 },
                )
            })
            .last()
            .expect("three stages");
        b.add(
            format!("features-{i}"),
            vec![count],
            stage_us / 2,
            part_bytes / 8,
            Payload::MergeInputs,
        );
    }
    b.build(format!("wordbag-{n}-{p}")).expect("wordbag graph valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::GraphStats;

    #[test]
    fn vectorizer_matches_table1() {
        // Table I: 301 tasks, 0 deps, LP 0, AD 1504 ms, S ≈ 10 MiB.
        let s = GraphStats::of(&vectorizer(300_000, 300));
        assert_eq!(s.n_tasks, 301);
        assert_eq!(s.n_deps, 0);
        assert_eq!(s.longest_path, 0);
        assert!((1_000.0..=2_000.0).contains(&s.avg_duration_ms), "ad {}", s.avg_duration_ms);
        assert!((7_000.0..=13_000.0).contains(&s.avg_output_kib), "s {}", s.avg_output_kib);
    }

    #[test]
    fn wordbag_matches_table1() {
        // Table I: 250 tasks, 200 deps, LP 2 (wordbag-..-50).
        let s = GraphStats::of(&wordbag(250, 50));
        assert_eq!(s.n_tasks, 250);
        assert_eq!(s.n_deps, 200);
        assert_eq!(s.longest_path, 2);
    }

    #[test]
    fn vectorizer_tasks_heavy_and_independent() {
        let g = vectorizer(300_000, 300);
        assert_eq!(g.roots().len(), 301);
        assert!(g.needs_runtime());
        // Table I: AD ≈ 1.5 s per task.
        let t = g.task(crate::taskgraph::TaskId(0));
        assert!((1_000_000..=2_500_000).contains(&t.duration_us), "dur {}", t.duration_us);
    }

    #[test]
    fn wordbag_per_partition_sinks() {
        let g = wordbag(250, 50);
        // Per partition: normalize + spell results are consumed client-side
        // (sinks), plus the features task — 3 sinks per partition.
        assert_eq!(g.sinks().len(), 150);
        assert_eq!(g.roots().len(), 50);
    }
}
