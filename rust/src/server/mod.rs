//! The RSDS central server (paper §IV).
//!
//! Split exactly as the paper's Figure 1: a [`Reactor`] that owns
//! connections, bookkeeping and protocol translation, and an isolated
//! [`crate::scheduler::Scheduler`] that only maps ready tasks to workers.
//! The reactor is a *pure state machine* (`on_message` in, `(Dest, Msg)`
//! out) so the integration tests and the simulator can drive it without
//! sockets; [`net::TcpServer`] wires it to real TCP for the distributed
//! runtime.
//!
//! Overhead emulation: constructed with the `python` profile and
//! `emulate = true`, the reactor busy-waits the calibrated CPython costs on
//! its own hot path — turning this binary into the paper's Dask-server
//! baseline on real sockets (DESIGN.md §5).

mod net;
mod pool;
mod reactor;
mod state;

pub use net::{serve, ServerConfig, ServerHandle};
pub use pool::{SchedulerFactory, SchedulerPool};
pub use reactor::{Dest, Origin, Reactor, ReactorReport};
pub use state::{GraphRun, RunIdAlloc, TaskState};
