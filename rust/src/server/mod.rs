//! The RSDS central server (paper §IV).
//!
//! Split exactly as the paper's Figure 1: a [`Reactor`] that owns
//! connections, bookkeeping and protocol translation, and an isolated
//! [`crate::scheduler::Scheduler`] that only maps ready tasks to workers.
//! The reactor is a *pure state machine* (`on_message` in, `(Dest, Msg)`
//! out) so the integration tests and the simulator can drive it without
//! sockets; [`serve`] wires it to real TCP for the distributed runtime.
//!
//! Resilience: worker disconnects are absorbed per run by lineage recovery
//! ([`GraphRun::recover`], orchestrated in the reactor) instead of failing
//! every run that touched the dead worker; see `docs/recovery.md`.
//!
//! Fairness & admission: worker-bound messages park on per-run outboxes
//! and [`Reactor::pump`] emits them in bounded rounds under a pluggable
//! [`FairnessPolicy`] (round-robin default), so one huge submission cannot
//! starve a small one; per-client live-run caps park excess submissions in
//! an admission queue (`run-queued`) until capacity frees. See
//! `docs/architecture.md` §"Fairness & admission".
//!
//! Overhead emulation: constructed with the `python` profile and
//! `emulate = true`, the reactor busy-waits the calibrated CPython costs on
//! its own hot path — turning this binary into the paper's Dask-server
//! baseline on real sockets (DESIGN.md §5).
//!
//! Ownership and threading: all scheduling and bookkeeping state —
//! [`GraphRun`]s, the [`SchedulerPool`], worker metadata — is owned by
//! exactly one *shard* thread and never locked. Each shard runs a
//! readiness-driven epoll event loop ([`poll`]) over nonblocking sockets:
//! it reads frames, feeds its own reactor's `on_message`/`on_disconnect`,
//! and resumes partial writes on writability. Client connections are
//! hash-partitioned over the shards and their runs never leave the shard;
//! cross-shard traffic is confined to worker registration/death
//! broadcasts and pre-encoded frame forwarding over intra-server channels
//! (see `net.rs` for the transport discipline).

pub mod fairness;
mod net;
pub mod poll;
mod pool;
mod reactor;
mod state;
mod window;

pub use fairness::{FairnessPolicy, RunQueueStat, DEFAULT_DISPATCH_QUOTA};
pub use net::{serve, ServerConfig, ServerHandle};
// Verification surface: the forward-buffer machinery, exposed so the
// model-checking suite (`tests/loom_models.rs`) can drive it under the
// exhaustive scheduler. Not part of the stable server API.
pub use net::{deliver_forward, pool_get, pool_put, BufPool, BUF_POOL_MAX};
pub use pool::{SchedulerFactory, SchedulerPool};
pub use reactor::{
    ComputeDispatch, ComputeInputs, Dest, Origin, OutboundSink, Reactor, ReactorReport,
    SharedIds, DEFAULT_MAX_LIVE_RUNS_PER_CLIENT, DEFAULT_MAX_QUEUED_RUNS_PER_CLIENT,
    DEFAULT_REPLICATION_FANOUT, DEFAULT_REPORT_RETENTION,
};
pub use state::{
    GraphRun, Parked, RecoveryPlan, ReplicaSet, RunIdAlloc, TaskState, DEFAULT_MAX_RECOVERIES,
};
pub use window::BoundedWindow;
