//! Run-fairness policies for the reactor's outbound dispatch.
//!
//! The multi-graph reactor translates scheduler actions into worker-bound
//! messages *per run* and parks them on that run's outbox
//! ([`crate::server::GraphRun`]). Emission — the per-message encode/send
//! work that used to be drained in arrival order, letting a 100K-task
//! submission starve a 10-task one — happens in bounded *rounds*: each
//! round a [`FairnessPolicy`] picks one run among those with pending
//! messages and up to a quota of its messages go out
//! ([`crate::server::Reactor::pump`]). The discrete-event simulator
//! ([`crate::sim`]) services its virtual reactor with the same policies so
//! sim and TCP server stay behavior-comparable.
//!
//! Policies must be **order-independent**: the caller assembles `stats`
//! from a hash map, so two entries may arrive in any order. Every policy
//! here breaks ties on the run id, which is allocation-ordered and unique.

use crate::protocol::RunId;

/// Messages emitted per policy round. Small enough that a run with one
/// pending message waits at most `live_runs × quota` emissions; large
/// enough that batching (one writer hand-off per round) stays effective.
pub const DEFAULT_DISPATCH_QUOTA: usize = 32;

/// One run's dispatch-queue state, as offered to a policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunQueueStat {
    pub run: RunId,
    /// Parked worker-bound messages in this run's outbox (always > 0).
    pub pending: usize,
    /// Unfinished tasks of the run — the weighting input.
    pub remaining: u64,
    /// Monotonic tick stamped when the outbox last became non-empty;
    /// the arrival order across queue activations.
    pub since: u64,
}

/// Picks which run's outbox the reactor services next.
pub trait FairnessPolicy: Send {
    fn name(&self) -> &'static str;

    /// Choose a run from `stats` (never empty; every entry has
    /// `pending > 0`). Must return the `run` of one of the entries and
    /// must not depend on the slice order.
    fn pick(&mut self, stats: &[RunQueueStat]) -> RunId;
}

/// The pre-fairness baseline: service queues strictly in the order they
/// became non-empty, each to exhaustion. A large run's backlog therefore
/// starves later arrivals — kept as the control arm of `fig_fairness`.
#[derive(Debug, Default)]
pub struct ArrivalOrder;

impl FairnessPolicy for ArrivalOrder {
    fn name(&self) -> &'static str {
        "arrival"
    }

    fn pick(&mut self, stats: &[RunQueueStat]) -> RunId {
        stats
            .iter()
            .min_by_key(|s| (s.since, s.run))
            .expect("stats is never empty")
            .run
    }
}

/// Round-robin over run ids (default): rotate through the pending runs in
/// id order. Guarantees bounded progress — a run with pending messages is
/// serviced within `live_runs` rounds, which the starvation proptest
/// asserts over random interleavings.
#[derive(Debug, Default)]
pub struct RoundRobin {
    /// Last serviced run; the rotation resumes strictly after it.
    cursor: Option<RunId>,
}

impl FairnessPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn pick(&mut self, stats: &[RunQueueStat]) -> RunId {
        let after = self.cursor;
        let next = stats
            .iter()
            .filter(|s| after.map(|c| s.run > c).unwrap_or(true))
            .map(|s| s.run)
            .min()
            .or_else(|| stats.iter().map(|s| s.run).min())
            .expect("stats is never empty");
        self.cursor = Some(next);
        next
    }
}

/// Weighted by remaining tasks: always service the run closest to
/// completion (shortest-remaining-first, ties by run id). Minimizes
/// small-run latency under a large background run even harder than
/// round-robin; with a finite backlog nothing starves (the served run's
/// queue drains, then the next-smallest is served), but a large run makes
/// progress only when no smaller run has pending messages — the
/// documented trade-off `fig_fairness` quantifies.
#[derive(Debug, Default)]
pub struct WeightedByRemaining;

impl FairnessPolicy for WeightedByRemaining {
    fn name(&self) -> &'static str {
        "weighted"
    }

    fn pick(&mut self, stats: &[RunQueueStat]) -> RunId {
        stats
            .iter()
            .min_by_key(|s| (s.remaining, s.run))
            .expect("stats is never empty")
            .run
    }
}

/// Construct a policy by CLI/config name.
pub fn by_name(name: &str) -> Option<Box<dyn FairnessPolicy>> {
    match name {
        "arrival" | "arrival-order" => Some(Box::<ArrivalOrder>::default()),
        "rr" | "round-robin" => Some(Box::<RoundRobin>::default()),
        "weighted" | "weighted-remaining" => Some(Box::<WeightedByRemaining>::default()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(run: u32, pending: usize, remaining: u64, since: u64) -> RunQueueStat {
        RunQueueStat { run: RunId(run), pending, remaining, since }
    }

    #[test]
    fn by_name_constructs_all_and_rejects_unknown() {
        for n in ["arrival", "rr", "round-robin", "weighted"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("fifo").is_none());
    }

    #[test]
    fn arrival_order_is_fifo_by_activation() {
        let mut p = ArrivalOrder;
        let stats = [stat(3, 1, 10, 7), stat(1, 100, 1, 2), stat(2, 5, 5, 4)];
        assert_eq!(p.pick(&stats), RunId(1));
        // Order-independence: a permutation picks the same run.
        let rev = [stats[2], stats[0], stats[1]];
        assert_eq!(p.pick(&rev), RunId(1));
    }

    #[test]
    fn round_robin_rotates_and_wraps() {
        let mut p = RoundRobin::default();
        let stats = [stat(0, 1, 1, 0), stat(2, 1, 1, 1), stat(5, 1, 1, 2)];
        assert_eq!(p.pick(&stats), RunId(0));
        assert_eq!(p.pick(&stats), RunId(2));
        assert_eq!(p.pick(&stats), RunId(5));
        assert_eq!(p.pick(&stats), RunId(0), "wraps to the smallest id");
        // A run draining out of the rotation is skipped transparently.
        let fewer = [stat(0, 1, 1, 0), stat(5, 1, 1, 2)];
        assert_eq!(p.pick(&fewer), RunId(5));
    }

    #[test]
    fn round_robin_bounded_gap() {
        // Every pending run is serviced within `stats.len()` rounds.
        let mut p = RoundRobin::default();
        let stats: Vec<RunQueueStat> =
            (0..5).map(|i| stat(i * 3, 1, 1, i as u64)).collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..stats.len() {
            seen.insert(p.pick(&stats));
        }
        assert_eq!(seen.len(), stats.len(), "one full rotation covers every run");
    }

    #[test]
    fn weighted_prefers_near_completion() {
        let mut p = WeightedByRemaining;
        let stats = [stat(0, 500, 10_000, 0), stat(1, 3, 11, 5), stat(2, 3, 11, 6)];
        assert_eq!(p.pick(&stats), RunId(1), "fewest remaining, ties by id");
    }
}
