//! Server-side bookkeeping: the task state machine and per-graph run state.

use crate::protocol::RunId;
use crate::scheduler::WorkerId;
use crate::taskgraph::{TaskGraph, TaskId};
use std::collections::HashMap;

/// Server-side lifecycle of a task (reactor's view).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskState {
    /// Unfinished dependencies remain.
    Waiting,
    /// Handed to the scheduler, no assignment yet sent.
    Ready,
    /// Compute message sent to this worker.
    Assigned(WorkerId),
    /// Retraction in flight: assigned to `from`, destined for `to`.
    Stealing { from: WorkerId, to: WorkerId },
    /// Finished on this worker (first finisher; replicas tracked in
    /// `who_has`).
    Finished(WorkerId),
    /// Worker reported an error.
    Erred,
}

/// Execution state of one submitted graph. The reactor keeps one `GraphRun`
/// per live [`RunId`]; everything in here is private to that run, so
/// concurrent graphs can never alias each other's `TaskId`s.
#[derive(Debug)]
pub struct GraphRun {
    pub graph: TaskGraph,
    pub client: u32,
    pub states: Vec<TaskState>,
    /// Remaining unfinished dependency count per task.
    pub unfinished_deps: Vec<u32>,
    /// Tasks not yet finished.
    pub remaining: usize,
    /// Wall-clock µs timestamp (from the reactor's stopwatch) at submit.
    pub submitted_at_us: u64,
    /// Workers holding each task's output (first = producer).
    pub who_has: Vec<Vec<WorkerId>>,
    /// Priority each task was last assigned with (scheduler-chosen; needed
    /// to re-send the *same* priority after a successful retraction).
    pub priorities: Vec<i64>,
    /// Steals whose target state was overwritten by a racing finish before
    /// the `StealResponse` arrived: task → the original `(from, to)`. The
    /// response handler consumes this so the scheduler learns the true
    /// endpoints of the failed steal.
    pub raced_steals: HashMap<TaskId, (WorkerId, WorkerId)>,
    // Per-run counters (reported in `ReactorReport`).
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub msgs_in: u64,
    pub msgs_out: u64,
}

impl GraphRun {
    pub fn new(graph: TaskGraph, client: u32, now_us: u64) -> GraphRun {
        let n = graph.len();
        let unfinished_deps: Vec<u32> = graph.tasks().iter().map(|t| t.inputs.len() as u32).collect();
        let states = unfinished_deps
            .iter()
            .map(|&d| if d == 0 { TaskState::Ready } else { TaskState::Waiting })
            .collect();
        GraphRun {
            graph,
            client,
            states,
            unfinished_deps,
            remaining: n,
            submitted_at_us: now_us,
            who_has: vec![Vec::new(); n],
            priorities: (0..n as i64).collect(),
            raced_steals: HashMap::new(),
            steals_attempted: 0,
            steals_failed: 0,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    /// Initially ready tasks (the graph roots).
    pub fn ready_roots(&self) -> Vec<TaskId> {
        self.graph.roots()
    }

    /// Mark `task` finished on `worker`; returns consumers that became
    /// ready. Idempotent against duplicate finish reports (a steal race can
    /// produce one) — the second report is ignored.
    pub fn finish(&mut self, task: TaskId, worker: WorkerId) -> Vec<TaskId> {
        if matches!(self.states[task.idx()], TaskState::Finished(_)) {
            self.who_has[task.idx()].push(worker);
            return Vec::new();
        }
        // A finish that beats an in-flight retraction must keep the steal's
        // endpoints around for the late `StealResponse` (see the reactor).
        if let TaskState::Stealing { from, to } = self.states[task.idx()] {
            self.raced_steals.insert(task, (from, to));
        }
        self.states[task.idx()] = TaskState::Finished(worker);
        self.who_has[task.idx()].push(worker);
        self.remaining -= 1;
        let mut newly_ready = Vec::new();
        for &c in self.graph.consumers(task) {
            let d = &mut self.unfinished_deps[c.idx()];
            debug_assert!(*d > 0);
            *d -= 1;
            if *d == 0 {
                debug_assert_eq!(self.states[c.idx()], TaskState::Waiting);
                self.states[c.idx()] = TaskState::Ready;
                newly_ready.push(c);
            }
        }
        newly_ready
    }

    pub fn is_done(&self) -> bool {
        self.remaining == 0
    }

    /// Worker currently responsible for a task, if any.
    pub fn assigned_worker(&self, task: TaskId) -> Option<WorkerId> {
        match self.states[task.idx()] {
            TaskState::Assigned(w) => Some(w),
            TaskState::Stealing { from, .. } => Some(from),
            _ => None,
        }
    }

    /// All tasks currently assigned to `worker` (used on disconnect).
    pub fn tasks_on(&self, worker: WorkerId) -> Vec<TaskId> {
        self.states
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                TaskState::Assigned(w) if *w == worker => Some(TaskId(i as u32)),
                TaskState::Stealing { from, .. } if *from == worker => Some(TaskId(i as u32)),
                _ => None,
            })
            .collect()
    }

    /// Whether this run still depends on `worker`: tasks assigned to it,
    /// steals *from or to* it in flight (a dead steal target would strand
    /// the retraction's resend), or data stored on it.
    pub fn involves_worker(&self, worker: WorkerId) -> bool {
        self.states.iter().any(|s| {
            matches!(s, TaskState::Assigned(w) if *w == worker)
                || matches!(s, TaskState::Stealing { from, to }
                    if *from == worker || *to == worker)
        }) || self.who_has.iter().flatten().any(|&h| h == worker)
    }

    /// Per-worker tasks this run considers queued (assigned or mid-steal
    /// from that worker) — the reactor-side view the scheduler invariant
    /// tests compare against [`crate::scheduler::Scheduler::queued_tasks`].
    pub fn queued_by_worker(&self) -> HashMap<WorkerId, Vec<TaskId>> {
        let mut out: HashMap<WorkerId, Vec<TaskId>> = HashMap::new();
        for (i, s) in self.states.iter().enumerate() {
            let w = match s {
                TaskState::Assigned(w) => *w,
                TaskState::Stealing { from, .. } => *from,
                _ => continue,
            };
            out.entry(w).or_default().push(TaskId(i as u32));
        }
        for q in out.values_mut() {
            q.sort_unstable();
        }
        out
    }
}

/// Allocator for fresh run ids (monotonic; never reused within a server's
/// lifetime, so a stale message can never alias a newer graph).
#[derive(Debug, Default)]
pub struct RunIdAlloc {
    next: u32,
}

impl RunIdAlloc {
    pub fn allocate(&mut self) -> RunId {
        let id = RunId(self.next);
        self.next += 1;
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{merge, tree};

    #[test]
    fn roots_ready_on_creation() {
        let run = GraphRun::new(merge(10), 0, 0);
        assert_eq!(run.remaining, 11);
        assert_eq!(run.ready_roots().len(), 10);
        assert_eq!(run.states[10], TaskState::Waiting, "sink waits for deps");
    }

    #[test]
    fn finish_cascades_readiness() {
        let mut run = GraphRun::new(merge(3), 0, 0);
        let w = WorkerId(0);
        assert!(run.finish(TaskId(0), w).is_empty());
        assert!(run.finish(TaskId(1), w).is_empty());
        let ready = run.finish(TaskId(2), w);
        assert_eq!(ready, vec![TaskId(3)], "sink ready after all leaves");
        assert!(!run.is_done());
        assert!(run.finish(TaskId(3), w).is_empty());
        assert!(run.is_done());
    }

    #[test]
    fn duplicate_finish_is_idempotent() {
        let mut run = GraphRun::new(merge(2), 0, 0);
        run.finish(TaskId(0), WorkerId(0));
        let before = run.remaining;
        let ready = run.finish(TaskId(0), WorkerId(1));
        assert!(ready.is_empty());
        assert_eq!(run.remaining, before);
        assert_eq!(run.who_has[0], vec![WorkerId(0), WorkerId(1)]);
    }

    #[test]
    fn tree_readiness_layers() {
        let g = tree(3); // 7 tasks: 4 leaves, 2 mid, 1 root
        let mut run = GraphRun::new(g, 0, 0);
        let w = WorkerId(0);
        let mut ready: Vec<TaskId> = run.ready_roots();
        let mut finished = 0;
        while let Some(t) = ready.pop() {
            ready.extend(run.finish(t, w));
            finished += 1;
        }
        assert_eq!(finished, 7);
        assert!(run.is_done());
    }

    #[test]
    fn tasks_on_worker_tracks_assignment_and_stealing() {
        let mut run = GraphRun::new(merge(4), 0, 0);
        run.states[0] = TaskState::Assigned(WorkerId(1));
        run.states[1] = TaskState::Stealing { from: WorkerId(1), to: WorkerId(2) };
        run.states[2] = TaskState::Assigned(WorkerId(2));
        let on1 = run.tasks_on(WorkerId(1));
        assert_eq!(on1, vec![TaskId(0), TaskId(1)]);
    }

    #[test]
    fn finish_during_steal_records_raced_endpoints() {
        let mut run = GraphRun::new(merge(4), 0, 0);
        run.states[0] = TaskState::Stealing { from: WorkerId(1), to: WorkerId(2) };
        run.finish(TaskId(0), WorkerId(1));
        assert_eq!(run.raced_steals.get(&TaskId(0)), Some(&(WorkerId(1), WorkerId(2))));
        // A plain finish leaves no record.
        run.finish(TaskId(1), WorkerId(0));
        assert!(!run.raced_steals.contains_key(&TaskId(1)));
    }

    #[test]
    fn run_ids_are_never_reused() {
        let mut alloc = RunIdAlloc::default();
        let a = alloc.allocate();
        let b = alloc.allocate();
        assert_ne!(a, b);
        assert_eq!(a, RunId(0));
        assert_eq!(b, RunId(1));
    }
}
