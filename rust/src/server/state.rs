//! Server-side bookkeeping: the task state machine, per-graph run state,
//! and the lineage-recovery planner.
//!
//! Everything here is owned by the reactor thread — no locks, no I/O. The
//! recovery planner ([`GraphRun::recover`]) is a pure state transformation
//! so it can be unit-tested without a cluster: given a dead worker it
//! resets in-flight work, resurrects outputs whose only replica died, and
//! returns a [`RecoveryPlan`] telling the reactor which schedulers/workers
//! to notify.

use crate::protocol::{Msg, RunId};
use crate::scheduler::WorkerId;
use crate::taskgraph::{GraphError, TaskGraph, TaskId, TaskSpec};
use std::collections::{HashMap, VecDeque};

/// How many worker-disconnect recoveries a single run absorbs before the
/// reactor falls back to failing it (`graph-failed`) — a cascading-failure
/// brake, not a correctness bound.
pub const DEFAULT_MAX_RECOVERIES: u32 = 8;

/// One worker-bound message parked on a run's outbox, in its cheapest
/// possible form.
///
/// An assignment is *not* materialized at park time: the key, payload and
/// input addresses it needs already live in the run's graph and `who_has`
/// tables, so the outbox carries only the dense ids and the
/// scheduler-chosen priority (16 bytes) — `Reactor::pump` resolves them
/// through the borrowed dispatch path when the message is actually
/// emitted. Input locations therefore reflect `who_has` *at emission*: at
/// least as fresh as a park-time snapshot would have been (a replica that
/// appeared in between is usable; one that died is handled by the same
/// `fetch-failed` retry / cancel-compute machinery either way, because the
/// run's FIFO outbox keeps cancels ordered after the computes they cancel).
///
/// Everything else worker-bound (steal requests, cancels) is a few-word
/// owned [`Msg`] with no heap payload.
#[derive(Debug)]
pub enum Parked {
    /// A compute-task assignment: resolved against the run at emission.
    Compute { task: TaskId, priority: i64 },
    /// Any other worker-bound message, already materialized.
    Wire(Msg),
}

/// Server-side lifecycle of a task (reactor's view).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskState {
    /// Unfinished dependencies remain.
    Waiting,
    /// Handed to the scheduler, no assignment yet sent.
    Ready,
    /// Compute message sent to this worker.
    Assigned(WorkerId),
    /// Retraction in flight: assigned to `from`, destined for `to`.
    Stealing { from: WorkerId, to: WorkerId },
    /// Finished on this worker (first finisher; replicas tracked in
    /// `who_has`).
    Finished(WorkerId),
    /// Worker reported an error.
    Erred,
}

/// Replica list for one task's output: the workers holding it, in
/// placement order (first = producer).
///
/// Up to [`ReplicaSet::INLINE`] ids live inline; only a fourth replica
/// spills to the heap (and an empty `Vec` costs nothing), so the common
/// cases — exactly one producer, occasionally a duplicate-finish replica —
/// never allocate. This removes the last per-task heap object on the
/// server: `who_has` used to be one `Vec` per task, allocated on first
/// finish. The `hotpath_micro` dispatch section pins the push/first/retain
/// cycle at zero allocations under the counting allocator.
#[derive(Debug, Clone)]
pub struct ReplicaSet {
    inline: [WorkerId; ReplicaSet::INLINE],
    len: u8,
    spill: Vec<WorkerId>,
}

impl ReplicaSet {
    /// Replicas held without heap spill. Three covers the planned
    /// k-replication follow-up (k ≤ 3 in the ROADMAP's object-store item).
    pub const INLINE: usize = 3;

    pub fn new() -> ReplicaSet {
        ReplicaSet { inline: [WorkerId(0); Self::INLINE], len: 0, spill: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.len as usize + self.spill.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append a replica (dedup is the caller's concern, as it was with the
    /// plain `Vec`). Allocation-free until the inline slots are full.
    pub fn push(&mut self, w: WorkerId) {
        if (self.len as usize) < Self::INLINE {
            self.inline[self.len as usize] = w;
            self.len += 1;
        } else {
            self.spill.push(w);
        }
    }

    /// First replica (the producer), if any.
    pub fn first(&self) -> Option<WorkerId> {
        if self.len > 0 {
            Some(self.inline[0])
        } else {
            None
        }
    }

    pub fn contains(&self, needle: WorkerId) -> bool {
        self.iter().any(|w| w == needle)
    }

    pub fn iter(&self) -> impl Iterator<Item = WorkerId> + '_ {
        self.inline[..self.len as usize].iter().copied().chain(self.spill.iter().copied())
    }

    /// Keep only replicas satisfying `keep`, preserving order. Spilled ids
    /// are pulled back inline so the invariant (spill non-empty only while
    /// inline is full) — and therefore allocation-free pushes — survive
    /// purges.
    pub fn retain(&mut self, mut keep: impl FnMut(WorkerId) -> bool) {
        let mut kept = 0usize;
        for i in 0..self.len as usize {
            let w = self.inline[i];
            if keep(w) {
                self.inline[kept] = w;
                kept += 1;
            }
        }
        self.len = kept as u8;
        self.spill.retain(|&w| keep(w));
        while (self.len as usize) < Self::INLINE && !self.spill.is_empty() {
            self.inline[self.len as usize] = self.spill.remove(0);
            self.len += 1;
        }
    }
}

impl Default for ReplicaSet {
    fn default() -> Self {
        ReplicaSet::new()
    }
}

impl PartialEq for ReplicaSet {
    fn eq(&self, other: &ReplicaSet) -> bool {
        self.len() == other.len() && self.iter().zip(other.iter()).all(|(a, b)| a == b)
    }
}

/// Comparability with the pre-interning representation (tests and
/// diagnostics state expected replica lists as plain vectors).
impl PartialEq<Vec<WorkerId>> for ReplicaSet {
    fn eq(&self, other: &Vec<WorkerId>) -> bool {
        self.len() == other.len() && self.iter().zip(other.iter().copied()).all(|(a, b)| a == b)
    }
}

impl PartialEq<&[WorkerId]> for ReplicaSet {
    fn eq(&self, other: &&[WorkerId]) -> bool {
        self.len() == other.len() && self.iter().zip(other.iter().copied()).all(|(a, b)| a == b)
    }
}

/// Execution state of one submitted graph. The reactor keeps one `GraphRun`
/// per live [`RunId`]; everything in here is private to that run, so
/// concurrent graphs can never alias each other's `TaskId`s.
#[derive(Debug)]
pub struct GraphRun {
    pub graph: TaskGraph,
    pub client: u32,
    pub states: Vec<TaskState>,
    /// Remaining unfinished dependency count per task.
    pub unfinished_deps: Vec<u32>,
    /// Tasks not yet finished.
    pub remaining: usize,
    /// Wall-clock µs timestamp (from the reactor's stopwatch) at submit.
    pub submitted_at_us: u64,
    /// Workers holding each task's output (first = producer). Inline
    /// small-vec: see [`ReplicaSet`].
    pub who_has: Vec<ReplicaSet>,
    /// Priority each task was last assigned with (scheduler-chosen; needed
    /// to re-send the *same* priority after a successful retraction).
    pub priorities: Vec<i64>,
    /// Steals whose target state was overwritten by a racing finish before
    /// the `StealResponse` arrived: task → the original `(from, to)`. The
    /// response handler consumes this so the scheduler learns the true
    /// endpoints of the failed steal.
    pub raced_steals: HashMap<TaskId, (WorkerId, WorkerId)>,
    /// Steals dissolved by a recovery pass while their victim was still
    /// alive: `(task, victim)` → number of that victim's `StealResponse`s
    /// still in flight. The scheduler was already told each steal failed,
    /// so the response handler consumes one marker and ignores the stale
    /// answer instead of resolving the steal a second time. Keyed by the
    /// responder so only *that worker's* answer is swallowed — a later,
    /// genuine steal of the re-placed task (different victim) must still
    /// resolve normally — counted so repeated dissolutions of the same
    /// task don't lose markers, and purged when the recorded victim itself
    /// dies (its answer can no longer arrive; per-connection FIFO makes a
    /// same-victim re-steal unambiguous, stale answers always arrive
    /// first).
    pub cancelled_steals: HashMap<(TaskId, WorkerId), u32>,
    /// Worker-disconnect recoveries absorbed so far (see
    /// [`GraphRun::recover`]).
    pub recoveries: u32,
    /// Recovery budget; past it a disconnect fails the run as before.
    pub max_recoveries: u32,
    /// Worker-bound messages translated from scheduler actions (state
    /// transitions already applied) but not yet emitted — the fairness
    /// unit. `Reactor::pump` drains outboxes in policy order, preserving
    /// per-run FIFO (the steal/recovery protocols rely on in-run message
    /// order, never on cross-run order). Assignments park as id-only
    /// [`Parked::Compute`] entries — no strings are cloned until (and
    /// unless) the message is emitted. Dropped wholesale when the run
    /// retires: anything still parked then is a recovery duplicate whose
    /// target the `release-run` broadcast purges anyway.
    pub outbox: VecDeque<(WorkerId, Parked)>,
    /// Tick at which `outbox` last became non-empty (stamped by the
    /// reactor); the arrival-order key across queue activations.
    pub outbox_since: u64,
    /// Recoverable `fetch-failed` re-runs, counted *per task* — bounds the
    /// bounce loop of a single task with a persistently stale `who_has`
    /// address without letting one wide disconnect (many tasks fetching
    /// from the same corpse at once) exhaust a shared budget.
    pub fetch_retries: HashMap<TaskId, u32>,
    /// Per-task replication flag, computed at activation when the server
    /// runs with k > 1: `true` marks outputs worth proactive copies
    /// (fan-out ≥ the configured threshold, or on the critical path).
    /// Empty when replication is off — the common case costs nothing.
    pub replicate_hint: Vec<bool>,
    // Per-run counters (reported in `ReactorReport`).
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub msgs_in: u64,
    pub msgs_out: u64,
    /// Previously finished tasks forced back to execution — by worker-death
    /// resurrection or by the fetch-failed missing-input safety net. The
    /// recovery benchmark's headline number: replication earns its bytes by
    /// driving this toward zero.
    pub tasks_recomputed: u64,
    /// `true` for an extensible run (`submit-graph` with `open`): the
    /// client may stream further tasks via `submit-extend`, and quiescence
    /// (`remaining == 0`) does not retire the run until a closing
    /// extension arrives.
    pub open: bool,
    /// `true` once no further extensions can arrive — from creation for a
    /// one-shot run, or when a `submit-extend` with `last` lands. Gates
    /// [`GraphRun::is_done`].
    pub closed: bool,
    /// Consumer count last told to the worker holding each task's output:
    /// stamped at assignment emission (the count baked into the
    /// `compute-task`), updated when a `pin-data` delta is pushed.
    /// [`GraphRun::NEVER_EMITTED`] until the task is first dispatched.
    /// The gap `consumers(t).len() - emitted_consumers[t]` is exactly the
    /// refcount the worker's store is missing after graph extensions.
    pub emitted_consumers: Vec<u32>,
}

/// What the reactor must do after [`GraphRun::extend`] grafted a task batch
/// onto a live run. Field order mirrors the order the reactor applies them.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExtendPlan {
    /// Tasks that ended the extension `Ready` — new roots whose inputs are
    /// all finished (or absent), plus resurrected lineage that can start
    /// immediately. The reactor seeds the scheduler with exactly these.
    pub ready: Vec<TaskId>,
    /// `(task, delta)`: finished outputs still resident somewhere whose
    /// store refcount must rise by `delta` — the reactor sends `pin-data`
    /// to every `who_has` holder.
    pub pin: Vec<(TaskId, u32)>,
    /// Finished outputs the extension needs whose every replica
    /// self-evicted; they are unfinished again (transitively, via the PR 3
    /// lineage machinery) and will be recomputed.
    pub resurrected: Vec<TaskId>,
}

/// What the reactor must do after [`GraphRun::recover`] absorbed a worker
/// death (instead of failing the run). Field order mirrors the order the
/// reactor applies them in.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryPlan {
    /// `(task, worker)` assignments that evaporated; the reactor reports
    /// each via `Scheduler::task_lost` so queue models stay in sync.
    pub lost_assignments: Vec<(TaskId, WorkerId)>,
    /// In-flight steals dissolved by the recovery (`(task, from, to)`);
    /// each is reported to the scheduler as failed.
    pub dissolved_steals: Vec<(TaskId, WorkerId, WorkerId)>,
    /// `(worker, task)`: live workers that must drop their queued copy
    /// (`cancel-compute`) because an input evaporated or the task was mid-
    /// steal; the task is re-sent after its inputs exist again.
    pub cancel: Vec<(WorkerId, TaskId)>,
    /// Previously finished tasks whose only replica died; they are
    /// unfinished again and will be recomputed.
    pub resurrected: Vec<TaskId>,
    /// Tasks that ended the recovery `Ready` (all inputs still available);
    /// the reactor re-seeds the scheduler with exactly these.
    pub ready: Vec<TaskId>,
}

impl RecoveryPlan {
    /// A trivial plan is a pure replica purge: survivors hold every output
    /// the dead worker had and nothing was queued on it. It costs no
    /// recovery budget and requires no scheduler/worker notifications.
    pub fn is_trivial(&self) -> bool {
        self.lost_assignments.is_empty()
            && self.dissolved_steals.is_empty()
            && self.cancel.is_empty()
            && self.resurrected.is_empty()
            && self.ready.is_empty()
    }
}

impl GraphRun {
    pub fn new(graph: TaskGraph, client: u32, now_us: u64) -> GraphRun {
        let n = graph.len();
        let unfinished_deps: Vec<u32> = graph.tasks().iter().map(|t| t.inputs.len() as u32).collect();
        let states = unfinished_deps
            .iter()
            .map(|&d| if d == 0 { TaskState::Ready } else { TaskState::Waiting })
            .collect();
        GraphRun {
            graph,
            client,
            states,
            unfinished_deps,
            remaining: n,
            submitted_at_us: now_us,
            who_has: vec![ReplicaSet::new(); n],
            priorities: (0..n as i64).collect(),
            raced_steals: HashMap::new(),
            cancelled_steals: HashMap::new(),
            recoveries: 0,
            max_recoveries: DEFAULT_MAX_RECOVERIES,
            outbox: VecDeque::new(),
            outbox_since: 0,
            fetch_retries: HashMap::new(),
            replicate_hint: Vec::new(),
            steals_attempted: 0,
            steals_failed: 0,
            msgs_in: 0,
            msgs_out: 0,
            tasks_recomputed: 0,
            open: false,
            closed: true,
            emitted_consumers: vec![Self::NEVER_EMITTED; n],
        }
    }

    /// Sentinel in [`GraphRun::emitted_consumers`]: the task has never been
    /// dispatched, so no worker store holds a count to correct.
    pub const NEVER_EMITTED: u32 = u32::MAX;

    /// Mark the run extensible (a `submit-graph` with `open`).
    pub fn set_open(&mut self) {
        self.open = true;
        self.closed = false;
    }

    /// Graft a validated task batch onto the live run (the `submit-extend`
    /// tentpole). On success the new tasks are installed `Ready`/`Waiting`,
    /// `remaining` grows, and the returned [`ExtendPlan`] tells the reactor
    /// which tasks to seed, which resident outputs to re-pin (`pin-data`
    /// deltas), and which evaporated outputs were transitively resurrected.
    /// On error nothing is mutated (graph validation happens before any
    /// table grows).
    pub fn extend(&mut self, new_tasks: Vec<TaskSpec>) -> Result<ExtendPlan, GraphError> {
        let old_n = self.graph.len();
        self.graph.extend(new_tasks)?;
        let total = self.graph.len();

        // Grow every per-task table to the new dense id space.
        self.states.resize(total, TaskState::Waiting);
        self.unfinished_deps.resize(total, 0);
        self.who_has.resize(total, ReplicaSet::new());
        self.priorities.extend((old_n as i64)..(total as i64));
        self.emitted_consumers.resize(total, Self::NEVER_EMITTED);
        if !self.replicate_hint.is_empty() {
            // Conservative default for grafted tasks: no proactive copies
            // (the activation-time hint pass only saw the base graph).
            self.replicate_hint.resize(total, false);
        }
        self.remaining += total - old_n;

        // Consumer arcs the extension added to pre-existing producers.
        let mut delta: HashMap<TaskId, u32> = HashMap::new();
        for i in old_n..total {
            for &inp in &self.graph.task(TaskId(i as u32)).inputs {
                if inp.idx() < old_n {
                    *delta.entry(inp).or_insert(0) += 1;
                }
            }
        }

        let mut plan = ExtendPlan::default();
        // Finished producers split two ways: still resident somewhere →
        // re-pin (raise the store refcount by the emission gap); every
        // replica self-evicted → resurrect, transitively.
        let mut seeds: Vec<TaskId> = Vec::new();
        let mut producers: Vec<TaskId> = delta.keys().copied().collect();
        producers.sort_unstable();
        for p in producers {
            if !matches!(self.states[p.idx()], TaskState::Finished(_)) {
                // Unfinished: the new count is baked into the eventual
                // compute-task, or delivered as a finish-time pin delta.
                continue;
            }
            if self.who_has[p.idx()].is_empty() {
                seeds.push(p);
            } else {
                let told = self.emitted_consumers[p.idx()];
                let now = self.graph.consumers(p).len() as u32;
                if told != Self::NEVER_EMITTED && now > told {
                    plan.pin.push((p, now - told));
                }
                self.emitted_consumers[p.idx()] = now;
            }
        }
        // Same transitive walk as `resurrect_missing_inputs`, seeded with
        // the evaporated producers themselves.
        let mut work = seeds;
        while let Some(p) = work.pop() {
            if !matches!(self.states[p.idx()], TaskState::Finished(_)) {
                continue; // already resurrected via another consumer path
            }
            self.states[p.idx()] = TaskState::Ready; // deps fixed below
            self.remaining += 1;
            plan.resurrected.push(p);
            for &inp in &self.graph.task(p).inputs {
                if matches!(self.states[inp.idx()], TaskState::Finished(_))
                    && self.who_has[inp.idx()].is_empty()
                {
                    work.push(inp);
                }
            }
        }
        self.tasks_recomputed += plan.resurrected.len() as u64;

        // Rebuild dependency counts for every unfinished task and settle
        // idle tasks into Ready/Waiting (in-flight tasks keep their state —
        // the fetch-failed safety net backstops one that raced a
        // resurrection, exactly as in recovery).
        for i in 0..total {
            if matches!(self.states[i], TaskState::Finished(_)) {
                continue;
            }
            let deps = self
                .graph
                .task(TaskId(i as u32))
                .inputs
                .iter()
                .filter(|inp| !matches!(self.states[inp.idx()], TaskState::Finished(_)))
                .count() as u32;
            self.unfinished_deps[i] = deps;
            if matches!(self.states[i], TaskState::Ready | TaskState::Waiting) {
                self.states[i] = if deps == 0 { TaskState::Ready } else { TaskState::Waiting };
            }
        }
        for i in old_n..total {
            if self.states[i] == TaskState::Ready {
                plan.ready.push(TaskId(i as u32));
            }
        }
        for &t in &plan.resurrected {
            if self.states[t.idx()] == TaskState::Ready {
                plan.ready.push(t);
            }
        }
        plan.ready.sort_unstable();
        plan.resurrected.sort_unstable();
        Ok(plan)
    }

    /// Initially ready tasks (the graph roots).
    pub fn ready_roots(&self) -> Vec<TaskId> {
        self.graph.roots()
    }

    /// Mark `task` finished on `worker`; returns consumers that became
    /// ready. Idempotent against duplicate finish reports (a steal race can
    /// produce one) — the second report is ignored.
    pub fn finish(&mut self, task: TaskId, worker: WorkerId) -> Vec<TaskId> {
        if matches!(self.states[task.idx()], TaskState::Finished(_)) {
            self.who_has[task.idx()].push(worker);
            return Vec::new();
        }
        // A finish that beats an in-flight retraction must keep the steal's
        // endpoints around for the late `StealResponse` (see the reactor).
        if let TaskState::Stealing { from, to } = self.states[task.idx()] {
            self.raced_steals.insert(task, (from, to));
        }
        self.states[task.idx()] = TaskState::Finished(worker);
        self.who_has[task.idx()].push(worker);
        self.remaining -= 1;
        // The fetch-retry cap bounds *consecutive* bounces of one stuck
        // task; a successful finish resets it, so independent recoverable
        // incidents across a long run never accumulate into a fatal one.
        self.fetch_retries.remove(&task);
        let mut newly_ready = Vec::new();
        for &c in self.graph.consumers(task) {
            // A consumer can already be Finished here: a cancelled copy
            // that was mid-execution during recovery may report early,
            // before this (resurrected) input recomputed. Its result was
            // accepted; don't re-ready it.
            if matches!(self.states[c.idx()], TaskState::Finished(_)) {
                continue;
            }
            let d = &mut self.unfinished_deps[c.idx()];
            if *d == 0 {
                // Counter underflow would wrap and re-ready the consumer
                // u32::MAX finishes later; skip it and log instead (the
                // debug build still fails loudly).
                debug_assert!(*d > 0, "dependency underflow for consumer {c:?}");
                log::error!("dependency counter underflow for consumer {c:?} of {task:?}");
                continue;
            }
            *d -= 1;
            if *d == 0 {
                if self.states[c.idx()] != TaskState::Waiting {
                    debug_assert_eq!(self.states[c.idx()], TaskState::Waiting);
                    log::error!(
                        "consumer {c:?} became ready while {:?} (expected Waiting)",
                        self.states[c.idx()]
                    );
                    continue;
                }
                self.states[c.idx()] = TaskState::Ready;
                newly_ready.push(c);
            }
        }
        newly_ready
    }

    /// A run retires only when every task finished AND no further
    /// extensions can arrive (one-shot runs are born closed; open runs
    /// close when a `last` extension lands).
    pub fn is_done(&self) -> bool {
        self.remaining == 0 && self.closed
    }

    /// Worker currently responsible for a task, if any.
    pub fn assigned_worker(&self, task: TaskId) -> Option<WorkerId> {
        match self.states[task.idx()] {
            TaskState::Assigned(w) => Some(w),
            TaskState::Stealing { from, .. } => Some(from),
            _ => None,
        }
    }

    /// All tasks currently assigned to `worker` (diagnostics/tests; the
    /// disconnect path itself walks states inside [`GraphRun::recover`]).
    pub fn tasks_on(&self, worker: WorkerId) -> Vec<TaskId> {
        self.states
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                TaskState::Assigned(w) if *w == worker => Some(TaskId(i as u32)),
                TaskState::Stealing { from, .. } if *from == worker => Some(TaskId(i as u32)),
                _ => None,
            })
            .collect()
    }

    /// Whether this run still depends on `worker`: tasks assigned to it,
    /// steals *from or to* it in flight (a dead steal target would strand
    /// the retraction's resend), or data stored on it.
    pub fn involves_worker(&self, worker: WorkerId) -> bool {
        self.states.iter().any(|s| {
            matches!(s, TaskState::Assigned(w) if *w == worker)
                || matches!(s, TaskState::Stealing { from, to }
                    if *from == worker || *to == worker)
        }) || self.who_has.iter().any(|h| h.contains(worker))
    }

    /// Absorb the death of `dead` by lineage recovery (the tentpole of the
    /// recovery design — see `docs/recovery.md`):
    ///
    /// 1. purge the dead worker's replicas from `who_has`,
    /// 2. reset every assignment/steal that touched it (and cancel queued
    ///    copies on live workers whose input addresses may have named it),
    /// 3. resurrect finished outputs whose only replica died, transitively
    ///    (an unfinished task needs all its lineage inputs to exist
    ///    somewhere),
    /// 4. rebuild dependency counts and return the set of tasks that are
    ///    `Ready` for re-placement.
    ///
    /// Returns `None` when the recovery budget is exhausted — the caller
    /// falls back to failing the run. A *trivial* plan (pure replica purge)
    /// consumes no budget.
    pub fn recover(&mut self, dead: WorkerId) -> Option<RecoveryPlan> {
        let mut plan = RecoveryPlan::default();
        let n = self.graph.len();
        // Outputs the dead worker held a replica of: any assignment sent
        // while it held one may carry its (now dead) data address, so
        // consumers of those outputs are conservatively cancelled.
        let held: Vec<bool> = self.who_has.iter().map(|h| h.contains(dead)).collect();
        for h in &mut self.who_has {
            h.retain(|w| w != dead);
        }
        // Markers waiting on an answer from the dead worker are dead
        // letters — drop them, or they would swallow a future genuine
        // response for the same (re-placed, re-stolen) task.
        self.cancelled_steals.retain(|&(_, victim), _| victim != dead);

        for i in 0..n {
            let t = TaskId(i as u32);
            // An input is tainted only when the corpse held it AND no live
            // replica survives. Pre-replication this predicate degenerated
            // to plain `held` (one copy each) and every consumer of the
            // dead worker's outputs was cancelled; with replica tracking a
            // surviving copy keeps the assignment valid — the worker's
            // fetch failover walks the alternates, and the `fetch-failed`
            // retry path backstops an assignment that named only the
            // corpse.
            let tainted_inputs = self
                .graph
                .task(t)
                .inputs
                .iter()
                .any(|&inp| held[inp.idx()] && self.who_has[inp.idx()].is_empty());
            match self.states[i] {
                TaskState::Assigned(w) if w == dead => {
                    plan.lost_assignments.push((t, w));
                    self.states[i] = TaskState::Ready; // deps fixed below
                }
                TaskState::Assigned(w) if tainted_inputs => {
                    plan.cancel.push((w, t));
                    plan.lost_assignments.push((t, w));
                    self.states[i] = TaskState::Ready;
                }
                TaskState::Stealing { from, to } if from == dead => {
                    // The retraction request went to the corpse; no answer
                    // will ever come — dissolve the steal now.
                    plan.dissolved_steals.push((t, from, to));
                    plan.lost_assignments.push((t, from));
                    self.states[i] = TaskState::Ready;
                }
                TaskState::Stealing { from, to } if to == dead || tainted_inputs => {
                    // Victim is alive: cancel its queued copy, dissolve the
                    // steal, and remember to swallow the late response
                    // (from that victim only).
                    plan.cancel.push((from, t));
                    plan.dissolved_steals.push((t, from, to));
                    plan.lost_assignments.push((t, from));
                    *self.cancelled_steals.entry((t, from)).or_insert(0) += 1;
                    self.states[i] = TaskState::Ready;
                }
                _ => {}
            }
        }

        // Transitive resurrection: every unfinished task's (transitive)
        // inputs must exist on some live worker.
        let mut work: Vec<TaskId> = (0..n)
            .filter(|&i| !matches!(self.states[i], TaskState::Finished(_)))
            .map(|i| TaskId(i as u32))
            .collect();
        while let Some(t) = work.pop() {
            for &inp in &self.graph.task(t).inputs {
                if matches!(self.states[inp.idx()], TaskState::Finished(_))
                    && self.who_has[inp.idx()].is_empty()
                {
                    self.states[inp.idx()] = TaskState::Ready; // deps fixed below
                    self.remaining += 1;
                    plan.resurrected.push(inp);
                    work.push(inp);
                }
            }
        }

        if plan.is_trivial() {
            return Some(plan); // replica purge only: free
        }
        self.tasks_recomputed += plan.resurrected.len() as u64;
        self.recoveries += 1;
        if self.recoveries > self.max_recoveries {
            return None;
        }

        // Rebuild dependency counts for every unfinished task, then settle
        // the reset tasks into Ready/Waiting. Tasks the recovery did not
        // touch keep their in-flight state — resurrection can only *add*
        // unfinished deps, and any task with a resurrected input was
        // already reset above (its input was `held` by the dead worker).
        for i in 0..n {
            if matches!(self.states[i], TaskState::Finished(_)) {
                continue;
            }
            let deps = self
                .graph
                .task(TaskId(i as u32))
                .inputs
                .iter()
                .filter(|inp| !matches!(self.states[inp.idx()], TaskState::Finished(_)))
                .count() as u32;
            self.unfinished_deps[i] = deps;
            match self.states[i] {
                TaskState::Ready | TaskState::Waiting => {
                    self.states[i] =
                        if deps == 0 { TaskState::Ready } else { TaskState::Waiting };
                }
                _ => {
                    if deps != 0 {
                        debug_assert_eq!(
                            deps, 0,
                            "in-flight task {i} kept an unfinished input through recovery"
                        );
                        log::error!(
                            "recovery left in-flight task {i} with {deps} unfinished input(s)"
                        );
                    }
                }
            }
        }
        for &(t, _) in &plan.lost_assignments {
            if self.states[t.idx()] == TaskState::Ready {
                plan.ready.push(t);
            }
        }
        for &t in &plan.resurrected {
            if self.states[t.idx()] == TaskState::Ready {
                plan.ready.push(t);
            }
        }
        plan.ready.sort_unstable();
        Some(plan)
    }

    /// Safety net for the `fetch-failed` retry path: by the time a task's
    /// fetch failed on every replica, an input may exist nowhere — it
    /// self-evicted (`replica-dropped`) or died with its holders after the
    /// assignment was emitted. Resurrect, transitively, every input of
    /// `task` that is `Finished` yet has an empty replica list, so the
    /// retry recomputes the data instead of bouncing off the same hole
    /// until the retry budget fails the run.
    ///
    /// Returns the resurrected tasks that ended `Ready` (the caller
    /// re-seeds the scheduler with exactly these); empty when every input
    /// still has a replica — the common retry case costs one inputs scan.
    pub fn resurrect_missing_inputs(&mut self, task: TaskId) -> Vec<TaskId> {
        let mut resurrected: Vec<TaskId> = Vec::new();
        let mut work = vec![task];
        while let Some(t) = work.pop() {
            for &inp in &self.graph.task(t).inputs {
                if matches!(self.states[inp.idx()], TaskState::Finished(_))
                    && self.who_has[inp.idx()].is_empty()
                {
                    self.states[inp.idx()] = TaskState::Ready; // deps fixed below
                    self.remaining += 1;
                    resurrected.push(inp);
                    work.push(inp);
                }
            }
        }
        if resurrected.is_empty() {
            return Vec::new();
        }
        self.tasks_recomputed += resurrected.len() as u64;
        // Rebuild dependency counts exactly like `recover`: resettled
        // Ready/Waiting for idle tasks, in-flight tasks keep their state
        // (another live consumer of a resurrected input will hit its own
        // fetch failure and come through this same path).
        let n = self.graph.len();
        for i in 0..n {
            if matches!(self.states[i], TaskState::Finished(_)) {
                continue;
            }
            let deps = self
                .graph
                .task(TaskId(i as u32))
                .inputs
                .iter()
                .filter(|inp| !matches!(self.states[inp.idx()], TaskState::Finished(_)))
                .count() as u32;
            self.unfinished_deps[i] = deps;
            if matches!(self.states[i], TaskState::Ready | TaskState::Waiting) {
                self.states[i] = if deps == 0 { TaskState::Ready } else { TaskState::Waiting };
            }
        }
        let mut ready: Vec<TaskId> = resurrected
            .iter()
            .copied()
            .filter(|t| self.states[t.idx()] == TaskState::Ready)
            .collect();
        ready.sort_unstable();
        ready
    }

    /// Per-worker tasks this run considers queued (assigned or mid-steal
    /// from that worker) — the reactor-side view the scheduler invariant
    /// tests compare against [`crate::scheduler::Scheduler::queued_tasks`].
    pub fn queued_by_worker(&self) -> HashMap<WorkerId, Vec<TaskId>> {
        let mut out: HashMap<WorkerId, Vec<TaskId>> = HashMap::new();
        for (i, s) in self.states.iter().enumerate() {
            let w = match s {
                TaskState::Assigned(w) => *w,
                TaskState::Stealing { from, .. } => *from,
                _ => continue,
            };
            out.entry(w).or_default().push(TaskId(i as u32));
        }
        for q in out.values_mut() {
            q.sort_unstable();
        }
        out
    }
}

/// Allocator for fresh run ids (monotonic; never reused within a server's
/// lifetime, so a stale message can never alias a newer graph).
///
/// With the sharded control plane each shard allocates independently:
/// shard `s` of `n` uses [`RunIdAlloc::strided`]`(s, n)` and hands out
/// `s, s+n, s+2n, …` — globally unique without coordination, and
/// `run.0 % n` recovers the owning shard (how cross-shard worker messages
/// are routed home). The default is the unsharded `(0, 1)` sequence.
#[derive(Debug)]
pub struct RunIdAlloc {
    next: u32,
    stride: u32,
}

impl Default for RunIdAlloc {
    fn default() -> Self {
        RunIdAlloc { next: 0, stride: 1 }
    }
}

impl RunIdAlloc {
    /// Allocator for shard `start` of `stride` total shards.
    pub fn strided(start: u32, stride: u32) -> RunIdAlloc {
        RunIdAlloc { next: start, stride: stride.max(1) }
    }

    pub fn allocate(&mut self) -> RunId {
        let id = RunId(self.next);
        self.next += self.stride;
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{merge, tree};

    #[test]
    fn roots_ready_on_creation() {
        let run = GraphRun::new(merge(10), 0, 0);
        assert_eq!(run.remaining, 11);
        assert_eq!(run.ready_roots().len(), 10);
        assert_eq!(run.states[10], TaskState::Waiting, "sink waits for deps");
    }

    #[test]
    fn finish_cascades_readiness() {
        let mut run = GraphRun::new(merge(3), 0, 0);
        let w = WorkerId(0);
        assert!(run.finish(TaskId(0), w).is_empty());
        assert!(run.finish(TaskId(1), w).is_empty());
        let ready = run.finish(TaskId(2), w);
        assert_eq!(ready, vec![TaskId(3)], "sink ready after all leaves");
        assert!(!run.is_done());
        assert!(run.finish(TaskId(3), w).is_empty());
        assert!(run.is_done());
    }

    #[test]
    fn duplicate_finish_is_idempotent() {
        let mut run = GraphRun::new(merge(2), 0, 0);
        run.finish(TaskId(0), WorkerId(0));
        let before = run.remaining;
        let ready = run.finish(TaskId(0), WorkerId(1));
        assert!(ready.is_empty());
        assert_eq!(run.remaining, before);
        assert_eq!(run.who_has[0], vec![WorkerId(0), WorkerId(1)]);
    }

    #[test]
    fn tree_readiness_layers() {
        let g = tree(3); // 7 tasks: 4 leaves, 2 mid, 1 root
        let mut run = GraphRun::new(g, 0, 0);
        let w = WorkerId(0);
        let mut ready: Vec<TaskId> = run.ready_roots();
        let mut finished = 0;
        while let Some(t) = ready.pop() {
            ready.extend(run.finish(t, w));
            finished += 1;
        }
        assert_eq!(finished, 7);
        assert!(run.is_done());
    }

    #[test]
    fn tasks_on_worker_tracks_assignment_and_stealing() {
        let mut run = GraphRun::new(merge(4), 0, 0);
        run.states[0] = TaskState::Assigned(WorkerId(1));
        run.states[1] = TaskState::Stealing { from: WorkerId(1), to: WorkerId(2) };
        run.states[2] = TaskState::Assigned(WorkerId(2));
        let on1 = run.tasks_on(WorkerId(1));
        assert_eq!(on1, vec![TaskId(0), TaskId(1)]);
    }

    #[test]
    fn finish_during_steal_records_raced_endpoints() {
        let mut run = GraphRun::new(merge(4), 0, 0);
        run.states[0] = TaskState::Stealing { from: WorkerId(1), to: WorkerId(2) };
        run.finish(TaskId(0), WorkerId(1));
        assert_eq!(run.raced_steals.get(&TaskId(0)), Some(&(WorkerId(1), WorkerId(2))));
        // A plain finish leaves no record.
        run.finish(TaskId(1), WorkerId(0));
        assert!(!run.raced_steals.contains_key(&TaskId(1)));
    }

    // ---- lineage recovery (PR 3 tentpole) ----

    /// Linear chain a → b → c (merge(1) is too small; build explicitly).
    fn chain3() -> TaskGraph {
        use crate::taskgraph::{GraphBuilder, Payload};
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 10, 8, Payload::NoOp);
        let m = b.add("b", vec![a], 10, 8, Payload::MergeInputs);
        b.add("c", vec![m], 10, 8, Payload::MergeInputs);
        b.build("chain").unwrap()
    }

    #[test]
    fn recover_with_surviving_replica_is_trivial() {
        let mut run = GraphRun::new(merge(2), 0, 0);
        // t0 finished on w0 AND w1 (duplicate finish ⇒ replica).
        run.finish(TaskId(0), WorkerId(0));
        run.finish(TaskId(0), WorkerId(1));
        let plan = run.recover(WorkerId(0)).unwrap();
        assert!(plan.is_trivial(), "{plan:?}");
        assert_eq!(run.who_has[0], vec![WorkerId(1)], "survivor replica kept");
        assert_eq!(run.recoveries, 0, "trivial purge costs no budget");
    }

    #[test]
    fn recover_requeues_tasks_assigned_to_dead_worker() {
        let mut run = GraphRun::new(merge(3), 0, 0);
        run.states[0] = TaskState::Assigned(WorkerId(0));
        run.states[1] = TaskState::Assigned(WorkerId(1));
        let plan = run.recover(WorkerId(0)).unwrap();
        assert_eq!(plan.lost_assignments, vec![(TaskId(0), WorkerId(0))]);
        assert_eq!(plan.ready, vec![TaskId(0)]);
        assert!(plan.cancel.is_empty() && plan.resurrected.is_empty());
        assert_eq!(run.states[0], TaskState::Ready);
        assert_eq!(run.states[1], TaskState::Assigned(WorkerId(1)), "survivor untouched");
        assert_eq!(run.recoveries, 1);
    }

    #[test]
    fn recover_sole_replica_triggers_transitive_recompute() {
        // a, b finished on w0 only; c assigned to live w1. Killing w0 must
        // resurrect both a and b (b needs a), and cancel c on w1 (its
        // input address named the corpse).
        let mut run = GraphRun::new(chain3(), 0, 0);
        let (a, b, c) = (TaskId(0), TaskId(1), TaskId(2));
        run.finish(a, WorkerId(0));
        run.finish(b, WorkerId(0));
        run.states[c.idx()] = TaskState::Assigned(WorkerId(1));
        let before_remaining = run.remaining;
        let plan = run.recover(WorkerId(0)).unwrap();
        let mut res = plan.resurrected.clone();
        res.sort_unstable();
        assert_eq!(res, vec![a, b]);
        assert_eq!(plan.cancel, vec![(WorkerId(1), c)]);
        assert_eq!(plan.lost_assignments, vec![(c, WorkerId(1))]);
        assert_eq!(plan.ready, vec![a], "only the root is ready again");
        assert_eq!(run.states[a.idx()], TaskState::Ready);
        assert_eq!(run.states[b.idx()], TaskState::Waiting);
        assert_eq!(run.states[c.idx()], TaskState::Waiting);
        assert_eq!(run.unfinished_deps[b.idx()], 1);
        assert_eq!(run.unfinished_deps[c.idx()], 1);
        assert_eq!(run.remaining, before_remaining + 2);
    }

    #[test]
    fn recover_dissolves_steals_touching_the_corpse() {
        let mut run = GraphRun::new(merge(4), 0, 0);
        // t0 mid-steal FROM the dead worker, t1 mid-steal TO it.
        run.states[0] = TaskState::Stealing { from: WorkerId(0), to: WorkerId(1) };
        run.states[1] = TaskState::Stealing { from: WorkerId(1), to: WorkerId(0) };
        let plan = run.recover(WorkerId(0)).unwrap();
        let mut dissolved = plan.dissolved_steals.clone();
        dissolved.sort_unstable_by_key(|d| d.0);
        assert_eq!(
            dissolved,
            vec![
                (TaskId(0), WorkerId(0), WorkerId(1)),
                (TaskId(1), WorkerId(1), WorkerId(0)),
            ]
        );
        // The live victim (w1) gets a cancel; its late StealResponse will
        // be swallowed.
        assert_eq!(plan.cancel, vec![(WorkerId(1), TaskId(1))]);
        assert_eq!(run.cancelled_steals.get(&(TaskId(1), WorkerId(1))), Some(&1));
        assert!(
            !run.cancelled_steals.keys().any(|&(t, _)| t == TaskId(0)),
            "corpse never answers"
        );
        assert_eq!(plan.ready, vec![TaskId(0), TaskId(1)]);
    }

    #[test]
    fn recover_keeps_assignment_when_live_replica_remains() {
        // Regression for the PR 3 conservatism this PR obsoletes: before
        // replica tracking fed the taint predicate, killing w0 cancelled
        // every assignment whose input w0 had held — even with a live
        // replica on w1. Now the surviving copy keeps the assignment
        // servable (the worker's fetch failover reaches it), and the whole
        // recovery is a trivial purge costing no budget.
        let mut run = GraphRun::new(chain3(), 0, 0);
        let (a, b, c) = (TaskId(0), TaskId(1), TaskId(2));
        run.finish(a, WorkerId(0));
        run.finish(b, WorkerId(0));
        // Replicas of both outputs on w1 (replica-added bookkeeping).
        run.who_has[a.idx()].push(WorkerId(1));
        run.who_has[b.idx()].push(WorkerId(1));
        run.states[c.idx()] = TaskState::Assigned(WorkerId(2));
        let plan = run.recover(WorkerId(0)).unwrap();
        assert!(plan.is_trivial(), "replica purge only: {plan:?}");
        assert_eq!(run.states[c.idx()], TaskState::Assigned(WorkerId(2)), "not cancelled");
        assert_eq!(run.who_has[a.idx()], vec![WorkerId(1)]);
        assert_eq!(run.who_has[b.idx()], vec![WorkerId(1)]);
        assert_eq!(run.recoveries, 0, "no budget spent");
        assert_eq!(run.tasks_recomputed, 0, "nothing recomputed");
    }

    #[test]
    fn recover_counts_recomputed_tasks() {
        let mut run = GraphRun::new(chain3(), 0, 0);
        run.finish(TaskId(0), WorkerId(0));
        run.finish(TaskId(1), WorkerId(0));
        run.recover(WorkerId(0)).unwrap();
        assert_eq!(run.tasks_recomputed, 2, "a and b resurrected");
    }

    #[test]
    fn resurrect_missing_inputs_recomputes_lost_lineage() {
        // c's retry found every replica of its input gone (self-evicted
        // via replica-dropped): the safety net must resurrect b, and
        // transitively a if a is also unavailable.
        let mut run = GraphRun::new(chain3(), 0, 0);
        let (a, b, c) = (TaskId(0), TaskId(1), TaskId(2));
        run.finish(a, WorkerId(0));
        run.finish(b, WorkerId(0));
        let before_remaining = run.remaining;
        run.who_has[a.idx()].retain(|_| false);
        run.who_has[b.idx()].retain(|_| false);
        let ready = run.resurrect_missing_inputs(c);
        assert_eq!(ready, vec![a], "only the root is immediately ready");
        assert_eq!(run.states[a.idx()], TaskState::Ready);
        assert_eq!(run.states[b.idx()], TaskState::Waiting);
        assert_eq!(run.unfinished_deps[b.idx()], 1);
        assert_eq!(run.remaining, before_remaining + 2);
        assert_eq!(run.tasks_recomputed, 2);
    }

    #[test]
    fn resurrect_missing_inputs_is_a_noop_with_live_replicas() {
        let mut run = GraphRun::new(chain3(), 0, 0);
        let (a, b, c) = (TaskId(0), TaskId(1), TaskId(2));
        run.finish(a, WorkerId(0));
        run.finish(b, WorkerId(0));
        let before_remaining = run.remaining;
        assert!(run.resurrect_missing_inputs(c).is_empty());
        assert_eq!(run.remaining, before_remaining);
        assert_eq!(run.tasks_recomputed, 0);
        assert!(matches!(run.states[b.idx()], TaskState::Finished(_)));
    }

    // ---- incremental extension (PR 9 tentpole) ----

    fn spec(id: u32, key: &str, inputs: Vec<TaskId>) -> crate::taskgraph::TaskSpec {
        use crate::taskgraph::Payload;
        crate::taskgraph::TaskSpec {
            id: TaskId(id),
            key: key.to_string(),
            inputs,
            duration_us: 10,
            output_size: 8,
            payload: Payload::MergeInputs,
            cores: 1,
        }
    }

    #[test]
    fn extend_installs_new_tasks_and_readies_roots() {
        let mut run = GraphRun::new(merge(2), 0, 0);
        run.set_open();
        let n0 = run.graph.len(); // 3
        let plan = run
            .extend(vec![
                spec(n0 as u32, "x", vec![]),
                spec(n0 as u32 + 1, "y", vec![TaskId(n0 as u32)]),
            ])
            .unwrap();
        assert_eq!(plan.ready, vec![TaskId(n0 as u32)], "only the new root starts");
        assert!(plan.pin.is_empty() && plan.resurrected.is_empty());
        assert_eq!(run.remaining, n0 + 2);
        assert_eq!(run.states[n0], TaskState::Ready);
        assert_eq!(run.states[n0 + 1], TaskState::Waiting);
        assert_eq!(run.unfinished_deps[n0 + 1], 1);
        assert_eq!(run.who_has.len(), n0 + 2);
        assert_eq!(run.priorities.len(), n0 + 2);
        assert_eq!(run.emitted_consumers[n0], GraphRun::NEVER_EMITTED);
    }

    #[test]
    fn extend_repins_resident_finished_inputs() {
        // a finished and resident on w0 with its emitted count stamped at
        // 1 (its lone base consumer b): grafting a second consumer must
        // produce a pin-data delta of exactly the gap, and re-stamp.
        let mut run = GraphRun::new(chain3(), 0, 0);
        let (a, b) = (TaskId(0), TaskId(1));
        run.finish(a, WorkerId(0));
        run.emitted_consumers[a.idx()] = 1;
        run.finish(b, WorkerId(0));
        run.emitted_consumers[b.idx()] = 1;
        let plan = run.extend(vec![spec(3, "d", vec![a])]).unwrap();
        assert_eq!(plan.pin, vec![(a, 1)]);
        assert_eq!(run.emitted_consumers[a.idx()], 2, "stamp catches up");
        assert!(plan.resurrected.is_empty());
        assert_eq!(plan.ready, vec![TaskId(3)], "input finished: new task starts");
        // A second extension with no new arcs to a produces no new pin.
        let plan2 = run.extend(vec![spec(4, "e", vec![TaskId(3)])]).unwrap();
        assert!(plan2.pin.is_empty());
    }

    #[test]
    fn extend_resurrects_evaporated_inputs_transitively() {
        // Both a and b finished on w0 then self-evicted (who_has empty):
        // extending with a consumer of b must resurrect b AND its input a
        // (the PR 3 lineage walk), and only a is immediately ready.
        let mut run = GraphRun::new(chain3(), 0, 0);
        let (a, b) = (TaskId(0), TaskId(1));
        run.finish(a, WorkerId(0));
        run.finish(b, WorkerId(0));
        run.who_has[a.idx()].retain(|_| false);
        run.who_has[b.idx()].retain(|_| false);
        let before = run.remaining;
        let plan = run.extend(vec![spec(3, "d", vec![b])]).unwrap();
        assert_eq!(plan.resurrected, vec![a, b]);
        assert!(plan.pin.is_empty());
        assert_eq!(plan.ready, vec![a]);
        assert_eq!(run.states[b.idx()], TaskState::Waiting);
        assert_eq!(run.states[3], TaskState::Waiting, "new task waits on b");
        assert_eq!(run.remaining, before + 3, "two resurrected + one new");
        assert_eq!(run.tasks_recomputed, 2);
    }

    #[test]
    fn extend_rejects_invalid_batch_without_mutation() {
        let mut run = GraphRun::new(merge(2), 0, 0);
        run.set_open();
        let before_tasks = run.graph.len();
        let before_remaining = run.remaining;
        // Wrong base id: ids must continue the dense space.
        assert!(run.extend(vec![spec(99, "x", vec![])]).is_err());
        assert_eq!(run.graph.len(), before_tasks);
        assert_eq!(run.remaining, before_remaining);
        assert_eq!(run.states.len(), before_tasks);
        assert_eq!(run.who_has.len(), before_tasks);
    }

    #[test]
    fn open_run_retires_only_after_close() {
        let mut run = GraphRun::new(merge(2), 0, 0);
        run.set_open();
        let w = WorkerId(0);
        for t in 0..3 {
            run.finish(TaskId(t), w);
        }
        assert_eq!(run.remaining, 0);
        assert!(!run.is_done(), "open + quiescent is not done");
        run.closed = true;
        assert!(run.is_done());
        // One-shot runs are born closed.
        let run2 = GraphRun::new(merge(2), 0, 0);
        assert!(run2.closed && !run2.open);
    }

    #[test]
    fn cancelled_steal_marker_dies_with_its_victim() {
        let mut run = GraphRun::new(merge(4), 0, 0);
        // Steal of t0 targeting w0 dissolves when w0 dies; live victim w1
        // still owes a response.
        run.states[0] = TaskState::Stealing { from: WorkerId(1), to: WorkerId(0) };
        run.recover(WorkerId(0)).unwrap();
        assert_eq!(run.cancelled_steals.get(&(TaskId(0), WorkerId(1))), Some(&1));
        // w1 dies before answering: the marker is a dead letter and must
        // go, or it would swallow a future genuine response for the
        // re-placed t0.
        run.recover(WorkerId(1)).unwrap();
        assert!(run.cancelled_steals.is_empty());
    }

    #[test]
    fn recovery_budget_exhaustion_returns_none() {
        let mut run = GraphRun::new(merge(2), 0, 0);
        run.max_recoveries = 1;
        run.states[0] = TaskState::Assigned(WorkerId(0));
        assert!(run.recover(WorkerId(0)).is_some());
        run.states[0] = TaskState::Assigned(WorkerId(1));
        assert!(run.recover(WorkerId(1)).is_none(), "budget exhausted");
        assert_eq!(run.recoveries, 2);
    }

    #[test]
    fn run_ids_are_never_reused() {
        let mut alloc = RunIdAlloc::default();
        let a = alloc.allocate();
        let b = alloc.allocate();
        assert_ne!(a, b);
        assert_eq!(a, RunId(0));
        assert_eq!(b, RunId(1));
    }

    #[test]
    fn strided_run_ids_are_disjoint_across_shards() {
        let mut shard0 = RunIdAlloc::strided(0, 4);
        let mut shard3 = RunIdAlloc::strided(3, 4);
        let a: Vec<RunId> = (0..3).map(|_| shard0.allocate()).collect();
        let b: Vec<RunId> = (0..3).map(|_| shard3.allocate()).collect();
        assert_eq!(a, vec![RunId(0), RunId(4), RunId(8)]);
        assert_eq!(b, vec![RunId(3), RunId(7), RunId(11)]);
        for r in a.iter().chain(b.iter()) {
            let owner = r.0 % 4;
            assert!(owner == 0 || owner == 3, "owner recoverable from the id");
        }
    }

    // ---- ReplicaSet (interned who_has small-vec) ----

    #[test]
    fn replica_set_inline_then_spill() {
        let mut r = ReplicaSet::new();
        assert!(r.is_empty());
        assert_eq!(r.first(), None);
        for i in 0..5 {
            r.push(WorkerId(i));
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.first(), Some(WorkerId(0)));
        assert!(r.contains(WorkerId(4)));
        assert!(!r.contains(WorkerId(9)));
        let order: Vec<WorkerId> = r.iter().collect();
        assert_eq!(r, order, "iteration preserves insertion order");
    }

    #[test]
    fn replica_set_retain_refills_inline_from_spill() {
        let mut r = ReplicaSet::new();
        for i in 0..5 {
            r.push(WorkerId(i));
        }
        // Drop the three inline entries: spilled 3 and 4 must move inline,
        // in order, so first() stays O(1) and pushes stay allocation-free.
        r.retain(|w| w.0 >= 3);
        assert_eq!(r, vec![WorkerId(3), WorkerId(4)]);
        assert_eq!(r.first(), Some(WorkerId(3)));
        r.retain(|_| false);
        assert!(r.is_empty());
        assert_eq!(r.first(), None);
    }

    #[test]
    fn replica_set_compares_with_vec() {
        let mut r = ReplicaSet::new();
        r.push(WorkerId(2));
        r.push(WorkerId(7));
        assert_eq!(r, vec![WorkerId(2), WorkerId(7)]);
        assert_ne!(r, vec![WorkerId(7), WorkerId(2)]);
        assert_ne!(r, vec![WorkerId(2)]);
    }
}
