//! TCP transport for the reactor: a sharded, readiness-driven control
//! plane. One accept thread hash-assigns each connection to one of N
//! *reactor shards*; each shard is a single thread running an epoll event
//! loop ([`super::poll`]) over the connections it owns, its own
//! [`Reactor`], and its own scheduler pool.
//!
//! Threading model (replaces the old thread-per-connection design, whose
//! 2 threads/connection collapsed past a few hundred clients):
//!
//! - **accept thread**: assigns global connection ids, routes each new
//!   socket to shard `conn % n_shards` over that shard's command channel.
//! - **shard threads** (`ServerConfig::shards`, default `min(cores, 4)`):
//!   nonblocking sockets, level-triggered epoll, per-connection read/write
//!   interest. A client's runs live wholly on its shard (`RunId % n_shards
//!   == shard` by strided allocation), so the per-task hot path never
//!   crosses a thread boundary. Total threads are `O(shards)`, not
//!   `O(clients)`.
//!
//! Workers are cluster-global: every shard's scheduler may place tasks on
//! any worker, but each worker's *socket* lives on one shard (its home).
//! Cross-shard traffic is confined to the intra-server command channels
//! ([`Cmd`]): worker registration/death broadcasts, worker messages about
//! a run owned elsewhere (`Cmd::Route`), and pre-encoded worker-bound
//! frames from other shards (`Cmd::Forward`), which the home shard splices
//! into the worker's output buffer. Ordering holds because the channels
//! are per-producer FIFO and every frame for a worker funnels through its
//! home shard's buffer.
//!
//! Hot-path discipline (this is the throughput ceiling every scaling item
//! sits on):
//!
//! - inbound frames accumulate across partial reads in a reused
//!   per-connection [`FrameAccumulator`] and decode via the streaming
//!   codec — no allocation per inbound message beyond the `Msg`'s own
//!   fields;
//! - the reactor pumps into a [`ShardSink`]: compute-task assignments are
//!   encoded from the borrowed [`ComputeDispatch`] straight into
//!   per-connection output buffers — no owned `Msg` is ever materialized
//!   on the dispatch path (zero allocations per task, asserted by
//!   `hotpath_micro`);
//! - flushing is *adaptive*: [`FlushTuner`] measures the per-`write(2)`
//!   syscall cost and sizes the coalescing threshold from it (an
//!   expensive syscall earns a bigger batch), instead of a fixed 64 KiB;
//!   everything flushes before the loop blocks, so idle latency is nil;
//! - a connection that can't take more bytes gets `EPOLLOUT` interest and
//!   the partial write resumes on writability ([`OutBuf::write_to`]) —
//!   a slow peer back-pressures its own buffer, never a thread.

use super::pool::SchedulerPool;
use super::poll::{Events, Interest, Poller, Waker};
use super::reactor::{
    ComputeDispatch, Dest, Origin, OutboundSink, Reactor, ReactorReport, SharedIds,
};
use super::window::BoundedWindow;
use crate::overhead::RuntimeProfile;
use crate::protocol::{
    append_frame, append_frame_with, decode_msg, FrameAccumulator, FrameError, Msg, NbRead, RunId,
};
use crate::scheduler::{WorkerId, WorkerInfo};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
// Model-checkable primitives (std unless built with `--cfg loom`); the
// mpsc channels stay std — the modelled paths only use non-blocking sends.
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Arc, Mutex};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Instant;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address; use port 0 for ephemeral.
    pub addr: String,
    /// Default scheduler name: `random` | `ws` | `dask-ws`. A `submit-graph`
    /// may override it per run.
    pub scheduler: String,
    /// Seed for the random scheduler.
    pub seed: u64,
    /// Runtime profile to charge on the hot path.
    pub profile: RuntimeProfile,
    /// Busy-wait the profile costs (Dask-emulation baseline).
    pub emulate: bool,
    /// Dispatch fairness policy over concurrent runs: `rr` (default) |
    /// `arrival` | `weighted`. See [`super::fairness`].
    pub fairness: String,
    /// Cap on concurrently executing runs per client; excess submissions
    /// park in the admission queue (`run-queued`).
    pub max_live_runs_per_client: usize,
    /// Cap on *parked* submissions per client; past it a submission fails
    /// instead of parking (bounds a runaway submitter's server memory).
    pub max_queued_runs_per_client: usize,
    /// Completed-run reports retained in memory (older ones are dropped;
    /// `reports_since` watermarks stay consistent).
    pub report_retention: usize,
    /// Per-run worker-disconnect recovery budget (see
    /// [`crate::server::DEFAULT_MAX_RECOVERIES`]). With 0, any non-trivial
    /// loss fails the run — the setting the client-side resubmission knob
    /// ([`crate::client::Client::with_retry_exhausted`]) pairs with.
    pub max_recoveries: u32,
    /// Reactor shards. Each client connection is assigned to one shard
    /// (`conn % shards`) which owns its runs end to end; workers register
    /// on their own shard and are broadcast to the rest. Default:
    /// `min(available cores, 4)`. The wire protocol is unaffected.
    pub shards: usize,
    /// Copies kept per hot/critical output, primary included (1 = off):
    /// producers are told to push k-1 replicas so most worker deaths
    /// purge addresses instead of recomputing lineage. See
    /// `docs/recovery.md`.
    pub replication: usize,
    /// Consumer-count threshold above which an output counts as hot
    /// ([`crate::taskgraph::replication_hints`]).
    pub replication_fanout: u32,
}

/// `min(available cores, 4)` — past a handful of shards the scheduler
/// itself is rarely the bottleneck and cross-shard worker chatter starts
/// to cost more than the parallelism buys (paper §V scales to 4).
fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4)
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            scheduler: "ws".into(),
            seed: 2020,
            profile: RuntimeProfile::rust(),
            emulate: false,
            fairness: "rr".into(),
            max_live_runs_per_client: super::reactor::DEFAULT_MAX_LIVE_RUNS_PER_CLIENT,
            max_queued_runs_per_client: super::reactor::DEFAULT_MAX_QUEUED_RUNS_PER_CLIENT,
            report_retention: super::reactor::DEFAULT_REPORT_RETENTION,
            max_recoveries: super::state::DEFAULT_MAX_RECOVERIES,
            shards: default_shards(),
            replication: 1,
            replication_fanout: super::reactor::DEFAULT_REPLICATION_FANOUT,
        }
    }
}

/// Recycled cross-shard forward buffers: a shard pops one per (remote
/// shard, connection) it emits to, the receiving shard pushes it back
/// after splicing the frames into the connection's output buffer. Bounded
/// so a burst cannot pin memory forever.
///
/// Public (with [`pool_get`]/[`pool_put`]/[`deliver_forward`]) for the
/// model-checking suite in `tests/loom_models.rs`, which verifies the
/// buffer-conservation invariant — every forwarded batch is spliced into
/// a live connection XOR returned to the pool — under a concurrent
/// worker death.
pub type BufPool = Arc<Mutex<Vec<Vec<u8>>>>;

/// Pool capacity bound (see [`BufPool`]).
pub const BUF_POOL_MAX: usize = 64;

/// Buffers above this capacity are dropped instead of pooled: a data-plane
/// burst (multi-MB `data-reply` batches) must not pin up to
/// `BUF_POOL_MAX × burst-size` bytes on an idle server forever.
const BUF_POOL_MAX_CAPACITY: usize = 256 * 1024;

/// Pop a recycled buffer (or a fresh one). See [`BufPool`].
pub fn pool_get(pool: &BufPool) -> Vec<u8> {
    pool.lock().unwrap().pop().unwrap_or_default()
}

/// Return a buffer to the pool (dropped if oversized or the pool is
/// full). See [`BufPool`].
pub fn pool_put(pool: &BufPool, mut buf: Vec<u8>) {
    if buf.capacity() > BUF_POOL_MAX_CAPACITY {
        return;
    }
    buf.clear();
    let mut p = pool.lock().unwrap();
    if p.len() < BUF_POOL_MAX {
        p.push(buf);
    }
}

/// Splice a forwarded frame batch into a connection's output buffer
/// (`out` is `None` when the connection is already gone — a forward
/// racing a close/death) and recycle the batch either way. Returns
/// whether the bytes were delivered.
///
/// This is the receiving half of the cross-shard [`Cmd::Forward`] path,
/// public so the model-checking suite (`tests/loom_models.rs`) can drive
/// a forward racing a worker death and check the conservation invariant:
/// the batch is delivered XOR dropped, and its buffer returns to the pool
/// exactly once in both cases — no frame is ever written to a corpse.
pub fn deliver_forward(out: Option<&mut Vec<u8>>, bytes: Vec<u8>, buf_pool: &BufPool) -> bool {
    match out {
        Some(dst) => {
            dst.extend_from_slice(&bytes);
            pool_put(buf_pool, bytes);
            true
        }
        None => {
            pool_put(buf_pool, bytes);
            false
        }
    }
}

/// Published completed-run reports: a [`BoundedWindow`] — the same type
/// the reactor keeps its own history in, so the invariant
/// `dropped + len == completions` lives in exactly one place. All shards
/// publish into this one window (each appends its fresh tail under the
/// lock); a poller that lags by more than the retention window misses the
/// evicted reports (by design: that is the bound on a long-lived server's
/// memory).
type ReportStore = BoundedWindow<ReactorReport>;

/// epoll token reserved for a shard's [`Waker`] eventfd. Connection ids
/// are assigned from 0 upward, so `u64::MAX` can never collide.
const WAKER_TOKEN: u64 = u64::MAX;

/// Frames decoded from one connection per readiness event before the loop
/// moves on. Level-triggered epoll re-reports the remaining buffered
/// input next iteration, so one chatty peer cannot monopolize a shard;
/// the cap just bounds the time between pump rounds.
const FRAMES_PER_EVENT: u32 = 128;

/// Age bound on the adaptive flush: under sustained load the event loop
/// may never go idle, and a small buffer — a `welcome` for a freshly
/// connecting peer, a tiny run's `graph-done` — would otherwise ride
/// below the byte threshold indefinitely. After this many loop iterations
/// without a full flush, everything buffered goes out regardless of size.
const FLUSH_MAX_ROUNDS: u32 = 64;

/// Floor of the adaptive flush threshold — below this, coalescing gains
/// nothing over the syscall we are about to pay anyway.
const FLUSH_MIN_BYTES: usize = 4 * 1024;

/// Ceiling of the adaptive flush threshold — past this, holdback latency
/// and buffer growth cost more than the saved syscalls.
const FLUSH_MAX_BYTES: usize = 256 * 1024;

/// Target amortized syscall overhead, in nanoseconds per buffered byte.
/// `threshold = syscall_ns / this`: a 2 µs `write(2)` earns a 40 KiB
/// batch; a cheap loopback write flushes eagerly at the floor.
const FLUSH_TARGET_NS_PER_BYTE: f64 = 0.05;

/// Adaptive flush threshold from measured per-syscall cost, replacing the
/// old fixed 64 KiB batch size: an EWMA over the wall time of each
/// `write(2)` sets how many bytes a flush must amortize. Slow transports
/// (loaded NIC, cross-node) coalesce harder; a fast loopback stays near
/// the floor and keeps latency down.
struct FlushTuner {
    /// EWMA of per-`write(2)` wall time, nanoseconds.
    call_ns: f64,
    /// Derived byte threshold, kept cached so the hot-path query is one
    /// integer compare.
    threshold: usize,
}

/// EWMA smoothing factor: light enough to ride out scheduler noise,
/// heavy enough to adapt within ~50 writes.
const FLUSH_EWMA_ALPHA: f64 = 0.05;

impl FlushTuner {
    fn new() -> FlushTuner {
        // Prior of 2 µs per call (a typical loopback write incl. kernel
        // copy) → initial threshold 40 KiB, near the old fixed constant.
        let mut t = FlushTuner { call_ns: 2_000.0, threshold: 0 };
        t.retune();
        t
    }

    fn retune(&mut self) {
        let raw = self.call_ns / FLUSH_TARGET_NS_PER_BYTE;
        self.threshold = (raw as usize).clamp(FLUSH_MIN_BYTES, FLUSH_MAX_BYTES);
    }

    /// Fold one measured `write(2)` into the EWMA.
    fn record(&mut self, elapsed_ns: u64) {
        self.call_ns += FLUSH_EWMA_ALPHA * (elapsed_ns as f64 - self.call_ns);
        self.retune();
    }

    /// Should a buffer of `pending` bytes flush now? One integer compare —
    /// runs once per connection per loop iteration (hot, zero-alloc).
    fn should_flush(&self, pending: usize) -> bool {
        pending >= self.threshold
    }
}

/// Compact the output buffer's consumed prefix once it exceeds this —
/// below it, the eventual full drain resets the buffer for free.
const OUT_COMPACT_BYTES: usize = 32 * 1024;

/// A connection's pending output: appended frames plus a cursor over what
/// `write(2)` has already taken. Partial writes park here and resume on
/// `EPOLLOUT` instead of blocking a thread.
struct OutBuf {
    buf: Vec<u8>,
    /// Bytes of `buf` already written to the socket.
    pos: usize,
}

impl OutBuf {
    fn new() -> OutBuf {
        OutBuf { buf: Vec::new(), pos: 0 }
    }

    /// Unwritten bytes.
    fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// The append position for new frames, first reclaiming consumed
    /// space: fully drained resets for free; a large consumed prefix
    /// under a partial write compacts so the buffer can't creep.
    fn tail(&mut self) -> &mut Vec<u8> {
        if self.pos > 0 && self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > OUT_COMPACT_BYTES {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        &mut self.buf
    }

    /// Write as much pending output as the socket takes. `Ok(true)` —
    /// drained; `Ok(false)` — the socket is full (caller arms `EPOLLOUT`
    /// and resumes on writability); `Err` — the connection is dead.
    /// Each successful `write(2)`'s wall time feeds the [`FlushTuner`].
    /// Hot (one call per flushing connection per loop): zero-alloc.
    fn write_to(&mut self, stream: &mut TcpStream, tuner: &mut FlushTuner) -> io::Result<bool> {
        while self.pos < self.buf.len() {
            let t0 = Instant::now();
            match stream.write(&self.buf[self.pos..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "peer stopped accepting bytes",
                    ))
                }
                Ok(n) => {
                    tuner.record(t0.elapsed().as_nanos() as u64);
                    self.pos += n;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.pos = 0;
        Ok(true)
    }
}

/// One nonblocking connection owned by a shard.
struct Conn {
    stream: TcpStream,
    /// Inbound reassembly across partial reads.
    acc: FrameAccumulator,
    /// Outbound frames not yet accepted by the socket.
    out: OutBuf,
    /// Whether `EPOLLOUT` interest is currently armed.
    want_write: bool,
    origin: Origin,
}

/// Intra-server commands between the accept thread and the shards, and
/// between shards. Each shard's channel is per-producer FIFO
/// (`std::sync::mpsc`), which the cross-shard ordering arguments below
/// rely on: a worker's home shard emits its `WorkerJoined` before any
/// `Forward` carrying frames for it, so receivers always learn the route
/// first.
enum Cmd {
    /// Accept thread → owning shard: adopt this fresh socket.
    Accept { conn: u64, stream: TcpStream },
    /// Worker's home shard → every other shard: a worker registered;
    /// `home`/`conn` locate its socket for [`Route::Remote`].
    WorkerJoined { info: WorkerInfo, data_addr: String, conn: u64, home: usize },
    /// Worker's home shard → every other shard: its connection died.
    /// Receivers drop the route *then* run recovery, so nothing emitted
    /// during recovery can target the corpse. Idempotent.
    WorkerDead { id: WorkerId },
    /// Non-owning shard → run-owning shard: a worker message about one of
    /// your runs (`task-finished`, `task-erred`, `steal-response`,
    /// `data-to-server`).
    Route { from: WorkerId, msg: Msg },
    /// Any shard → worker's home shard: pre-encoded frames to splice into
    /// the worker's output buffer ([`deliver_forward`]).
    Forward { conn: u64, bytes: Vec<u8> },
    /// Stop the shard's event loop.
    Stop,
}

/// A shard's command inbox plus the eventfd that pops its event loop out
/// of `epoll_wait`. Senders enqueue, then wake — the eventfd is
/// level-triggered, so a wake can never be lost between the queue check
/// and the block.
#[derive(Clone)]
struct ShardLink {
    tx: Sender<Cmd>,
    waker: Arc<Waker>,
}

impl ShardLink {
    fn send(&self, cmd: Cmd) {
        if self.tx.send(cmd).is_ok() {
            self.waker.wake();
        }
    }
}

/// Where a destination's socket lives: on this shard, or on another
/// shard (worker registered elsewhere — frames go out via
/// [`Cmd::Forward`]). Clients are always `Local` to their shard.
#[derive(Clone, Copy)]
enum Route {
    Local(u64),
    Remote { shard: usize, conn: u64 },
}

/// The run a worker-originated message concerns — `None` for traffic
/// that is connection-local (registration, liveness). Used to route a
/// worker message to the shard owning the run: strided [`RunId`]
/// allocation makes ownership a modulo.
fn run_of(msg: &Msg) -> Option<RunId> {
    match msg {
        Msg::TaskFinished(info) => Some(info.run),
        Msg::TaskErred { run, .. } => Some(*run),
        Msg::StealResponse { run, .. } => Some(*run),
        Msg::DataToServer { run, .. } => Some(*run),
        Msg::ReplicaAdded { run, .. } => Some(*run),
        Msg::ReplicaDropped { run, .. } => Some(*run),
        _ => None,
    }
}

/// Sink the reactor pumps into: frames append straight to per-connection
/// output buffers (local destinations) or per-(shard, conn) forward
/// buffers (workers homed elsewhere). Compute-task assignments encode
/// from the borrowed [`ComputeDispatch`] — no owned `Msg` is built, so a
/// warm dispatch performs zero heap allocations (asserted by
/// `hotpath_micro`).
struct ShardSink<'a> {
    conns: &'a mut HashMap<u64, Conn>,
    routes: &'a HashMap<Dest, Route>,
    fwd: &'a mut HashMap<(usize, u64), Vec<u8>>,
    buf_pool: &'a BufPool,
}

impl ShardSink<'_> {
    fn buf_for(&mut self, dest: Dest, op: &str) -> Option<&mut Vec<u8>> {
        match self.routes.get(&dest).copied() {
            Some(Route::Local(conn)) => match self.conns.get_mut(&conn) {
                Some(c) => Some(c.out.tail()),
                None => {
                    log::warn!("connection gone for {dest:?}; dropping {op}");
                    None
                }
            },
            Some(Route::Remote { shard, conn }) => Some(
                self.fwd.entry((shard, conn)).or_insert_with(|| pool_get(self.buf_pool)),
            ),
            None => {
                log::warn!("no route for {dest:?}; dropping {op}");
                None
            }
        }
    }
}

impl OutboundSink for ShardSink<'_> {
    fn emit_msg(&mut self, dest: Dest, msg: Msg) {
        if let Some(buf) = self.buf_for(dest, msg.op()) {
            if let Err(e) = append_frame(buf, &msg) {
                log::warn!("dropping oversized {op}: {e}", op = msg.op());
            }
        }
    }

    fn emit_compute(&mut self, dispatch: &ComputeDispatch<'_>) {
        if let Some(buf) = self.buf_for(Dest::Worker(dispatch.worker), "compute-task") {
            if let Err(e) = append_frame_with(buf, |body| dispatch.encode_into(body)) {
                log::warn!("dropping oversized compute-task: {e}");
            }
        }
    }
}

/// One reactor shard: an epoll event loop over the connections it owns,
/// its reactor + scheduler pool, and links to its peers.
struct Shard {
    index: usize,
    n_shards: usize,
    reactor: Reactor,
    poller: Poller,
    waker: Arc<Waker>,
    rx: Receiver<Cmd>,
    /// Links to every shard (self included; broadcast skips it).
    links: Vec<ShardLink>,
    conns: HashMap<u64, Conn>,
    routes: HashMap<Dest, Route>,
    /// Reactor reply scratch; empty between uses ([`Shard::route_out`]).
    out: Vec<(Dest, Msg)>,
    /// Pending cross-shard frame batches by (home shard, conn).
    fwd: HashMap<(usize, u64), Vec<u8>>,
    fwd_keys: Vec<(usize, u64)>,
    wake_buf: Vec<bool>,
    flush_keys: Vec<u64>,
    buf_pool: BufPool,
    tuner: FlushTuner,
    reports: Arc<Mutex<ReportStore>>,
    reported: usize,
    stop: bool,
}

impl Shard {
    fn run(mut self) {
        let mut events = Events::with_capacity(256);
        // Copied out of `events` so handlers can borrow `self` mutably.
        let mut ready: Vec<(u64, bool, bool, bool)> = Vec::new();
        let mut pumping = false;
        let mut rounds: u32 = 0;
        while !self.stop {
            // Run-fair intake: while worker-bound messages are parked,
            // poll without blocking — a pump round runs every iteration,
            // so a huge backlog is emitted in bounded slices interleaved
            // with fresh events. Block only when fully drained, and flush
            // everything first: nothing fresher can join the buffers.
            let timeout = if pumping {
                Some(0)
            } else {
                self.flush_conns(true);
                rounds = 0;
                None
            };
            let n_ready = match self.poller.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(e) => {
                    log::warn!("shard {}: epoll_wait: {e}", self.index);
                    0
                }
            };
            ready.clear();
            for ev in events.iter().take(n_ready) {
                ready.push((ev.token, ev.readable, ev.writable, ev.hangup));
            }
            for &(token, readable, writable, hangup) in &ready {
                if token == WAKER_TOKEN {
                    self.waker.drain();
                    continue;
                }
                if readable || hangup {
                    if !self.read_conn(token) {
                        self.close_conn(token);
                        continue;
                    }
                }
                if writable {
                    self.flush_conn(token, true);
                }
            }
            self.drain_cmds();
            pumping = self.pump_once();
            self.dispatch_fwd();
            rounds += 1;
            let flush_all = rounds >= FLUSH_MAX_ROUNDS;
            if flush_all {
                rounds = 0;
            }
            self.flush_conns(flush_all);
            self.publish_reports();
        }
        self.shutdown_conns();
    }

    /// Adopt a freshly accepted socket.
    fn add_conn(&mut self, id: u64, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        stream.set_nodelay(true).ok();
        if let Err(e) = self.poller.register(stream.as_raw_fd(), id, Interest::READ) {
            log::warn!("conn {id}: epoll register failed: {e}");
            return;
        }
        self.conns.insert(
            id,
            Conn {
                stream,
                acc: FrameAccumulator::new(),
                out: OutBuf::new(),
                want_write: false,
                origin: Origin::Unregistered { conn: id },
            },
        );
    }

    /// Drain decodable frames from one readable connection; `false` means
    /// close it. Caps at [`FRAMES_PER_EVENT`] frames — level-triggered
    /// epoll re-reports the remaining buffered input next iteration.
    fn read_conn(&mut self, id: u64) -> bool {
        for _ in 0..FRAMES_PER_EVENT {
            let msg = {
                let Some(conn) = self.conns.get_mut(&id) else { return true };
                match conn.acc.poll_frame(&mut conn.stream) {
                    Ok(NbRead::Frame(bytes)) => match decode_msg(bytes) {
                        Ok(msg) => msg,
                        Err(e) => {
                            log::warn!("conn {id}: bad message: {e}; closing");
                            return false;
                        }
                    },
                    Ok(NbRead::WouldBlock) => return true,
                    Ok(NbRead::Closed) => return false,
                    Err(FrameError::Closed) => return false,
                    Err(e) => {
                        log::warn!("conn {id}: frame error: {e}");
                        return false;
                    }
                }
            };
            self.on_frame(id, msg);
        }
        true
    }

    /// One decoded inbound message: route it cross-shard if a worker is
    /// talking about a run owned elsewhere, else feed the local reactor
    /// and bind registrations to the connection.
    fn on_frame(&mut self, id: u64, msg: Msg) {
        let origin = self
            .conns
            .get(&id)
            .map(|c| c.origin)
            .unwrap_or(Origin::Unregistered { conn: id });
        if let Origin::Worker(w) = origin {
            if let Some(run) = run_of(&msg) {
                let owner = run.0 as usize % self.n_shards;
                if owner != self.index {
                    self.links[owner].send(Cmd::Route { from: w, msg });
                    return;
                }
            }
        }
        let registering_client = matches!(
            (&origin, &msg),
            (Origin::Unregistered { .. }, Msg::RegisterClient { .. })
        );
        let registering_worker = matches!(
            (&origin, &msg),
            (Origin::Unregistered { .. }, Msg::RegisterWorker { .. })
        );
        // Captured before the reactor consumes the message: the join
        // broadcast below needs them (cold path — registration only).
        let worker_detail = match (registering_worker, &msg) {
            (true, Msg::RegisterWorker { ncores, node, data_addr, .. }) => {
                Some((*ncores, *node, data_addr.clone()))
            }
            _ => None,
        };
        self.reactor.on_message(origin, msg, &mut self.out);
        // Bind a freshly assigned id to this connection: the Welcome the
        // reactor just emitted names the id. The route is inserted before
        // `route_out`, so the Welcome itself resolves Local — and for a
        // worker it is appended to the output buffer *before* the join
        // broadcast goes out, so remote shards' forwarded frames always
        // land after it.
        if registering_client || registering_worker {
            if let Some((dest, Msg::Welcome { id: assigned })) = self
                .out
                .iter()
                .rev()
                .find(|(_, m)| matches!(m, Msg::Welcome { .. }))
            {
                let origin = if registering_client {
                    Origin::Client(*assigned)
                } else {
                    Origin::Worker(WorkerId(*assigned))
                };
                let dest = *dest;
                if let Some(conn) = self.conns.get_mut(&id) {
                    conn.origin = origin;
                }
                self.routes.insert(dest, Route::Local(id));
                if let (Origin::Worker(w), Some((ncores, node, data_addr))) =
                    (origin, worker_detail)
                {
                    let info = WorkerInfo { id: w, ncores, node };
                    let home = self.index;
                    self.broadcast(|| Cmd::WorkerJoined {
                        info,
                        data_addr: data_addr.clone(),
                        conn: id,
                        home,
                    });
                }
            }
        }
        self.route_out();
    }

    /// Deliver every queued reactor reply ([`Shard::out`]) to its route.
    fn route_out(&mut self) {
        let mut out = std::mem::take(&mut self.out);
        for (dest, msg) in out.drain(..) {
            self.send_msg(dest, &msg);
        }
        // Hand the (now empty) vector back so its capacity is reused.
        self.out = out;
    }

    fn send_msg(&mut self, dest: Dest, msg: &Msg) {
        let Shard { conns, routes, fwd, buf_pool, .. } = self;
        match routes.get(&dest).copied() {
            Some(Route::Local(conn)) => match conns.get_mut(&conn) {
                Some(c) => {
                    if let Err(e) = append_frame(c.out.tail(), msg) {
                        log::warn!("dropping oversized {op}: {e}", op = msg.op());
                    }
                }
                None => log::warn!("connection gone for {dest:?}; dropping {op}", op = msg.op()),
            },
            Some(Route::Remote { shard, conn }) => {
                let buf = fwd.entry((shard, conn)).or_insert_with(|| pool_get(buf_pool));
                if let Err(e) = append_frame(buf, msg) {
                    log::warn!("dropping oversized {op}: {e}", op = msg.op());
                }
            }
            None => log::warn!("no route for {dest:?}; dropping {op}", op = msg.op()),
        }
    }

    /// One fairness round: up to a quota of parked messages from the
    /// policy-chosen run go straight into output/forward buffers —
    /// compute-tasks encoded borrowed, no owned `Msg` built.
    fn pump_once(&mut self) -> bool {
        let Shard { reactor, conns, routes, fwd, buf_pool, .. } = self;
        let mut sink = ShardSink { conns, routes, fwd, buf_pool };
        reactor.pump_into(&mut sink).is_some()
    }

    /// Hand accumulated cross-shard frame batches to their home shards.
    /// Wakes are coalesced: one eventfd write per destination shard per
    /// call, however many batches went its way.
    fn dispatch_fwd(&mut self) {
        if self.fwd.is_empty() {
            return;
        }
        self.fwd_keys.clear();
        self.fwd_keys.extend(self.fwd.keys().copied());
        self.wake_buf.clear();
        self.wake_buf.resize(self.n_shards, false);
        for &(shard, conn) in &self.fwd_keys {
            let Some(bytes) = self.fwd.remove(&(shard, conn)) else { continue };
            if bytes.is_empty() {
                // Every append failed (oversized); nothing to forward.
                pool_put(&self.buf_pool, bytes);
                continue;
            }
            match self.links[shard].tx.send(Cmd::Forward { conn, bytes }) {
                Ok(()) => self.wake_buf[shard] = true,
                // A dead shard hands the command back inside the error;
                // recycle the buffer (conservation invariant).
                Err(e) => {
                    if let Cmd::Forward { bytes, .. } = e.0 {
                        pool_put(&self.buf_pool, bytes);
                    }
                }
            }
        }
        for shard in 0..self.n_shards {
            if self.wake_buf[shard] {
                self.links[shard].waker.wake();
            }
        }
    }

    fn drain_cmds(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok(cmd) => self.on_cmd(cmd),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.stop = true;
                    break;
                }
            }
        }
    }

    fn on_cmd(&mut self, cmd: Cmd) {
        match cmd {
            Cmd::Accept { conn, stream } => self.add_conn(conn, stream),
            Cmd::WorkerJoined { info, data_addr, conn, home } => {
                self.routes
                    .insert(Dest::Worker(info.id), Route::Remote { shard: home, conn });
                self.reactor.register_remote_worker(info, data_addr);
            }
            Cmd::WorkerDead { id } => {
                // Route removed first: recovery below re-emits the dead
                // worker's assignments, and none of them may resolve to
                // the corpse. `remove` returning None means we already
                // processed this death — broadcasts are idempotent.
                if self.routes.remove(&Dest::Worker(id)).is_some() {
                    self.reactor.on_disconnect(Origin::Worker(id), &mut self.out);
                    self.route_out();
                }
            }
            Cmd::Route { from, msg } => {
                self.reactor.on_message(Origin::Worker(from), msg, &mut self.out);
                self.route_out();
            }
            Cmd::Forward { conn, bytes } => {
                let delivered = deliver_forward(
                    self.conns.get_mut(&conn).map(|c| c.out.tail()),
                    bytes,
                    &self.buf_pool,
                );
                if !delivered {
                    // Forward raced the connection's close; the sender's
                    // route is (or is about to be) torn down by the death
                    // broadcast. Dropping is correct — recovery re-emits.
                    log::debug!("conn {conn}: dropped forward for closed connection");
                }
            }
            Cmd::Stop => self.stop = true,
        }
    }

    /// Flush one connection (`force` bypasses the adaptive threshold:
    /// writability resumption and pre-block flushes must always write).
    fn flush_conn(&mut self, id: u64, force: bool) {
        let failed = {
            let Shard { conns, poller, tuner, .. } = self;
            let Some(conn) = conns.get_mut(&id) else { return };
            if conn.out.pending() == 0 {
                if conn.want_write {
                    conn.want_write = false;
                    let _ = poller.rearm(conn.stream.as_raw_fd(), id, Interest::READ);
                }
                return;
            }
            if !force && !conn.want_write && !tuner.should_flush(conn.out.pending()) {
                return;
            }
            match conn.out.write_to(&mut conn.stream, tuner) {
                Ok(true) => {
                    if conn.want_write {
                        conn.want_write = false;
                        let _ = poller.rearm(conn.stream.as_raw_fd(), id, Interest::READ);
                    }
                    false
                }
                Ok(false) => {
                    // Socket full: resume on writability.
                    if !conn.want_write {
                        conn.want_write = true;
                        let _ = poller.rearm(conn.stream.as_raw_fd(), id, Interest::READ_WRITE);
                    }
                    false
                }
                Err(e) => {
                    log::warn!("conn {id}: write error: {e}");
                    true
                }
            }
        };
        if failed {
            self.close_conn(id);
        }
    }

    /// Flush every connection with pending output (or an armed write
    /// interest, so drained buffers drop `EPOLLOUT` promptly).
    fn flush_conns(&mut self, force: bool) {
        let mut keys = std::mem::take(&mut self.flush_keys);
        keys.clear();
        keys.extend(
            self.conns
                .iter()
                .filter(|(_, c)| c.out.pending() > 0 || c.want_write)
                .map(|(&id, _)| id),
        );
        for &id in keys.iter() {
            self.flush_conn(id, force);
        }
        self.flush_keys = keys;
    }

    fn close_conn(&mut self, id: u64) {
        let Some(conn) = self.conns.remove(&id) else { return };
        let _ = self.poller.deregister(conn.stream.as_raw_fd());
        let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        match conn.origin {
            Origin::Worker(w) => {
                // Same discipline as the remote side (`Cmd::WorkerDead`):
                // route gone before recovery runs, broadcast before the
                // local reactor re-emits the corpse's assignments.
                self.routes.remove(&Dest::Worker(w));
                self.broadcast(|| Cmd::WorkerDead { id: w });
                self.reactor.on_disconnect(Origin::Worker(w), &mut self.out);
                self.route_out();
            }
            Origin::Client(c) => {
                self.routes.remove(&Dest::Client(c));
                self.reactor.on_disconnect(Origin::Client(c), &mut self.out);
                self.route_out();
            }
            Origin::Unregistered { .. } => {}
        }
    }

    /// Send a command to every *other* shard.
    fn broadcast(&self, make: impl Fn() -> Cmd) {
        for (i, link) in self.links.iter().enumerate() {
            if i == self.index {
                continue;
            }
            link.send(make());
        }
    }

    /// Publish new reports into the shared window (only the fresh tail is
    /// ever copied; both windows count against the monotonic completion
    /// total, so `dropped + len == completions` holds on both sides).
    fn publish_reports(&mut self) {
        let total = self.reactor.report_count();
        if total > self.reported {
            let all = self.reactor.reports();
            let fresh = total - self.reported;
            let mut shared = self.reports.lock().unwrap();
            if fresh > all.len() {
                // More completions this iteration than the reactor window
                // holds (tiny retention + a burst): the overflow is gone
                // on both sides.
                shared.note_missed(fresh - all.len());
            }
            let start = all.len().saturating_sub(fresh);
            shared.extend_from_slice(&all[start..]);
            self.reported = total;
        }
    }

    fn shutdown_conns(&mut self) {
        for (_, conn) in self.conns.drain() {
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Running server: address, per-graph reports, shutdown control.
pub struct ServerHandle {
    pub addr: SocketAddr,
    reports: Arc<Mutex<ReportStore>>,
    stop: Arc<AtomicBool>,
    links: Vec<ShardLink>,
    threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Reports of all graphs completed so far (the retained window).
    ///
    /// Prefer [`ServerHandle::reports_since`] in polling loops — this
    /// clones the full retained history every call.
    pub fn reports(&self) -> Vec<ReactorReport> {
        self.reports_since(0).0
    }

    /// Reports with absolute completion index ≥ `watermark`, plus the
    /// watermark to pass to the *next* call. Pollers must advance using
    /// the returned watermark — not by counting returned reports — so
    /// exactly-once delivery holds even when the retention window has
    /// evicted part of the poller's gap (the evicted reports are
    /// permanently missed; counting only the returned ones would make a
    /// lagging poller re-receive the window's tail forever).
    ///
    /// History is bounded: the server retains only the newest
    /// `report_retention` reports (`ServerConfig`); `report_count` keeps
    /// counting evicted reports, so watermarks never go backwards.
    pub fn reports_since(&self, watermark: usize) -> (Vec<ReactorReport>, usize) {
        let store = self.reports.lock().unwrap();
        let (fresh, next) = store.since(watermark);
        (fresh.to_vec(), next)
    }

    /// Total completed-run reports so far (a cheap watermark probe;
    /// monotonic, includes reports evicted from the retained window).
    pub fn report_count(&self) -> usize {
        self.reports.lock().unwrap().total()
    }

    /// Stop the server and join every thread it spawned — the accept
    /// thread and all shard event loops (shards close their own
    /// connections on the way out).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for link in &self.links {
            link.send(Cmd::Stop);
        }
        // Unblock the accept loop.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start the server; returns once the listener is bound.
pub fn serve(config: ServerConfig) -> Result<ServerHandle> {
    // Validate here with clean errors — the reactor builders assert, which
    // is right for programmatic misuse but not for a CLI flag.
    if config.max_live_runs_per_client == 0 {
        return Err(anyhow!("max_live_runs_per_client must be at least 1"));
    }
    if config.max_queued_runs_per_client == 0 {
        return Err(anyhow!("max_queued_runs_per_client must be at least 1"));
    }
    if config.report_retention == 0 {
        return Err(anyhow!("report_retention must be at least 1"));
    }
    if config.shards == 0 {
        return Err(anyhow!("shards must be at least 1"));
    }
    let n_shards = config.shards;

    let listener = TcpListener::bind(&config.addr)
        .with_context(|| format!("bind {}", config.addr))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let reports = Arc::new(Mutex::new(ReportStore::new(config.report_retention)));
    let buf_pool: BufPool = Arc::new(Mutex::new(Vec::new()));
    // Worker/client ids are cluster-global; every shard's reactor draws
    // from this one pair of counters.
    let ids = std::sync::Arc::new(SharedIds::default());

    let mut links: Vec<ShardLink> = Vec::with_capacity(n_shards);
    let mut rxs: Vec<Receiver<Cmd>> = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let (tx, rx) = channel::<Cmd>();
        let waker = Arc::new(Waker::new().context("create shard waker")?);
        links.push(ShardLink { tx, waker });
        rxs.push(rx);
    }

    let mut threads = Vec::new();
    for (s, rx) in rxs.into_iter().enumerate() {
        let pool = SchedulerPool::new(&config.scheduler, config.seed)
            .ok_or_else(|| anyhow!("unknown scheduler {:?}", config.scheduler))?;
        let policy = super::fairness::by_name(&config.fairness)
            .ok_or_else(|| anyhow!("unknown fairness policy {:?}", config.fairness))?;
        let reactor = Reactor::new(pool, config.profile.clone(), config.emulate)
            .with_fairness(policy)
            .with_admission_cap(config.max_live_runs_per_client)
            .with_admission_queue_cap(config.max_queued_runs_per_client)
            .with_report_retention(config.report_retention)
            .with_max_recoveries(config.max_recoveries)
            .with_replication(config.replication, config.replication_fanout)
            .with_shared_ids(ids.clone())
            .with_run_stride(s as u32, n_shards as u32);
        let poller = Poller::new().context("create shard poller")?;
        let waker = links[s].waker.clone();
        poller
            .register(waker.fd(), WAKER_TOKEN, Interest::READ)
            .context("register shard waker")?;
        let shard = Shard {
            index: s,
            n_shards,
            reactor,
            poller,
            waker,
            rx,
            links: links.clone(),
            conns: HashMap::new(),
            routes: HashMap::new(),
            out: Vec::new(),
            fwd: HashMap::new(),
            fwd_keys: Vec::new(),
            wake_buf: vec![false; n_shards],
            flush_keys: Vec::new(),
            buf_pool: buf_pool.clone(),
            tuner: FlushTuner::new(),
            reports: reports.clone(),
            reported: 0,
            stop: false,
        };
        threads.push(std::thread::spawn(move || shard.run()));
    }

    // Accept thread: assign global connection ids, hand each socket to
    // its owning shard. The only O(clients) cost here is the hash send.
    {
        let stop = stop.clone();
        let links = links.clone();
        threads.push(std::thread::spawn(move || {
            let mut next_conn: u64 = 0;
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let conn = next_conn;
                next_conn += 1;
                let shard = (conn % links.len() as u64) as usize;
                links[shard].send(Cmd::Accept { conn, stream });
            }
        }));
    }

    Ok(ServerHandle { addr, reports, stop, links, threads })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_shards_is_at_least_one_and_at_most_four() {
        let n = default_shards();
        assert!((1..=4).contains(&n));
        assert!((1..=4).contains(&ServerConfig::default().shards));
    }

    #[test]
    fn zero_shards_is_rejected() {
        let err = serve(ServerConfig { shards: 0, ..ServerConfig::default() })
            .err()
            .expect("shards: 0 must be rejected");
        assert!(err.to_string().contains("shards"));
    }

    #[test]
    fn flush_tuner_tracks_syscall_cost() {
        let mut t = FlushTuner::new();
        let initial = t.threshold;
        assert!((FLUSH_MIN_BYTES..=FLUSH_MAX_BYTES).contains(&initial));
        // Expensive syscalls push the threshold up…
        for _ in 0..200 {
            t.record(50_000);
        }
        assert!(t.threshold > initial);
        assert!(t.threshold <= FLUSH_MAX_BYTES);
        assert!(t.should_flush(FLUSH_MAX_BYTES));
        // …and cheap ones pull it down to the floor.
        for _ in 0..400 {
            t.record(10);
        }
        assert_eq!(t.threshold, FLUSH_MIN_BYTES);
        assert!(!t.should_flush(FLUSH_MIN_BYTES - 1));
        assert!(t.should_flush(FLUSH_MIN_BYTES));
    }

    #[test]
    fn outbuf_tail_reclaims_consumed_prefix() {
        let mut out = OutBuf::new();
        out.tail().extend_from_slice(&[1, 2, 3, 4]);
        out.pos = 4; // fully consumed
        assert_eq!(out.pending(), 0);
        out.tail().extend_from_slice(&[5, 6]);
        assert_eq!(out.buf, vec![5, 6]);
        assert_eq!(out.pos, 0);
        // Large consumed prefix under a partial write compacts.
        let big = vec![0u8; OUT_COMPACT_BYTES + 16];
        out.tail().clear();
        out.pos = 0;
        out.tail().extend_from_slice(&big);
        out.pos = OUT_COMPACT_BYTES + 8;
        out.tail().extend_from_slice(&[9]);
        assert_eq!(out.pos, 0);
        assert_eq!(out.pending(), 9);
    }

    #[test]
    fn deliver_forward_delivers_xor_recycles() {
        let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
        let bytes = vec![1u8, 2, 3];
        let mut dst = Vec::new();
        assert!(deliver_forward(Some(&mut dst), bytes, &pool));
        assert_eq!(dst, vec![1, 2, 3]);
        assert_eq!(pool.lock().unwrap().len(), 1);
        let bytes = vec![4u8, 5];
        assert!(!deliver_forward(None, bytes, &pool));
        // Recycled either way; never delivered to a gone connection.
        assert_eq!(pool.lock().unwrap().len(), 2);
    }
}
