//! TCP transport for the reactor: listener, per-connection reader threads,
//! per-connection writer threads, and the single reactor thread they feed.
//!
//! Threading model (the offline-environment stand-in for the paper's tokio
//! event loop): readers decode frames into [`Msg`] and push them over one
//! mpsc channel; the reactor thread — the only place touching scheduler and
//! bookkeeping state — processes them in arrival order and hands outbound
//! messages to per-connection writer queues so a slow peer can never block
//! the reactor.
//!
//! Hot-path discipline (this is the throughput ceiling every scaling item
//! sits on):
//!
//! - readers reuse one frame buffer per connection ([`FrameReader`]) and
//!   decode via the streaming codec — no allocation per inbound message
//!   beyond the `Msg`'s own fields;
//! - the reactor pumps into a [`BatchSink`]: compute-task assignments are
//!   encoded from the borrowed [`ComputeDispatch`] straight into recycled
//!   per-connection batch buffers — no owned `Msg` is ever materialized on
//!   the dispatch path (zero allocations per task, asserted by
//!   `hotpath_micro`);
//! - flushing is *adaptive across events*: a batch is handed to its writer
//!   thread when it crosses [`FLUSH_BATCH_BYTES`] or when the inbox
//!   drains (always before the loop blocks), so sustained load coalesces
//!   many events into one syscall without idle latency;
//! - writer threads flush a whole batch with one `write_all` (one syscall)
//!   and return the buffer to a shared pool for reuse.

use super::pool::SchedulerPool;
use super::reactor::{ComputeDispatch, Dest, Origin, OutboundSink, Reactor, ReactorReport};
use super::window::BoundedWindow;
use crate::overhead::RuntimeProfile;
use crate::protocol::{append_frame, append_frame_with, decode_msg, FrameError, FrameReader, Msg};
use crate::scheduler::WorkerId;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
// Model-checkable primitives (std unless built with `--cfg loom`); the
// mpsc channels stay std — the modelled paths only use non-blocking sends.
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Arc, Mutex};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address; use port 0 for ephemeral.
    pub addr: String,
    /// Default scheduler name: `random` | `ws` | `dask-ws`. A `submit-graph`
    /// may override it per run.
    pub scheduler: String,
    /// Seed for the random scheduler.
    pub seed: u64,
    /// Runtime profile to charge on the hot path.
    pub profile: RuntimeProfile,
    /// Busy-wait the profile costs (Dask-emulation baseline).
    pub emulate: bool,
    /// Dispatch fairness policy over concurrent runs: `rr` (default) |
    /// `arrival` | `weighted`. See [`super::fairness`].
    pub fairness: String,
    /// Cap on concurrently executing runs per client; excess submissions
    /// park in the admission queue (`run-queued`).
    pub max_live_runs_per_client: usize,
    /// Cap on *parked* submissions per client; past it a submission fails
    /// instead of parking (bounds a runaway submitter's server memory).
    pub max_queued_runs_per_client: usize,
    /// Completed-run reports retained in memory (older ones are dropped;
    /// `reports_since` watermarks stay consistent).
    pub report_retention: usize,
    /// Per-run worker-disconnect recovery budget (see
    /// [`crate::server::DEFAULT_MAX_RECOVERIES`]). With 0, any non-trivial
    /// loss fails the run — the setting the client-side resubmission knob
    /// ([`crate::client::Client::with_retry_exhausted`]) pairs with.
    pub max_recoveries: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            scheduler: "ws".into(),
            seed: 2020,
            profile: RuntimeProfile::rust(),
            emulate: false,
            fairness: "rr".into(),
            max_live_runs_per_client: super::reactor::DEFAULT_MAX_LIVE_RUNS_PER_CLIENT,
            max_queued_runs_per_client: super::reactor::DEFAULT_MAX_QUEUED_RUNS_PER_CLIENT,
            report_retention: super::reactor::DEFAULT_REPORT_RETENTION,
            max_recoveries: super::state::DEFAULT_MAX_RECOVERIES,
        }
    }
}

enum NetEvent {
    Inbound { conn: u64, msg: Msg },
    Disconnected { conn: u64 },
    Stop,
}

/// Recycled coalescing buffers: the reactor pops one per (event,
/// destination), the writer thread pushes it back after flushing. Bounded
/// so a burst cannot pin memory forever.
///
/// Public (with [`pool_get`]/[`pool_put`]/[`flush_batches`]) for the
/// model-checking suite in `tests/loom_models.rs`, which verifies the
/// buffer-conservation invariant — every batch is delivered to a writer
/// XOR returned to the pool — under concurrent shutdown.
pub type BufPool = Arc<Mutex<Vec<Vec<u8>>>>;

/// Pool capacity bound (see [`BufPool`]).
pub const BUF_POOL_MAX: usize = 64;

/// Buffers above this capacity are dropped instead of pooled: a data-plane
/// burst (multi-MB `data-reply` batches) must not pin up to
/// `BUF_POOL_MAX × burst-size` bytes on an idle server forever.
const BUF_POOL_MAX_CAPACITY: usize = 256 * 1024;

/// Pop a recycled buffer (or a fresh one). See [`BufPool`].
pub fn pool_get(pool: &BufPool) -> Vec<u8> {
    pool.lock().unwrap().pop().unwrap_or_default()
}

/// Return a buffer to the pool (dropped if oversized or the pool is
/// full). See [`BufPool`].
pub fn pool_put(pool: &BufPool, mut buf: Vec<u8>) {
    if buf.capacity() > BUF_POOL_MAX_CAPACITY {
        return;
    }
    buf.clear();
    let mut p = pool.lock().unwrap();
    if p.len() < BUF_POOL_MAX {
        p.push(buf);
    }
}

/// Published completed-run reports: a [`BoundedWindow`] — the same type
/// the reactor keeps its own history in, so the invariant
/// `dropped + len == completions` lives in exactly one place. A poller
/// that lags by more than the retention window misses the evicted reports
/// (by design: that is the bound on a long-lived server's memory); the
/// publishing code in `reactor_loop` reconciles the two windows by
/// completion *count*.
type ReportStore = BoundedWindow<ReactorReport>;

/// Running server: address, per-graph reports, shutdown control.
pub struct ServerHandle {
    pub addr: SocketAddr,
    reports: Arc<Mutex<ReportStore>>,
    stop: Arc<AtomicBool>,
    event_tx: Sender<NetEvent>,
    writers: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    threads: Vec<JoinHandle<()>>,
    conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServerHandle {
    /// Reports of all graphs completed so far (the retained window).
    ///
    /// Prefer [`ServerHandle::reports_since`] in polling loops — this
    /// clones the full retained history every call.
    pub fn reports(&self) -> Vec<ReactorReport> {
        self.reports_since(0).0
    }

    /// Reports with absolute completion index ≥ `watermark`, plus the
    /// watermark to pass to the *next* call. Pollers must advance using
    /// the returned watermark — not by counting returned reports — so
    /// exactly-once delivery holds even when the retention window has
    /// evicted part of the poller's gap (the evicted reports are
    /// permanently missed; counting only the returned ones would make a
    /// lagging poller re-receive the window's tail forever).
    ///
    /// History is bounded: the server retains only the newest
    /// `report_retention` reports (`ServerConfig`); `report_count` keeps
    /// counting evicted reports, so watermarks never go backwards.
    pub fn reports_since(&self, watermark: usize) -> (Vec<ReactorReport>, usize) {
        let store = self.reports.lock().unwrap();
        let (fresh, next) = store.since(watermark);
        (fresh.to_vec(), next)
    }

    /// Total completed-run reports so far (a cheap watermark probe;
    /// monotonic, includes reports evicted from the retained window).
    pub fn report_count(&self) -> usize {
        self.reports.lock().unwrap().total()
    }

    /// Stop the server and join every thread it spawned — the accept loop,
    /// the reactor, and all per-connection reader/writer threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.event_tx.send(NetEvent::Stop);
        // Unblock the accept loop.
        let _ = TcpStream::connect(self.addr);
        // Close every live connection so blocked readers return.
        for (_, s) in self.conns.lock().unwrap().drain() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        // Drop the writer senders so writer threads drain and exit.
        self.writers.lock().unwrap().clear();
        // Join accept + reactor first: a connection racing the drains above
        // (accepted after the stop check, registered after the drain) would
        // leave a reader blocked on a socket nobody closed. Once the accept
        // loop has exited no new registrations can appear, so a second
        // drain closes any such straggler before the per-connection joins.
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        for (_, s) in self.conns.lock().unwrap().drain() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        self.writers.lock().unwrap().clear();
        let handles: Vec<JoinHandle<()>> =
            self.conn_threads.lock().unwrap().drain(..).collect();
        for t in handles {
            let _ = t.join();
        }
    }
}

/// Start the server; returns once the listener is bound.
pub fn serve(config: ServerConfig) -> Result<ServerHandle> {
    let pool = SchedulerPool::new(&config.scheduler, config.seed)
        .ok_or_else(|| anyhow!("unknown scheduler {:?}", config.scheduler))?;
    let policy = super::fairness::by_name(&config.fairness)
        .ok_or_else(|| anyhow!("unknown fairness policy {:?}", config.fairness))?;
    // Validate here with clean errors — the reactor builders assert, which
    // is right for programmatic misuse but not for a CLI flag.
    if config.max_live_runs_per_client == 0 {
        return Err(anyhow!("max_live_runs_per_client must be at least 1"));
    }
    if config.max_queued_runs_per_client == 0 {
        return Err(anyhow!("max_queued_runs_per_client must be at least 1"));
    }
    if config.report_retention == 0 {
        return Err(anyhow!("report_retention must be at least 1"));
    }
    let reactor = Reactor::new(pool, config.profile.clone(), config.emulate)
        .with_fairness(policy)
        .with_admission_cap(config.max_live_runs_per_client)
        .with_admission_queue_cap(config.max_queued_runs_per_client)
        .with_report_retention(config.report_retention)
        .with_max_recoveries(config.max_recoveries);

    let listener = TcpListener::bind(&config.addr)
        .with_context(|| format!("bind {}", config.addr))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let reports = Arc::new(Mutex::new(ReportStore::new(config.report_retention)));
    let (event_tx, event_rx) = channel::<NetEvent>();

    // Writer registry: conn id -> outbound batch queue (each item is one or
    // more coalesced frames).
    let writers: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>> = Arc::new(Mutex::new(HashMap::new()));
    // Live streams, kept so shutdown can unblock reader threads.
    let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
    // Reader/writer thread handles, joined on shutdown instead of leaking.
    let conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let buf_pool: BufPool = Arc::new(Mutex::new(Vec::new()));

    let mut threads = Vec::new();

    // Accept loop.
    {
        let stop = stop.clone();
        let event_tx = event_tx.clone();
        let writers = writers.clone();
        let conns = conns.clone();
        let conn_threads = conn_threads.clone();
        let buf_pool = buf_pool.clone();
        threads.push(std::thread::spawn(move || {
            let mut next_conn: u64 = 0;
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let conn = next_conn;
                next_conn += 1;
                stream.set_nodelay(true).ok();
                let Ok(registry_stream) = stream.try_clone() else { continue };
                conns.lock().unwrap().insert(conn, registry_stream);
                // Writer thread: flush whole batches, recycle the buffers.
                let (wtx, wrx) = channel::<Vec<u8>>();
                writers.lock().unwrap().insert(conn, wtx);
                let Ok(mut wstream) = stream.try_clone() else {
                    // No writer thread will exist: drop the registry
                    // entries made above so the dead conn doesn't linger.
                    writers.lock().unwrap().remove(&conn);
                    conns.lock().unwrap().remove(&conn);
                    continue;
                };
                let pool = buf_pool.clone();
                let writer = std::thread::spawn(move || {
                    for batch in wrx {
                        let ok = wstream
                            .write_all(&batch)
                            .and_then(|_| wstream.flush())
                            .is_ok();
                        pool_put(&pool, batch);
                        if !ok {
                            break;
                        }
                    }
                    let _ = wstream.shutdown(std::net::Shutdown::Both);
                });
                // Reader thread: reused frame buffer, streaming decode.
                let event_tx = event_tx.clone();
                let mut rstream = stream;
                let reader = std::thread::spawn(move || {
                    let mut frames = FrameReader::new();
                    loop {
                        match frames.read(&mut rstream) {
                            Ok(bytes) => match decode_msg(bytes) {
                                Ok(msg) => {
                                    if event_tx.send(NetEvent::Inbound { conn, msg }).is_err() {
                                        break;
                                    }
                                }
                                Err(e) => {
                                    log::warn!("conn {conn}: bad message: {e}; closing");
                                    break;
                                }
                            },
                            Err(FrameError::Closed) => break,
                            Err(e) => {
                                log::warn!("conn {conn}: frame error: {e}");
                                break;
                            }
                        }
                    }
                    let _ = event_tx.send(NetEvent::Disconnected { conn });
                });
                let mut handles = conn_threads.lock().unwrap();
                handles.push(writer);
                handles.push(reader);
            }
        }));
    }

    // Reactor thread.
    {
        let reports = reports.clone();
        let writers = writers.clone();
        let conns = conns.clone();
        threads.push(std::thread::spawn(move || {
            reactor_loop(reactor, event_rx, writers, conns, buf_pool, reports);
        }));
    }

    Ok(ServerHandle {
        addr,
        reports,
        stop,
        event_tx,
        writers,
        conns,
        threads,
        conn_threads,
    })
}

/// Adaptive flush threshold: a connection's coalesced batch is handed to
/// its writer thread once it crosses this size even while inbound events
/// keep arriving; smaller batches ride across events and flush when the
/// inbox drains. Cuts writer hand-offs (and syscalls) by batching *across*
/// events under load without adding latency when idle — the inbox-drained
/// flush runs before the loop ever blocks.
const FLUSH_BATCH_BYTES: usize = 64 * 1024;

/// Age bound on the adaptive flush: under sustained load the inbox may
/// never drain (`try_recv` keeps yielding events), and a small batch — a
/// `welcome` for a freshly connecting peer, a tiny run's `graph-done` —
/// would otherwise ride below the byte threshold indefinitely. After this
/// many loop iterations without a full flush, everything buffered goes out
/// regardless of size (at one pump round per iteration this bounds the
/// holdback to a couple thousand messages' worth of processing time).
const FLUSH_MAX_ROUNDS: u32 = 64;

/// Sink the reactor pumps into: frames append straight to the
/// per-connection batch buffers. Compute-task assignments encode from the
/// borrowed [`ComputeDispatch`] — no owned `Msg` is built, so a warm
/// dispatch performs zero heap allocations (asserted by `hotpath_micro`).
struct BatchSink<'a> {
    batches: &'a mut HashMap<u64, Vec<u8>>,
    conn_of: &'a HashMap<Dest, u64>,
    buf_pool: &'a BufPool,
}

impl BatchSink<'_> {
    fn batch_for(&mut self, dest: Dest, op: &str) -> Option<&mut Vec<u8>> {
        let Some(&conn) = self.conn_of.get(&dest) else {
            log::warn!("no connection for {dest:?}; dropping {op}");
            return None;
        };
        Some(self.batches.entry(conn).or_insert_with(|| pool_get(self.buf_pool)))
    }
}

impl OutboundSink for BatchSink<'_> {
    fn emit_msg(&mut self, dest: Dest, msg: Msg) {
        if let Some(batch) = self.batch_for(dest, msg.op()) {
            if let Err(e) = append_frame(batch, &msg) {
                log::warn!("dropping oversized {op}: {e}", op = msg.op());
            }
        }
    }

    fn emit_compute(&mut self, dispatch: &ComputeDispatch<'_>) {
        if let Some(batch) = self.batch_for(Dest::Worker(dispatch.worker), "compute-task") {
            if let Err(e) = append_frame_with(batch, |body| dispatch.encode_into(body)) {
                log::warn!("dropping oversized compute-task: {e}");
            }
        }
    }
}

/// Hand every batch of at least `min_len` bytes to its writer thread
/// (`min_len == 0` flushes everything). `scratch` is a reused key buffer
/// so a warm flush allocates nothing. The writer-registry lock is taken
/// once per call, and only when something actually flushes.
/// Hand every batch of at least `min_len` bytes to its connection's
/// writer thread, recycling batches whose writer is gone. Public for the
/// model-checking suite (`tests/loom_models.rs`), which runs it against a
/// concurrently draining writer registry to check buffer conservation:
/// each batch is delivered XOR pooled, never both, never neither.
pub fn flush_batches(
    batches: &mut HashMap<u64, Vec<u8>>,
    scratch: &mut Vec<u64>,
    writers: &Mutex<HashMap<u64, Sender<Vec<u8>>>>,
    buf_pool: &BufPool,
    min_len: usize,
) {
    scratch.clear();
    scratch.extend(batches.iter().filter(|(_, b)| b.len() >= min_len).map(|(&c, _)| c));
    if scratch.is_empty() {
        return;
    }
    let writer_map = writers.lock().unwrap();
    for conn in scratch.drain(..) {
        let Some(batch) = batches.remove(&conn) else { continue };
        if batch.is_empty() {
            // Every append to it failed (oversized); nothing to write.
            pool_put(buf_pool, batch);
            continue;
        }
        match writer_map.get(&conn) {
            // A closed writer hands the batch back inside the error;
            // recycle it (the disconnect event cleans the registry).
            Some(tx) => {
                if let Err(failed) = tx.send(batch) {
                    pool_put(buf_pool, failed.0);
                }
            }
            None => pool_put(buf_pool, batch),
        }
    }
}

fn reactor_loop(
    mut reactor: Reactor,
    event_rx: Receiver<NetEvent>,
    writers: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    buf_pool: BufPool,
    reports: Arc<Mutex<ReportStore>>,
) {
    // conn <-> identity maps, maintained from registration replies.
    let mut origin_of: HashMap<u64, Origin> = HashMap::new();
    let mut conn_of: HashMap<Dest, u64> = HashMap::new();
    let mut out: Vec<(Dest, Msg)> = Vec::new();
    // Cross-event coalescing: frames grouped by destination connection.
    // Batches persist across iterations until the adaptive flush hands
    // them off; the map keeps its capacity either way.
    let mut batches: HashMap<u64, Vec<u8>> = HashMap::new();
    let mut flush_scratch: Vec<u64> = Vec::new();
    let mut rounds_since_flush: u32 = 0;
    let mut reported = 0usize;

    // Whether the previous iteration's pump round emitted anything —
    // cheaper than probing `pending_messages()` (an O(live runs) sum)
    // before every event; an extra empty poll after the backlog drains is
    // the only cost.
    let mut pumping = false;
    loop {
        // Run-fair intake: while worker-bound messages are parked, poll for
        // inbound events without blocking — a pump round runs after every
        // iteration, so a huge backlog is emitted in bounded slices
        // interleaved with fresh events instead of all at once. Block only
        // when the reactor is fully drained.
        let event = if pumping {
            match event_rx.try_recv() {
                Ok(ev) => Some(ev),
                Err(std::sync::mpsc::TryRecvError::Empty) => None,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
            }
        } else {
            // Reactor fully drained and about to block: nothing fresher
            // can join the batches, so everything buffered goes out now.
            flush_batches(&mut batches, &mut flush_scratch, &writers, &buf_pool, 0);
            rounds_since_flush = 0;
            match event_rx.recv() {
                Ok(ev) => Some(ev),
                Err(_) => break,
            }
        };
        let inbox_drained = event.is_none();
        match event {
            None => {}
            Some(NetEvent::Stop) => break,
            Some(NetEvent::Disconnected { conn }) => {
                writers.lock().unwrap().remove(&conn);
                conns.lock().unwrap().remove(&conn);
                if let Some(origin) = origin_of.remove(&conn) {
                    if let Origin::Worker(w) = origin {
                        conn_of.remove(&Dest::Worker(w));
                    }
                    if let Origin::Client(c) = origin {
                        conn_of.remove(&Dest::Client(c));
                    }
                    reactor.on_disconnect(origin, &mut out);
                }
            }
            Some(NetEvent::Inbound { conn, msg }) => {
                let origin = origin_of
                    .get(&conn)
                    .copied()
                    .unwrap_or(Origin::Unregistered { conn });
                let registering_client = matches!(
                    (&origin, &msg),
                    (Origin::Unregistered { .. }, Msg::RegisterClient { .. })
                );
                let registering_worker = matches!(
                    (&origin, &msg),
                    (Origin::Unregistered { .. }, Msg::RegisterWorker { .. })
                );
                reactor.on_message(origin, msg, &mut out);
                // Bind freshly assigned ids to this connection: the Welcome
                // the reactor just emitted names the id.
                if registering_client || registering_worker {
                    if let Some((dest, Msg::Welcome { id })) =
                        out.iter().rev().find(|(_, m)| matches!(m, Msg::Welcome { .. }))
                    {
                        let origin = if registering_client {
                            Origin::Client(*id)
                        } else {
                            Origin::Worker(WorkerId(*id))
                        };
                        origin_of.insert(conn, origin);
                        conn_of.insert(*dest, conn);
                    }
                }
            }
        }
        // One fairness round per iteration: up to a quota of parked
        // messages from the policy-chosen run join the per-connection
        // batches — compute-tasks encoded borrowed, no owned Msg built.
        pumping = {
            let mut sink = BatchSink {
                batches: &mut batches,
                conn_of: &conn_of,
                buf_pool: &buf_pool,
            };
            reactor.pump_into(&mut sink).is_some()
        };
        // Reactor replies outside the pump (acks, completions, release
        // broadcasts) join the same batches.
        for (dest, msg) in out.drain(..) {
            let Some(&conn) = conn_of.get(&dest) else {
                log::warn!("no connection for {dest:?}; dropping {op}", op = msg.op());
                continue;
            };
            let batch = batches
                .entry(conn)
                .or_insert_with(|| pool_get(&buf_pool));
            if let Err(e) = append_frame(batch, &msg) {
                log::warn!("conn {conn}: dropping oversized {op}: {e}", op = msg.op());
            }
        }
        // Adaptive flush: a batch that crossed the size threshold goes out
        // immediately; the rest ride across events and flush when the
        // inbox drains (here, or above before the loop blocks) — or when
        // the age bound expires, so sustained load can't starve a small
        // batch (a welcome, a tiny run's completion) below the threshold.
        let flush_all = inbox_drained || rounds_since_flush >= FLUSH_MAX_ROUNDS;
        let min_len = if flush_all { 0 } else { FLUSH_BATCH_BYTES };
        flush_batches(&mut batches, &mut flush_scratch, &writers, &buf_pool, min_len);
        rounds_since_flush = if flush_all { 0 } else { rounds_since_flush + 1 };
        // Publish new reports (only the fresh tail is ever copied; both
        // windows count against the monotonic completion total, so the
        // `dropped + len == completions` invariant holds on both sides).
        let total = reactor.report_count();
        if total > reported {
            let all = reactor.reports();
            let fresh = total - reported;
            let mut shared = reports.lock().unwrap();
            if fresh > all.len() {
                // More completions this iteration than the reactor window
                // holds (tiny retention + a burst): the overflow is gone
                // on both sides.
                shared.note_missed(fresh - all.len());
            }
            let start = all.len().saturating_sub(fresh);
            shared.extend_from_slice(&all[start..]);
            reported = total;
        }
    }
}
