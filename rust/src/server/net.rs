//! TCP transport for the reactor: listener, per-connection reader threads,
//! per-connection writer threads, and the single reactor thread they feed.
//!
//! Threading model (the offline-environment stand-in for the paper's tokio
//! event loop): readers decode frames into [`Msg`] and push them over one
//! mpsc channel; the reactor thread — the only place touching scheduler and
//! bookkeeping state — processes them in arrival order and hands outbound
//! messages to per-connection writer queues so a slow peer can never block
//! the reactor.

use super::pool::SchedulerPool;
use super::reactor::{Dest, Origin, Reactor, ReactorReport};
use crate::overhead::RuntimeProfile;
use crate::protocol::{decode_msg, encode_msg, read_frame, write_frame, FrameError, Msg};
use crate::scheduler::WorkerId;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address; use port 0 for ephemeral.
    pub addr: String,
    /// Scheduler name: `random` | `ws` | `dask-ws`.
    pub scheduler: String,
    /// Seed for the random scheduler.
    pub seed: u64,
    /// Runtime profile to charge on the hot path.
    pub profile: RuntimeProfile,
    /// Busy-wait the profile costs (Dask-emulation baseline).
    pub emulate: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            scheduler: "ws".into(),
            seed: 2020,
            profile: RuntimeProfile::rust(),
            emulate: false,
        }
    }
}

enum NetEvent {
    Inbound { conn: u64, msg: Msg },
    Disconnected { conn: u64 },
    Stop,
}

/// Running server: address, per-graph reports, shutdown control.
pub struct ServerHandle {
    pub addr: SocketAddr,
    reports: Arc<Mutex<Vec<ReactorReport>>>,
    stop: Arc<AtomicBool>,
    event_tx: Sender<NetEvent>,
    threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Reports of all graphs completed so far.
    pub fn reports(&self) -> Vec<ReactorReport> {
        self.reports.lock().unwrap().clone()
    }

    /// Stop the server and join its threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.event_tx.send(NetEvent::Stop);
        // Unblock the accept loop.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start the server; returns once the listener is bound.
pub fn serve(config: ServerConfig) -> Result<ServerHandle> {
    let pool = SchedulerPool::new(&config.scheduler, config.seed)
        .ok_or_else(|| anyhow!("unknown scheduler {:?}", config.scheduler))?;
    let reactor = Reactor::new(pool, config.profile.clone(), config.emulate);

    let listener = TcpListener::bind(&config.addr)
        .with_context(|| format!("bind {}", config.addr))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let reports = Arc::new(Mutex::new(Vec::new()));
    let (event_tx, event_rx) = channel::<NetEvent>();

    // Writer registry: conn id -> outbound byte queue.
    let writers: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>> = Arc::new(Mutex::new(HashMap::new()));

    let mut threads = Vec::new();

    // Accept loop.
    {
        let stop = stop.clone();
        let event_tx = event_tx.clone();
        let writers = writers.clone();
        threads.push(std::thread::spawn(move || {
            let mut next_conn: u64 = 0;
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let conn = next_conn;
                next_conn += 1;
                stream.set_nodelay(true).ok();
                // Writer thread.
                let (wtx, wrx) = channel::<Vec<u8>>();
                writers.lock().unwrap().insert(conn, wtx);
                let mut wstream = stream.try_clone().expect("clone stream");
                std::thread::spawn(move || {
                    for bytes in wrx {
                        if write_frame(&mut wstream, &bytes).is_err() {
                            break;
                        }
                    }
                    let _ = wstream.shutdown(std::net::Shutdown::Both);
                });
                // Reader thread.
                let event_tx = event_tx.clone();
                let mut rstream = stream;
                std::thread::spawn(move || {
                    loop {
                        match read_frame(&mut rstream) {
                            Ok(bytes) => match decode_msg(&bytes) {
                                Ok(msg) => {
                                    if event_tx.send(NetEvent::Inbound { conn, msg }).is_err() {
                                        break;
                                    }
                                }
                                Err(e) => {
                                    log::warn!("conn {conn}: bad message: {e}; closing");
                                    break;
                                }
                            },
                            Err(FrameError::Closed) => break,
                            Err(e) => {
                                log::warn!("conn {conn}: frame error: {e}");
                                break;
                            }
                        }
                    }
                    let _ = event_tx.send(NetEvent::Disconnected { conn });
                });
            }
        }));
    }

    // Reactor thread.
    {
        let reports = reports.clone();
        let writers = writers.clone();
        threads.push(std::thread::spawn(move || {
            reactor_loop(reactor, event_rx, writers, reports);
        }));
    }

    Ok(ServerHandle { addr, reports, stop, event_tx, threads })
}

fn reactor_loop(
    mut reactor: Reactor,
    event_rx: Receiver<NetEvent>,
    writers: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>>,
    reports: Arc<Mutex<Vec<ReactorReport>>>,
) {
    // conn <-> identity maps, maintained from registration replies.
    let mut origin_of: HashMap<u64, Origin> = HashMap::new();
    let mut conn_of: HashMap<Dest, u64> = HashMap::new();
    let mut out: Vec<(Dest, Msg)> = Vec::new();
    let mut reported = 0usize;

    for event in event_rx {
        match event {
            NetEvent::Stop => break,
            NetEvent::Disconnected { conn } => {
                writers.lock().unwrap().remove(&conn);
                if let Some(origin) = origin_of.remove(&conn) {
                    if let Origin::Worker(w) = origin {
                        conn_of.remove(&Dest::Worker(w));
                    }
                    if let Origin::Client(c) = origin {
                        conn_of.remove(&Dest::Client(c));
                    }
                    reactor.on_disconnect(origin, &mut out);
                }
            }
            NetEvent::Inbound { conn, msg } => {
                let origin = origin_of
                    .get(&conn)
                    .copied()
                    .unwrap_or(Origin::Unregistered { conn });
                let registering_client = matches!(
                    (&origin, &msg),
                    (Origin::Unregistered { .. }, Msg::RegisterClient { .. })
                );
                let registering_worker = matches!(
                    (&origin, &msg),
                    (Origin::Unregistered { .. }, Msg::RegisterWorker { .. })
                );
                reactor.on_message(origin, msg, &mut out);
                // Bind freshly assigned ids to this connection: the Welcome
                // the reactor just emitted names the id.
                if registering_client || registering_worker {
                    if let Some((dest, Msg::Welcome { id })) =
                        out.iter().rev().find(|(_, m)| matches!(m, Msg::Welcome { .. }))
                    {
                        let origin = if registering_client {
                            Origin::Client(*id)
                        } else {
                            Origin::Worker(WorkerId(*id))
                        };
                        origin_of.insert(conn, origin);
                        conn_of.insert(*dest, conn);
                    }
                }
            }
        }
        // Flush outbound.
        for (dest, msg) in out.drain(..) {
            let Some(&conn) = conn_of.get(&dest) else {
                log::warn!("no connection for {dest:?}; dropping {op}", op = msg.op());
                continue;
            };
            let bytes = encode_msg(&msg);
            if let Some(tx) = writers.lock().unwrap().get(&conn) {
                let _ = tx.send(bytes);
            }
        }
        // Publish new reports.
        let all = reactor.reports();
        if all.len() > reported {
            let mut shared = reports.lock().unwrap();
            shared.extend_from_slice(&all[reported..]);
            reported = all.len();
        }
    }
}
