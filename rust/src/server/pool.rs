//! Per-run scheduler instances.
//!
//! The [`crate::scheduler::Scheduler`] trait is deliberately per-graph (the
//! paper's model, §IV-A): implementations index their state by dense
//! [`crate::taskgraph::TaskId`]s. Multi-graph serving therefore cannot share
//! one scheduler across runs — recycled task ids would alias state. The
//! pool keeps one isolated scheduler per live [`RunId`], replaying the
//! cluster membership into each newcomer, which also keeps per-run
//! scheduling state out of the reactor's dispatch loop.

use crate::protocol::RunId;
use crate::scheduler::{self, Scheduler, WorkerInfo};
use std::collections::HashMap;

/// Builds one scheduler instance from a (run-decorrelated) seed.
pub type SchedulerFactory = Box<dyn Fn(u64) -> Box<dyn Scheduler> + Send>;

/// One scheduler per live run, all built from the same factory.
pub struct SchedulerPool {
    factory: SchedulerFactory,
    seed: u64,
    workers: Vec<WorkerInfo>,
    scheds: HashMap<RunId, Box<dyn Scheduler>>,
}

impl SchedulerPool {
    /// Pool over a named algorithm. Validates `name` eagerly (so a bad CLI
    /// flag fails at startup, not at first submission).
    pub fn new(name: &str, seed: u64) -> Option<SchedulerPool> {
        scheduler::by_name(name, seed)?;
        let name = name.to_string();
        Some(Self::with_factory(
            Box::new(move |s| scheduler::by_name(&name, s).expect("validated above")),
            seed,
        ))
    }

    /// Pool over an arbitrary factory (tests inject probe schedulers here).
    pub fn with_factory(factory: SchedulerFactory, seed: u64) -> SchedulerPool {
        SchedulerPool { factory, seed, workers: Vec::new(), scheds: HashMap::new() }
    }

    /// Record a worker and propagate it to every live scheduler.
    pub fn add_worker(&mut self, info: WorkerInfo) {
        self.workers.push(info);
        for s in self.scheds.values_mut() {
            s.add_worker(info);
        }
    }

    /// A worker disconnected: stop replaying it into newly created
    /// schedulers AND tell every live scheduler to drop it — lineage
    /// recovery re-places the dead worker's tasks through the normal
    /// `tasks_ready` path, so placement models must forget the corpse
    /// before that happens (the reactor still fails fast if a scheduler
    /// assigns to a dead worker anyway; see `flush_actions`).
    pub fn remove_worker(&mut self, id: crate::scheduler::WorkerId) {
        self.workers.retain(|w| w.id != id);
        for s in self.scheds.values_mut() {
            s.remove_worker(id);
        }
    }

    /// Whether `name` names a known scheduler algorithm. Used by the
    /// reactor's admission control to reject a bad per-run override
    /// *before* the submission is parked in the admission queue — so a
    /// deferred [`SchedulerPool::create_with`] at activation time can
    /// never fail for a named override.
    pub fn is_known(name: &str) -> bool {
        scheduler::by_name(name, 0).is_some()
    }

    /// Instantiate the default scheduler for a new run: fresh algorithm
    /// state, current cluster membership, run-decorrelated seed.
    pub fn create(&mut self, run: RunId, graph: &crate::taskgraph::TaskGraph) {
        self.create_with(run, graph, None).expect("default factory is always valid");
    }

    /// Like [`SchedulerPool::create`], but `scheduler` may override the
    /// pool's algorithm for this run (the `submit-graph` per-run choice):
    /// latency-sensitive clients can run `random` while throughput clients
    /// run `ws` on the same server. An unknown name fails the submission
    /// eagerly — no scheduler state is created.
    pub fn create_with(
        &mut self,
        run: RunId,
        graph: &crate::taskgraph::TaskGraph,
        scheduler: Option<&str>,
    ) -> Result<(), String> {
        let seed = self.seed.wrapping_add(run.0 as u64);
        let mut s = match scheduler {
            None => (self.factory)(seed),
            Some(name) => scheduler::by_name(name, seed)
                .ok_or_else(|| format!("unknown scheduler {name:?}"))?,
        };
        for &w in &self.workers {
            s.add_worker(w);
        }
        s.graph_submitted(graph);
        let prev = self.scheds.insert(run, s);
        if prev.is_some() {
            // RunIdAlloc never reuses ids, so a collision means a live
            // run's scheduler was just replaced — surface it in release
            // builds too instead of silently dropping the old scheduler.
            debug_assert!(prev.is_none(), "run id {run} reused while still live");
            log::error!("run id {run} reused while still live; its scheduler was replaced");
        }
        Ok(())
    }

    pub fn get(&mut self, run: RunId) -> Option<&mut Box<dyn Scheduler>> {
        self.scheds.get_mut(&run)
    }

    /// Immutable access (introspection / tests).
    pub fn peek(&self, run: RunId) -> Option<&dyn Scheduler> {
        self.scheds.get(&run).map(|s| s.as_ref())
    }

    /// Drop a completed/failed run's scheduler.
    pub fn remove(&mut self, run: RunId) {
        self.scheds.remove(&run);
    }

    pub fn live_runs(&self) -> usize {
        self.scheds.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::merge;
    use crate::scheduler::{Action, WorkerId};

    fn info(i: u32) -> WorkerInfo {
        WorkerInfo { id: WorkerId(i), ncores: 1, node: 0 }
    }

    #[test]
    fn bad_name_rejected_eagerly() {
        assert!(SchedulerPool::new("fifo", 1).is_none());
        assert!(SchedulerPool::new("ws", 1).is_some());
    }

    #[test]
    fn runs_get_isolated_schedulers() {
        let mut pool = SchedulerPool::new("ws", 42).unwrap();
        pool.add_worker(info(0));
        pool.add_worker(info(1));
        let (ra, rb) = (RunId(0), RunId(1));
        let (ga, gb) = (merge(4), merge(8));
        pool.create(ra, &ga);
        pool.create(rb, &gb);
        assert_eq!(pool.live_runs(), 2);
        // Same TaskIds scheduled under both runs: each scheduler only sees
        // its own queue state.
        let mut out = Vec::new();
        pool.get(ra).unwrap().tasks_ready(&ga.roots(), &mut out);
        let a_assigns = out.iter().filter(|a| matches!(a, Action::Assign(_))).count();
        assert_eq!(a_assigns, 4);
        out.clear();
        pool.get(rb).unwrap().tasks_ready(&gb.roots(), &mut out);
        let b_assigns = out.iter().filter(|a| matches!(a, Action::Assign(_))).count();
        assert_eq!(b_assigns, 8);
        let qa: usize = pool.peek(ra).unwrap().queued_tasks().unwrap().iter().map(|(_, q)| q.len()).sum();
        let qb: usize = pool.peek(rb).unwrap().queued_tasks().unwrap().iter().map(|(_, q)| q.len()).sum();
        assert_eq!((qa, qb), (4, 8), "no cross-run aliasing of TaskIds");
        pool.remove(ra);
        assert!(pool.get(ra).is_none());
        assert_eq!(pool.live_runs(), 1);
    }

    #[test]
    fn per_run_scheduler_override() {
        let mut pool = SchedulerPool::new("ws", 42).unwrap();
        pool.add_worker(info(0));
        let g = merge(4);
        pool.create_with(RunId(0), &g, None).unwrap();
        pool.create_with(RunId(1), &g, Some("random")).unwrap();
        assert_eq!(pool.peek(RunId(0)).unwrap().name(), "ws");
        assert_eq!(pool.peek(RunId(1)).unwrap().name(), "random");
        // Unknown name: eager error, no state created.
        let err = pool.create_with(RunId(2), &g, Some("fifo")).unwrap_err();
        assert!(err.contains("fifo"), "{err}");
        assert!(pool.peek(RunId(2)).is_none());
        assert_eq!(pool.live_runs(), 2);
    }

    #[test]
    fn removed_workers_propagate_to_live_schedulers() {
        let mut pool = SchedulerPool::new("ws", 3).unwrap();
        pool.add_worker(info(0));
        pool.add_worker(info(1));
        let g = merge(8);
        pool.create(RunId(0), &g);
        pool.remove_worker(WorkerId(0));
        // The live run's scheduler must never place on the corpse…
        let mut out = Vec::new();
        pool.get(RunId(0)).unwrap().tasks_ready(&g.roots(), &mut out);
        for a in &out {
            if let Action::Assign(a) = a {
                assert_ne!(a.worker, WorkerId(0));
            }
        }
        // …and future runs never see it either.
        pool.create(RunId(1), &g);
        out.clear();
        pool.get(RunId(1)).unwrap().tasks_ready(&g.roots(), &mut out);
        for a in &out {
            if let Action::Assign(a) = a {
                assert_ne!(a.worker, WorkerId(0));
            }
        }
    }

    #[test]
    fn late_workers_propagate_to_live_schedulers() {
        let mut pool = SchedulerPool::new("ws", 7).unwrap();
        pool.add_worker(info(0));
        let g = merge(6);
        pool.create(RunId(0), &g);
        pool.add_worker(info(1));
        let mut out = Vec::new();
        pool.get(RunId(0)).unwrap().tasks_ready(&g.roots(), &mut out);
        let used: std::collections::HashSet<WorkerId> = out
            .iter()
            .filter_map(|a| match a {
                Action::Assign(a) => Some(a.worker),
                _ => None,
            })
            .collect();
        assert!(used.contains(&WorkerId(1)), "late worker must be schedulable: {used:?}");
    }
}
