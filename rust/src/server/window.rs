//! One bounded retention window with a monotonic eviction counter.
//!
//! Two places keep "the newest N completed-run reports, plus a count of how
//! many older ones were dropped": the reactor's own history and the TCP
//! layer's published `ReportStore`. They were separate hand-rolled copies
//! of the same scheme, reconciled by completion count in `reactor_loop`;
//! this type is the single home of the invariant
//!
//! ```text
//! dropped() + len() == total()      (monotonic; total never decreases)
//! ```
//!
//! so watermark-based polling (`reports_since`) stays exactly-once across
//! evictions on both sides.

/// A bounded FIFO window over an ever-growing sequence: keeps the newest
/// `retention` items, counts the evicted prefix.
#[derive(Debug)]
pub struct BoundedWindow<T> {
    items: Vec<T>,
    dropped: usize,
    retention: usize,
}

impl<T> BoundedWindow<T> {
    /// `retention` must be ≥ 1 (a zero-capacity window would make every
    /// watermark probe meaningless).
    pub fn new(retention: usize) -> BoundedWindow<T> {
        assert!(retention >= 1, "retention must be positive");
        BoundedWindow { items: Vec::new(), dropped: 0, retention }
    }

    /// Append one item, evicting from the front past the retention bound.
    pub fn push(&mut self, item: T) {
        self.items.push(item);
        self.trim();
    }

    /// Append a batch (the publishing side copies the reactor's fresh tail
    /// in one go).
    pub fn extend_from_slice(&mut self, fresh: &[T])
    where
        T: Clone,
    {
        self.items.extend_from_slice(fresh);
        self.trim();
    }

    /// Account for items that were evicted *upstream* before this window
    /// ever saw them (a burst larger than the producer's own retention):
    /// they count toward `total` but were never held here.
    pub fn note_missed(&mut self, n: usize) {
        self.dropped += n;
    }

    fn trim(&mut self) {
        if self.items.len() > self.retention {
            let d = self.items.len() - self.retention;
            self.items.drain(..d);
            self.dropped += d;
        }
    }

    /// Items currently retained, oldest first.
    pub fn as_slice(&self) -> &[T] {
        &self.items
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Items evicted so far.
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Monotonic count of every item ever pushed (or noted as missed) —
    /// the absolute index space watermarks live in.
    pub fn total(&self) -> usize {
        self.dropped + self.items.len()
    }

    /// Retained items with absolute index ≥ `watermark`, plus the
    /// watermark for the *next* call. A watermark older than the window
    /// clamps to its start — that prefix is permanently gone (by design:
    /// the retention bound is the memory bound), and the returned
    /// watermark jumps the gap so a lagging poller never re-receives the
    /// window's tail forever.
    pub fn since(&self, watermark: usize) -> (&[T], usize) {
        let start = watermark.max(self.dropped) - self.dropped;
        let fresh = self.items.get(start..).unwrap_or(&[]);
        let next = self.total().max(watermark);
        (fresh, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retains_newest_and_counts_dropped() {
        let mut w = BoundedWindow::new(3);
        for i in 0..7 {
            w.push(i);
            assert_eq!(w.dropped() + w.len(), w.total(), "invariant");
            assert_eq!(w.total(), i + 1);
        }
        assert_eq!(w.as_slice(), &[4, 5, 6]);
        assert_eq!(w.dropped(), 4);
    }

    #[test]
    fn since_is_exactly_once_across_eviction() {
        let mut w = BoundedWindow::new(2);
        w.push("a");
        let (fresh, mark) = w.since(0);
        assert_eq!(fresh, &["a"]);
        assert_eq!(mark, 1);
        w.push("b");
        w.push("c");
        w.push("d"); // "a", "b" evicted
        let (fresh, mark2) = w.since(mark);
        assert_eq!(fresh, &["c", "d"], "evicted 'b' is permanently missed");
        assert_eq!(mark2, 4);
        let (fresh, mark3) = w.since(mark2);
        assert!(fresh.is_empty());
        assert_eq!(mark3, 4, "watermark is stable with no new items");
    }

    #[test]
    fn stale_watermark_clamps_and_jumps_the_gap() {
        let mut w = BoundedWindow::new(2);
        for i in 0..10 {
            w.push(i);
        }
        // Poller last saw index 3; indices 3..8 are gone.
        let (fresh, mark) = w.since(3);
        assert_eq!(fresh, &[8, 9]);
        assert_eq!(mark, 10, "next watermark jumps past the evicted gap");
    }

    #[test]
    fn missed_items_advance_total() {
        let mut w = BoundedWindow::new(4);
        w.note_missed(3);
        w.push(10);
        assert_eq!(w.total(), 4);
        assert_eq!(w.dropped(), 3);
        let (fresh, mark) = w.since(0);
        assert_eq!(fresh, &[10]);
        assert_eq!(mark, 4);
    }

    #[test]
    fn batch_extend_trims_once() {
        let mut w = BoundedWindow::new(3);
        w.extend_from_slice(&[1, 2, 3, 4, 5]);
        assert_eq!(w.as_slice(), &[3, 4, 5]);
        assert_eq!(w.dropped(), 2);
    }
}
