//! Minimal readiness-polling core: a hand-rolled epoll + eventfd wrapper.
//!
//! The control plane needs exactly four OS facilities — create an epoll
//! instance, (de)register file descriptors with read/write interest, block
//! until something is ready, and wake the blocked thread from another
//! thread. Pulling in `mio`/`tokio` for that would add a dependency tree
//! larger than this whole repo, so — mirroring how `modelcheck.rs` stands
//! in for loom — this module declares the handful of `extern "C"` glibc
//! entry points itself and wraps them in a safe, intent-revealing API.
//!
//! Design notes:
//! - **Level-triggered.** Readiness is re-reported until the condition
//!   clears, so a shard that stops reading mid-burst (e.g. to bound a
//!   dispatch round) is re-notified on the next `wait`. Write interest is
//!   toggled on only while a connection has pending output (the classic
//!   LT pattern), so an idle connection costs nothing per iteration.
//! - **Tokens, not pointers.** Each registration carries a caller-chosen
//!   `u64` token (connection id / waker sentinel); `wait` hands tokens
//!   back. No lifetimes, no slab, no unsafe outside the syscall layer.
//! - **Waker = eventfd.** Cross-shard commands are delivered over an
//!   in-process channel; the sender then writes one `u64` to the shard's
//!   eventfd, which is registered in the same epoll set as the sockets.
//!   The shard thread therefore has a single blocking point.

use std::fs::File;
use std::io::{self, Read, Write};
use std::os::fd::{AsRawFd, FromRawFd, RawFd};

// Linux ABI constants (asm-generic). Stable since epoll's introduction;
// values are part of the kernel ABI and cannot change.
const EPOLL_CLOEXEC: i32 = 0o2000000; // == O_CLOEXEC
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000; // == O_NONBLOCK

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;

/// Mirror of the kernel's `struct epoll_event`. On x86-64 the kernel reads
/// the struct packed (no padding between `events` and `data`); other
/// architectures use natural alignment.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy, Default)]
struct EpollEvent {
    events: u32,
    data: u64,
}

// SAFETY: these signatures match the glibc prototypes for the epoll and
// eventfd syscall wrappers (see epoll_ctl(2), eventfd(2)); glibc is already
// linked by std. No types involve Rust-side ownership.
extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn close(fd: i32) -> i32;
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// Readiness interest for a registered descriptor.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const READ_WRITE: Interest = Interest { readable: true, writable: true };

    fn bits(self) -> u32 {
        // RDHUP lets a half-closed peer surface as an event even when we
        // have drained the read buffer (level-triggered EPOLLIN would also
        // fire on EOF, but only while data/EOF is unread).
        let mut bits = EPOLLRDHUP;
        if self.readable {
            bits |= EPOLLIN;
        }
        if self.writable {
            bits |= EPOLLOUT;
        }
        bits
    }
}

/// One readiness notification, translated out of the raw event mask.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error or hangup: the owner should read until `Closed`/error and drop
    /// the connection. (Level-triggered `readable` accompanies most hangups,
    /// but a pure RST can arrive with only ERR set.)
    pub hangup: bool,
}

/// Reusable output buffer for [`Poller::wait`].
pub struct Events {
    buf: Vec<EpollEvent>,
    len: usize,
}

impl Events {
    pub fn with_capacity(cap: usize) -> Events {
        Events { buf: vec![EpollEvent::default(); cap.max(1)], len: 0 }
    }

    pub fn iter(&self) -> impl Iterator<Item = Event> + '_ {
        self.buf[..self.len].iter().map(|ev| {
            // Copy the (potentially packed) fields out by value before use.
            let bits = ev.events;
            let token = ev.data;
            Event {
                token,
                readable: bits & (EPOLLIN | EPOLLRDHUP) != 0,
                writable: bits & EPOLLOUT != 0,
                hangup: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
            }
        })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// An epoll instance. Registered descriptors are identified by caller
/// tokens; the poller never owns the descriptors themselves (the `Conn`
/// table does), except for the fd of the epoll set itself.
pub struct Poller {
    epfd: RawFd,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        // SAFETY: epoll_create1 takes no pointers; a negative return is
        // mapped to errno by cvt.
        let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, ev: Option<&mut EpollEvent>) -> io::Result<()> {
        let ptr = match ev {
            Some(ev) => ev as *mut EpollEvent,
            None => std::ptr::null_mut(),
        };
        // SAFETY: `ptr` is either null (DEL ignores it on post-2.6.9
        // kernels) or points at a live EpollEvent for the duration of the
        // call; the kernel only reads it.
        cvt(unsafe { epoll_ctl(self.epfd, op, fd, ptr) })?;
        Ok(())
    }

    /// Register `fd` with the given interest under `token`.
    pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest.bits(), data: token };
        self.ctl(EPOLL_CTL_ADD, fd, Some(&mut ev))
    }

    /// Change the interest set of an already-registered `fd`.
    pub fn rearm(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest.bits(), data: token };
        self.ctl(EPOLL_CTL_MOD, fd, Some(&mut ev))
    }

    /// Remove `fd` from the set. Dropping/closing the fd also removes it;
    /// explicit deregistration keeps the sequencing obvious at call sites.
    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, None)
    }

    /// Block until at least one registered descriptor is ready, a timeout
    /// elapses, or the waker fires. `timeout_ms` of `None` blocks
    /// indefinitely; `Some(0)` polls. EINTR is retried internally.
    pub fn wait(&self, events: &mut Events, timeout_ms: Option<i32>) -> io::Result<usize> {
        let timeout = timeout_ms.unwrap_or(-1);
        let cap = events.buf.len() as i32;
        loop {
            // SAFETY: the events buffer outlives the call and `cap` is its
            // exact element count; the kernel writes at most `cap` entries.
            let n = unsafe { epoll_wait(self.epfd, events.buf.as_mut_ptr(), cap, timeout) };
            match cvt(n) {
                Ok(n) => {
                    events.len = n as usize;
                    return Ok(events.len);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: epfd was returned by epoll_create1 and is closed exactly
        // once, here.
        let _ = unsafe { close(self.epfd) };
    }
}

/// Cross-thread wakeup for a [`Poller`]: an eventfd registered in the same
/// epoll set as the sockets. `wake` is called by *other* threads after
/// enqueuing a command; `drain` is called by the owning shard when the
/// waker's token surfaces from `wait`.
pub struct Waker {
    file: File,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        // SAFETY: eventfd takes no pointers; negative return maps to errno
        // via cvt.
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        // SAFETY: `fd` is a freshly created, owned eventfd; File takes
        // sole ownership and will close it exactly once on drop.
        let file = unsafe { File::from_raw_fd(fd) };
        Ok(Waker { file })
    }

    pub fn fd(&self) -> RawFd {
        self.file.as_raw_fd()
    }

    /// Nudge the polling thread. Nonblocking: if the counter is already
    /// saturated the poller is guaranteed to be awake, so a short write is
    /// ignorable.
    pub fn wake(&self) {
        let one = 1u64.to_ne_bytes();
        let _ = (&self.file).write(&one);
    }

    /// Reset the eventfd counter so the next `wake` re-triggers readiness.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        // Nonblocking read: WouldBlock means another drain already won.
        let _ = (&self.file).read(&mut buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn waker_wakes_and_drains() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        poller.register(waker.fd(), u64::MAX, Interest::READ).unwrap();
        let mut events = Events::with_capacity(4);

        // Nothing pending: a zero-timeout wait reports no events.
        assert_eq!(poller.wait(&mut events, Some(0)).unwrap(), 0);

        waker.wake();
        waker.wake(); // coalesces into one readiness event
        assert_eq!(poller.wait(&mut events, Some(1000)).unwrap(), 1);
        let ev = events.iter().next().unwrap();
        assert_eq!(ev.token, u64::MAX);
        assert!(ev.readable);

        // Level-triggered: still ready until drained.
        assert_eq!(poller.wait(&mut events, Some(0)).unwrap(), 1);
        waker.drain();
        assert_eq!(poller.wait(&mut events, Some(0)).unwrap(), 0);

        // Wakes after a drain re-trigger readiness.
        waker.wake();
        assert_eq!(poller.wait(&mut events, Some(1000)).unwrap(), 1);
    }

    #[test]
    fn socket_readiness_and_write_interest_toggle() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.register(server.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut events = Events::with_capacity(4);

        // Idle socket: no events.
        assert_eq!(poller.wait(&mut events, Some(0)).unwrap(), 0);

        client.write_all(b"ping").unwrap();
        assert_eq!(poller.wait(&mut events, Some(1000)).unwrap(), 1);
        let ev = events.iter().next().unwrap();
        assert_eq!(ev.token, 7);
        assert!(ev.readable && !ev.writable);

        // Rearm for write interest: an idle outgoing buffer is writable.
        poller.rearm(server.as_raw_fd(), 7, Interest::READ_WRITE).unwrap();
        assert_eq!(poller.wait(&mut events, Some(1000)).unwrap(), 1);
        assert!(events.iter().next().unwrap().writable);

        // Peer close surfaces as readable + hangup.
        poller.rearm(server.as_raw_fd(), 7, Interest::READ).unwrap();
        drop(client);
        // Drain the pending "ping" first so EOF readiness is unambiguous.
        let mut sink = [0u8; 16];
        use std::io::Read as _;
        let mut s = &server;
        while matches!(s.read(&mut sink), Ok(n) if n > 0) {}
        assert_eq!(poller.wait(&mut events, Some(1000)).unwrap(), 1);
        let ev = events.iter().next().unwrap();
        assert!(ev.hangup || ev.readable);

        poller.deregister(server.as_raw_fd()).unwrap();
        assert_eq!(poller.wait(&mut events, Some(0)).unwrap(), 0);
    }
}
