//! The reactor: connection-facing state machine of the RSDS server (§IV-A).
//!
//! "The reactor manages worker and client connections, maintains
//! bookkeeping information and translates scheduler assignments into DASK
//! messages which are then sent to the workers."
//!
//! Pure state machine: [`Reactor::on_message`] consumes one inbound message
//! and appends outbound `(Dest, Msg)` pairs; no I/O happens here. The TCP
//! layer ([`super::net`]) and the integration tests drive it identically.
//!
//! Multi-graph serving: the reactor keeps one [`GraphRun`] per live
//! [`RunId`] and one scheduler per run (via [`SchedulerPool`]), so any
//! number of clients can submit graphs concurrently — recycled dense
//! `TaskId`s can never alias state across runs because every task-bearing
//! message on the wire names its run.
//!
//! Worker-disconnect resilience: a disconnect no longer fails every run
//! that touched the worker. Each affected run is repaired by *lineage
//! recovery* ([`GraphRun::recover`]): lost assignments are re-placed, lost
//! outputs are recomputed from their producers, queued tasks with
//! evaporated inputs are cancelled on live workers (`cancel-compute`) and
//! re-sent once their inputs exist again — all bounded by a per-run
//! recovery budget, past which the old `graph-failed` behavior returns.
//! See `docs/recovery.md` for the invariants.
//!
//! Run-fair dispatch: worker-bound messages are not emitted inside
//! `on_message` in arrival order (which let one huge submission starve a
//! small one). State transitions still happen synchronously, but the
//! translated messages are *parked* on the owning run's outbox and emitted
//! by [`Reactor::pump`] in bounded rounds, one run per round, chosen by a
//! pluggable [`FairnessPolicy`] (round-robin by default). Admission
//! control caps *live* runs per client: excess `submit-graph`s are acked
//! with `run-queued` and parked in a FIFO admission queue, activating as
//! that client's runs retire. See `docs/architecture.md` §"Fairness &
//! admission".

use super::fairness::{FairnessPolicy, RoundRobin, RunQueueStat, DEFAULT_DISPATCH_QUOTA};
use super::pool::SchedulerPool;
use super::state::{ExtendPlan, GraphRun, Parked, ReplicaSet, RunIdAlloc, TaskState};
use super::window::BoundedWindow;
use crate::overhead::RuntimeProfile;
use crate::protocol::{
    encode_compute_task_into, ComputeTaskParts, Msg, RunId, TaskInputLoc, TaskInputRef,
    FETCH_FAILED_PREFIX, RECOVERY_EXHAUSTED_REASON,
};
use crate::scheduler::{Action, Scheduler, WorkerId, WorkerInfo};
use crate::taskgraph::{TaskGraph, TaskId, TaskSpec};
use crate::util::timing::{busy_wait_us, Stopwatch};
use std::collections::{HashMap, VecDeque};

/// Message destination, resolved to a socket by the transport layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dest {
    Client(u32),
    Worker(WorkerId),
}

/// Message origin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Origin {
    /// Not yet registered; `conn` is a transport-level token echoed back in
    /// the registration reply path.
    Unregistered { conn: u64 },
    Client(u32),
    Worker(WorkerId),
}

/// Post-run statistics for one graph. Message and steal counters are
/// per-run (attributed to the run the message named), so concurrent graphs
/// get independent reports.
#[derive(Debug, Clone, PartialEq)]
pub struct ReactorReport {
    pub run: RunId,
    pub client: u32,
    pub graph_name: String,
    pub n_tasks: u64,
    pub makespan_us: u64,
    /// Average overhead per task: makespan / #tasks (the paper's AOT).
    pub aot_us: f64,
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub msgs_in: u64,
    pub msgs_out: u64,
    /// Worker-disconnect recoveries this run absorbed (0 on a clean run).
    pub recoveries: u32,
    /// Previously finished tasks forced back to execution (lost-output
    /// resurrections across all recovery passes plus fetch-retry safety
    /// nets). The `fig_recovery` bench's headline: replication exists to
    /// drive this toward 0.
    pub tasks_recomputed: u64,
}

/// Cap on recoverable `fetch-failed` re-runs *per task* — a stale
/// `who_has` address can bounce a task a few times before the peer's
/// disconnect event is processed; past this the error is treated as
/// fatal. Per task (not per run) so one wide disconnect — many tasks
/// fetching from the same corpse at once — cannot exhaust a shared budget.
const MAX_FETCH_RETRIES: u32 = 5;

#[derive(Debug, Clone, Copy)]
struct WorkerMeta {
    #[allow(dead_code)] // kept for introspection/debug dumps
    info: WorkerInfo,
    connected: bool,
}

/// Cross-shard id allocators. Worker ids index cluster-global tables
/// (every shard's runs may be placed on any worker) and client ids key
/// completed-run reports, so under the sharded server every shard's
/// reactor draws both from one shared pair of counters instead of its
/// local lengths. Deliberately plain `std` atomics, not the loom shim:
/// id allocation is a fetch-add, not a model-checked core.
#[derive(Debug, Default)]
pub struct SharedIds {
    next_client: std::sync::atomic::AtomicU32,
    next_worker: std::sync::atomic::AtomicU32,
}

/// Default cap on concurrently *executing* runs per client; further
/// submissions park in the admission queue. Generous enough that ordinary
/// pipelining never queues, small enough that a runaway submitter cannot
/// multiply scheduler instances without bound.
pub const DEFAULT_MAX_LIVE_RUNS_PER_CLIENT: usize = 16;

/// Default number of completed-run reports retained in memory; older
/// reports are dropped (counted, so watermarks stay consistent) so a
/// long-lived server does not grow its history without bound.
pub const DEFAULT_REPORT_RETENTION: usize = 4096;

/// Default cap on *parked* submissions per client. Without it the
/// admission queue would undo the live-run cap's point: a runaway
/// submitter could buffer unbounded graphs server-side. Past this the
/// submission fails (`graph-failed`) instead of parking.
pub const DEFAULT_MAX_QUEUED_RUNS_PER_CLIENT: usize = 64;

/// Default fan-out threshold for marking an output replication-worthy: two
/// consumers is the smallest fan-out where one lost copy stalls more than
/// one task.
pub const DEFAULT_REPLICATION_FANOUT: u32 = 2;

/// A submission parked by admission control: acked (`run-queued`) but not
/// yet executing — no `GraphRun`, no scheduler instance.
struct ParkedRun {
    run: RunId,
    client: u32,
    graph: TaskGraph,
    scheduler: Option<String>,
    /// Reactor-clock µs at the original submission; the run's makespan
    /// spans the queued phase (the client-observed latency).
    submitted_at_us: u64,
    /// Extensible submission: still accepting `submit-extend` batches.
    /// Extensions arriving while parked fold into `graph` directly (no
    /// `GraphRun` exists yet); a closing extension clears this so the
    /// eventual activation starts the run already closed.
    open: bool,
}

/// The reactor state machine.
pub struct Reactor {
    pool: SchedulerPool,
    profile: RuntimeProfile,
    /// Busy-wait the profile's costs on the hot path (Dask emulation).
    emulate: bool,
    clock: Stopwatch,
    workers: Vec<WorkerMeta>,
    worker_addrs: Vec<String>,
    n_clients: u32,
    runs: HashMap<RunId, GraphRun>,
    run_ids: RunIdAlloc,
    /// Retained window of completed-run reports. [`BoundedWindow`] owns
    /// the `dropped + len == completions` invariant; the TCP layer's
    /// published store is the same type, reconciled by completion count.
    reports: BoundedWindow<ReactorReport>,
    actions_buf: Vec<Action>,
    /// Recovery budget stamped onto each new run (see
    /// [`GraphRun::recover`]); defaults to
    /// [`super::state::DEFAULT_MAX_RECOVERIES`].
    default_max_recoveries: u32,
    /// Dispatch-order policy over the per-run outboxes.
    policy: Box<dyn FairnessPolicy>,
    /// Messages emitted per [`Reactor::pump`] round.
    quota: usize,
    /// Monotonic tick for outbox empty→non-empty transitions (the
    /// arrival-order key the `arrival` policy sorts by).
    outbox_seq: u64,
    /// Parked submissions, FIFO; activated as their client's runs retire.
    admission: VecDeque<ParkedRun>,
    max_live_per_client: usize,
    max_queued_per_client: usize,
    /// Reused per-round buffers: `pump` runs once per inbound event, and
    /// the per-message event path is kept allocation-free (PR 2's codec
    /// work made that a measured property; staging buffers must not undo
    /// it).
    stats_buf: Vec<RunQueueStat>,
    emitted_buf: Vec<(WorkerId, Parked)>,
    /// Shared client/worker id counters under the sharded server; `None`
    /// (the default) keeps the single-reactor local sequences.
    shared_ids: Option<std::sync::Arc<SharedIds>>,
    /// Object-store replication factor `k` (1 = off): outputs flagged in a
    /// run's `replicate_hint` are pushed to `k-1` extra workers when they
    /// first finish, so most worker deaths purge `who_has` instead of
    /// recomputing lineage.
    replication: usize,
    /// Consumer-count threshold past which an output counts as hot (see
    /// [`crate::taskgraph::replication_hints`]).
    replication_fanout: u32,
}

/// A compute-task assignment about to be emitted, with every field
/// *borrowed* from where it already lives: the key and payload from the
/// run's submitted graph, the input addresses from the `who_has` tables
/// and the worker registration table. Nothing here owns a string — the
/// allocation-free dispatch path ([`Reactor::pump_into`] +
/// [`OutboundSink::emit_compute`]) encodes straight from these borrows via
/// [`encode_compute_task_into`]; [`ComputeDispatch::to_msg`] materializes
/// the owned [`Msg`] only for sinks that need one (tests, in-process
/// drivers).
pub struct ComputeDispatch<'a> {
    pub run: RunId,
    pub task: TaskId,
    pub worker: WorkerId,
    pub priority: i64,
    graph: &'a TaskGraph,
    who_has: &'a [ReplicaSet],
    addrs: &'a [String],
}

/// Borrowed iterator over an assignment's `who_has` input locations
/// (one [`TaskInputRef`] per dependency, no allocation).
#[derive(Clone)]
pub struct ComputeInputs<'a> {
    graph: &'a TaskGraph,
    who_has: &'a [ReplicaSet],
    addrs: &'a [String],
    target: WorkerId,
    inputs: std::slice::Iter<'a, TaskId>,
}

impl<'a> Iterator for ComputeInputs<'a> {
    type Item = TaskInputRef<'a>;

    fn next(&mut self) -> Option<TaskInputRef<'a>> {
        let &input = self.inputs.next()?;
        let holders = &self.who_has[input.idx()];
        // First holder wins (the producer); the empty address means "local
        // to the assignment's target worker".
        let addr = match holders.first() {
            Some(h) if h == self.target => "",
            Some(h) => self.addrs.get(h.idx()).map(String::as_str).unwrap_or(""),
            None => "",
        };
        let mut loc = TaskInputRef::new(input, addr, self.graph.task(input).output_size);
        // Every further replica rides along as an alternate source (capped
        // at the protocol's MAX_ALT_ADDRS by `push_alt`): the worker fails
        // over to them before escalating to a `fetch-failed` re-run.
        for h in holders.iter().skip(1) {
            if h == self.target {
                continue; // local copy: the worker's own store covers it
            }
            if let Some(a) = self.addrs.get(h.idx()) {
                if !a.is_empty() {
                    loc.push_alt(a);
                }
            }
        }
        Some(loc)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.inputs.size_hint()
    }
}

impl ExactSizeIterator for ComputeInputs<'_> {}

impl<'a> ComputeDispatch<'a> {
    /// Resolve a parked assignment against its live run. Public so benches
    /// and tests can drive the borrowed encode path directly.
    pub fn new(
        run_id: RunId,
        task: TaskId,
        worker: WorkerId,
        priority: i64,
        run: &'a GraphRun,
        worker_addrs: &'a [String],
    ) -> ComputeDispatch<'a> {
        ComputeDispatch {
            run: run_id,
            task,
            worker,
            priority,
            graph: &run.graph,
            who_has: &run.who_has,
            addrs: worker_addrs,
        }
    }

    /// The task's Dask-style key, borrowed from the graph.
    pub fn key(&self) -> &'a str {
        &self.graph.task(self.task).key
    }

    /// Scalar wire fields, borrowed (see [`ComputeTaskParts`]).
    pub fn parts(&self) -> ComputeTaskParts<'a> {
        let spec = self.graph.task(self.task);
        ComputeTaskParts {
            run: self.run,
            task: self.task,
            key: &spec.key,
            payload: &spec.payload,
            duration_us: spec.duration_us,
            output_size: spec.output_size,
            priority: self.priority,
            consumers: self.graph.consumers(self.task).len() as u32,
            cores: spec.cores,
        }
    }

    /// Borrowed input locations, resolved against `who_has` at call time.
    pub fn inputs(&self) -> ComputeInputs<'a> {
        ComputeInputs {
            graph: self.graph,
            who_has: self.who_has,
            addrs: self.addrs,
            target: self.worker,
            inputs: self.graph.task(self.task).inputs.iter(),
        }
    }

    /// Encode the `compute-task` frame body straight from the borrows —
    /// the zero-allocation dispatch path (byte-identical to encoding
    /// [`ComputeDispatch::to_msg`]).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        encode_compute_task_into(&self.parts(), self.inputs(), out);
    }

    /// Materialize the owned message (allocates: key clone + input vector).
    /// Sinks that hand messages to in-process consumers use this; the TCP
    /// sink never does.
    pub fn to_msg(&self) -> Msg {
        let spec = self.graph.task(self.task);
        Msg::ComputeTask {
            run: self.run,
            task: self.task,
            key: spec.key.clone(),
            payload: spec.payload.clone(),
            duration_us: spec.duration_us,
            output_size: spec.output_size,
            inputs: self
                .inputs()
                .map(|l| TaskInputLoc {
                    task: l.task,
                    addr: l.addr.to_string(),
                    alts: l.alts().iter().map(|a| a.to_string()).collect(),
                    nbytes: l.nbytes,
                })
                .collect(),
            priority: self.priority,
            consumers: self.graph.consumers(self.task).len() as u32,
            cores: spec.cores,
        }
    }
}

/// Deterministic replica placement: connected workers in id order,
/// cyclically from the producer's successor, skipping current holders and
/// unknown data addresses; up to `want` taken. Deterministic so the
/// simulator (`sim/engine.rs`) mirrors the policy exactly — the
/// scheduler-vs-reactor parity suite depends on it.
fn replica_targets(
    workers: &[WorkerMeta],
    addrs: &[String],
    holders: &ReplicaSet,
    producer: WorkerId,
    want: usize,
) -> Vec<String> {
    let n = workers.len();
    let mut out = Vec::new();
    for off in 1..n {
        if out.len() >= want {
            break;
        }
        let idx = (producer.idx() + off) % n;
        if !workers[idx].connected || holders.contains(WorkerId(idx as u32)) {
            continue;
        }
        match addrs.get(idx) {
            Some(a) if !a.is_empty() => out.push(a.clone()),
            _ => {}
        }
    }
    out
}

/// Where [`Reactor::pump_into`] delivers emitted messages. The TCP layer's
/// sink encodes compute-tasks from the borrowed [`ComputeDispatch`]
/// directly into per-connection batch buffers (no owned message, no
/// allocation); the `Vec<(Dest, Msg)>` impl materializes owned messages
/// for tests and in-process drivers.
pub trait OutboundSink {
    /// An already-owned worker- or client-bound message.
    fn emit_msg(&mut self, dest: Dest, msg: Msg);
    /// A compute-task assignment in borrowed form, valid for this call.
    fn emit_compute(&mut self, dispatch: &ComputeDispatch<'_>);
}

impl OutboundSink for Vec<(Dest, Msg)> {
    fn emit_msg(&mut self, dest: Dest, msg: Msg) {
        self.push((dest, msg));
    }

    fn emit_compute(&mut self, dispatch: &ComputeDispatch<'_>) {
        self.push((Dest::Worker(dispatch.worker), dispatch.to_msg()));
    }
}

impl Reactor {
    pub fn new(pool: SchedulerPool, profile: RuntimeProfile, emulate: bool) -> Reactor {
        Reactor {
            pool,
            profile,
            emulate,
            clock: Stopwatch::start(),
            workers: Vec::new(),
            worker_addrs: Vec::new(),
            n_clients: 0,
            runs: HashMap::new(),
            run_ids: RunIdAlloc::default(),
            reports: BoundedWindow::new(DEFAULT_REPORT_RETENTION),
            actions_buf: Vec::new(),
            default_max_recoveries: super::state::DEFAULT_MAX_RECOVERIES,
            policy: Box::<RoundRobin>::default(),
            quota: DEFAULT_DISPATCH_QUOTA,
            outbox_seq: 0,
            admission: VecDeque::new(),
            max_live_per_client: DEFAULT_MAX_LIVE_RUNS_PER_CLIENT,
            max_queued_per_client: DEFAULT_MAX_QUEUED_RUNS_PER_CLIENT,
            stats_buf: Vec::new(),
            emitted_buf: Vec::new(),
            shared_ids: None,
            replication: 1,
            replication_fanout: DEFAULT_REPLICATION_FANOUT,
        }
    }

    /// Share client/worker id allocation with the other reactor shards
    /// (ids stay globally unique without the shards coordinating).
    pub fn with_shared_ids(mut self, ids: std::sync::Arc<SharedIds>) -> Reactor {
        self.shared_ids = Some(ids);
        self
    }

    /// Allocate run ids in the strided sequence `start, start+stride, …`
    /// so concurrent shards never collide and `run.0 % stride` recovers
    /// the owning shard (how worker messages are routed home).
    pub fn with_run_stride(mut self, start: u32, stride: u32) -> Reactor {
        assert!(stride >= 1, "stride must be positive");
        assert!(start < stride, "start must index into the stride");
        self.run_ids = RunIdAlloc::strided(start, stride);
        self
    }

    /// Replace the dispatch fairness policy (default: round-robin).
    pub fn with_fairness(mut self, policy: Box<dyn FairnessPolicy>) -> Reactor {
        self.policy = policy;
        self
    }

    /// Override the per-[`Reactor::pump`]-round message quota (≥ 1).
    pub fn with_dispatch_quota(mut self, quota: usize) -> Reactor {
        assert!(quota >= 1, "dispatch quota must be positive");
        self.quota = quota;
        self
    }

    /// Override the per-client live-run cap (≥ 1 — with 0 nothing could
    /// ever activate).
    pub fn with_admission_cap(mut self, cap: usize) -> Reactor {
        assert!(cap >= 1, "admission cap must be positive");
        self.max_live_per_client = cap;
        self
    }

    /// Override the per-client *parked*-submission cap (≥ 1); past it a
    /// submission fails instead of parking.
    pub fn with_admission_queue_cap(mut self, cap: usize) -> Reactor {
        assert!(cap >= 1, "admission queue cap must be positive");
        self.max_queued_per_client = cap;
        self
    }

    /// Override how many completed-run reports are retained (≥ 1).
    /// Builder-time only: replacing the window discards nothing because no
    /// run has completed yet.
    pub fn with_report_retention(mut self, retention: usize) -> Reactor {
        assert!(retention >= 1, "report retention must be positive");
        self.reports = BoundedWindow::new(retention);
        self
    }

    /// Enable proactive k-replication of hot/critical outputs: each output
    /// flagged by [`crate::taskgraph::replication_hints`] (fan-out ≥
    /// `fanout` consumers, or on the critical path) is pushed to `k-1`
    /// extra workers when it first finishes. `k` counts the primary copy;
    /// `k = 1` disables (the default).
    pub fn with_replication(mut self, k: usize, fanout: u32) -> Reactor {
        assert!(k >= 1, "replication factor counts the primary copy");
        self.replication = k;
        self.replication_fanout = fanout;
        self
    }

    /// Override the per-run worker-disconnect recovery budget. With 0,
    /// any disconnect that loses work or data fails the run like before
    /// recovery existed — except *trivial* losses (every output the dead
    /// worker held has a surviving replica and nothing was queued on it),
    /// which are absorbed for free at any budget.
    pub fn with_max_recoveries(mut self, cap: u32) -> Reactor {
        self.default_max_recoveries = cap;
        self
    }

    pub fn n_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.connected).count()
    }

    /// Grow the worker tables so `idx` is addressable. Pad slots are
    /// disconnected placeholders: with shared id allocation another shard
    /// may have handed out lower ids whose broadcasts haven't arrived yet
    /// (per-sender FIFO orders each worker's own join before any message
    /// that names it, but *different* workers' joins race freely).
    fn ensure_worker_slot(&mut self, idx: usize) {
        while self.workers.len() <= idx {
            let id = WorkerId(self.workers.len() as u32);
            self.workers.push(WorkerMeta {
                info: WorkerInfo { id, ncores: 0, node: 0 },
                connected: false,
            });
            self.worker_addrs.push(String::new());
        }
    }

    /// Absorb a worker that registered on another shard (the cross-shard
    /// join broadcast): record its metadata and make it schedulable for
    /// this shard's runs. No `Welcome` is emitted — the home shard already
    /// answered over the worker's own connection. Idempotent against a
    /// duplicate broadcast.
    pub fn register_remote_worker(&mut self, info: WorkerInfo, data_addr: String) {
        self.ensure_worker_slot(info.id.idx());
        if self.workers[info.id.idx()].connected {
            return;
        }
        self.workers[info.id.idx()] = WorkerMeta { info, connected: true };
        self.worker_addrs[info.id.idx()] = data_addr;
        self.pool.add_worker(info);
    }

    /// Retained completed-run reports, oldest first. The window is bounded
    /// by the report retention (default
    /// [`DEFAULT_REPORT_RETENTION`]); [`Reactor::report_count`] is the
    /// monotonic total including evicted reports.
    pub fn reports(&self) -> &[ReactorReport] {
        self.reports.as_slice()
    }

    /// Total runs completed so far (monotonic; includes reports already
    /// evicted from the retained window).
    pub fn report_count(&self) -> usize {
        self.reports.total()
    }

    /// Reports evicted from the retained window so far.
    pub fn reports_dropped(&self) -> usize {
        self.reports.dropped()
    }

    /// Number of graphs currently executing.
    pub fn live_runs(&self) -> usize {
        self.runs.len()
    }

    /// Number of submissions parked in the admission queue.
    pub fn queued_runs(&self) -> usize {
        self.admission.len()
    }

    /// Total parked worker-bound messages across all runs' outboxes.
    pub fn pending_messages(&self) -> usize {
        self.runs.values().map(|r| r.outbox.len()).sum()
    }

    /// Bookkeeping state of a live run (tests / introspection).
    pub fn run_state(&self, run: RunId) -> Option<&GraphRun> {
        self.runs.get(&run)
    }

    /// The scheduler instance serving a live run (tests / introspection).
    pub fn scheduler_view(&self, run: RunId) -> Option<&dyn Scheduler> {
        self.pool.peek(run)
    }

    /// Charge emulated runtime cost (no-op unless `emulate`).
    fn charge(&self, us: f64) {
        if self.emulate && us >= 1.0 {
            busy_wait_us(us as u64);
        }
    }

    fn charge_msg(&self, approx_bytes: usize) {
        self.charge(self.profile.msg_cost_us(approx_bytes));
    }

    /// Park a worker-bound message on its run's outbox. State transitions
    /// were already applied by the caller; the per-message emission cost is
    /// charged when [`Reactor::pump`] emits it, so a large run's backlog
    /// cannot monopolize the reactor. Assignments park as id-only
    /// [`Parked::Compute`] entries — no key/address strings are cloned at
    /// park time (or, on the TCP sink, ever).
    fn park(&mut self, run_id: RunId, worker: WorkerId, msg: Parked) {
        let run = self.runs.get_mut(&run_id).expect("park for dead run");
        if run.outbox.is_empty() {
            run.outbox_since = self.outbox_seq;
            self.outbox_seq += 1;
        }
        run.outbox.push_back((worker, msg));
    }

    /// [`Reactor::pump_into`] with a message-materializing `Vec` sink —
    /// the test/driver convenience form.
    pub fn pump(&mut self, out: &mut Vec<(Dest, Msg)>) -> Option<RunId> {
        self.pump_into(out)
    }

    /// One fairness round: the policy picks a run among those with parked
    /// messages and up to the dispatch quota of its messages are emitted
    /// (per-run FIFO). Returns the serviced run, or `None` when nothing is
    /// pending. The transport loop interleaves pump rounds with inbound
    /// events, handing an encoding sink so a warm round performs zero heap
    /// allocations end to end; tests use [`Reactor::drain`].
    pub fn pump_into(&mut self, sink: &mut dyn OutboundSink) -> Option<RunId> {
        // Reused buffers (taken, not borrowed, so `charge_msg`'s `&self`
        // below doesn't conflict): a warm pump round allocates nothing.
        let mut stats = std::mem::take(&mut self.stats_buf);
        stats.clear();
        stats.extend(self.runs.iter().filter(|(_, r)| !r.outbox.is_empty()).map(
            |(&id, r)| RunQueueStat {
                run: id,
                pending: r.outbox.len(),
                remaining: r.remaining as u64,
                since: r.outbox_since,
            },
        ));
        if stats.is_empty() {
            self.stats_buf = stats;
            return None;
        }
        let mut pick = self.policy.pick(&stats);
        if !stats.iter().any(|s| s.run == pick) {
            // Contract violation by a (user-supplied) policy. Loud in
            // debug; in release fall back to the oldest pending queue
            // rather than returning `Some` with zero emissions — that
            // would hang `drain` and busy-spin the transport loop.
            debug_assert!(false, "policy picked {pick}, which has no pending messages");
            pick = stats
                .iter()
                .min_by_key(|s| (s.since, s.run))
                .expect("stats is non-empty")
                .run;
        }
        self.stats_buf = stats;
        let mut emitted = std::mem::take(&mut self.emitted_buf);
        {
            let run = self.runs.get_mut(&pick).expect("picked run is live");
            for _ in 0..self.quota {
                match run.outbox.pop_front() {
                    Some(m) => emitted.push(m),
                    None => break,
                }
            }
            // The remainder keeps its activation tick: the arrival policy
            // must drain a queue to exhaustion before moving on, exactly
            // like the pre-fairness reactor.
        }
        for (worker, parked) in emitted.drain(..) {
            match parked {
                Parked::Wire(msg) => {
                    self.charge_msg(64);
                    sink.emit_msg(Dest::Worker(worker), msg);
                }
                Parked::Compute { task, priority } => {
                    self.charge_msg(192);
                    // Resolve against the run *now*: key/payload from the
                    // graph, input addresses from the current `who_has`
                    // (at least as fresh as a park-time snapshot).
                    let run = self.runs.get_mut(&pick).expect("picked run is live");
                    // Stamp the consumer count baked into this frame: a
                    // later graph extension that raises it delivers only
                    // the gap as a `pin-data` delta (see `TaskFinished`).
                    run.emitted_consumers[task.idx()] =
                        run.graph.consumers(task).len() as u32;
                    let run = &*run;
                    let dispatch = ComputeDispatch::new(
                        pick,
                        task,
                        worker,
                        priority,
                        run,
                        &self.worker_addrs,
                    );
                    sink.emit_compute(&dispatch);
                }
            }
        }
        self.emitted_buf = emitted;
        Some(pick)
    }

    /// Emit every parked message (repeated [`Reactor::pump`] rounds, still
    /// in policy order). Tests and single-shot drivers use this; the
    /// transport loop pumps incrementally instead.
    pub fn drain(&mut self, out: &mut Vec<(Dest, Msg)>) {
        while self.pump(out).is_some() {}
    }

    /// [`Reactor::drain`] over an arbitrary sink.
    pub fn drain_into(&mut self, sink: &mut dyn OutboundSink) {
        while self.pump_into(sink).is_some() {}
    }

    /// Tell every connected worker to drop a retired run's queued tasks and
    /// stored outputs; without this a long-lived worker leaks every run.
    fn release_run(&self, run_id: RunId, out: &mut Vec<(Dest, Msg)>) {
        for (i, meta) in self.workers.iter().enumerate() {
            if meta.connected {
                out.push((Dest::Worker(WorkerId(i as u32)), Msg::ReleaseRun { run: run_id }));
            }
        }
    }

    /// Abort a run: drop its state and scheduler, tell its client.
    fn fail_run(&mut self, run_id: RunId, reason: String, out: &mut Vec<(Dest, Msg)>) {
        self.pool.remove(run_id);
        if let Some(run) = self.runs.remove(&run_id) {
            out.push((Dest::Client(run.client), Msg::GraphFailed { run: run_id, reason }));
            self.release_run(run_id, out);
        }
    }

    /// Complete a run if all its tasks finished: emit report + GraphDone.
    fn maybe_complete(&mut self, run_id: RunId, out: &mut Vec<(Dest, Msg)>) {
        let done = self.runs.get(&run_id).map(|r| r.is_done()).unwrap_or(false);
        if !done {
            return;
        }
        // Dropping the run drops its outbox too: a message still parked at
        // completion is a recovery duplicate (its task finished via an
        // earlier copy) and the release-run broadcast purges its target.
        let mut run = self.runs.remove(&run_id).expect("checked above");
        self.pool.remove(run_id);
        run.msgs_out += 1 + self.n_workers() as u64; // GraphDone + ReleaseRuns below
        let makespan_us = self.clock.elapsed_us().saturating_sub(run.submitted_at_us);
        let n_tasks = run.graph.len() as u64;
        // The window bounds the in-memory history; evictions are counted
        // inside it so `report_count` stays monotonic and pollers'
        // watermarks keep meaning "reports seen so far". The TCP layer
        // publishes through the same `BoundedWindow` type, reconciled by
        // completion count in `reactor_loop`.
        self.reports.push(ReactorReport {
            run: run_id,
            client: run.client,
            graph_name: run.graph.name.clone(),
            n_tasks,
            makespan_us,
            // max(1): an empty graph must not report NaN.
            aot_us: makespan_us as f64 / n_tasks.max(1) as f64,
            steals_attempted: run.steals_attempted,
            steals_failed: run.steals_failed,
            msgs_in: run.msgs_in,
            msgs_out: run.msgs_out,
            recoveries: run.recoveries,
            tasks_recomputed: run.tasks_recomputed,
        });
        out.push((Dest::Client(run.client), Msg::GraphDone { run: run_id, makespan_us, n_tasks }));
        self.release_run(run_id, out);
    }

    /// Start executing a (fresh or parked) submission: create the run and
    /// its scheduler, seed the roots. `sub.submitted_at_us` is the original
    /// submission time, so a run's makespan spans its queued phase —
    /// that's the latency its client observed. `prior_msgs_out` counts the
    /// ack messages already sent for this run.
    fn activate_run(&mut self, sub: ParkedRun, prior_msgs_out: u64, out: &mut Vec<(Dest, Msg)>) {
        let ParkedRun { run: run_id, client, graph, scheduler, submitted_at_us, open } = sub;
        self.charge(self.profile.task_transition_us * graph.len() as f64 * 0.2);
        if let Err(reason) = self.pool.create_with(run_id, &graph, scheduler.as_deref()) {
            // Unreachable for named overrides (validated at submission);
            // kept as the safety net for factory pools.
            out.push((Dest::Client(client), Msg::GraphFailed { run: run_id, reason }));
            return;
        }
        let mut run = GraphRun::new(graph, client, submitted_at_us);
        if open {
            run.set_open();
        }
        run.max_recoveries = self.default_max_recoveries;
        if self.replication > 1 {
            run.replicate_hint =
                crate::taskgraph::replication_hints(&run.graph, self.replication_fanout);
        }
        run.msgs_in += 1; // the submission itself
        run.msgs_out += prior_msgs_out;
        let roots = run.ready_roots();
        self.runs.insert(run_id, run);
        self.pool
            .get(run_id)
            .expect("just created")
            .tasks_ready(&roots, &mut self.actions_buf);
        self.flush_actions(run_id, out);
        // Degenerate empty graph: done before any task report.
        self.maybe_complete(run_id, out);
    }

    /// Handle a `submit-extend`: graft a task batch onto an *open* run —
    /// live or still parked in the admission queue — ack with the new task
    /// total, then apply the [`ExtendPlan`]: seed the scheduler with the
    /// newly ready tasks, push `pin-data` refcount deltas to every holder
    /// of a resident finished input, and let transitively resurrected
    /// lineage recompute through the normal ready path. `last: true`
    /// closes the run (an empty batch with `last` is a pure close — a
    /// quiescent run retires immediately).
    fn handle_extend(
        &mut self,
        client: u32,
        run_id: RunId,
        tasks: Vec<TaskSpec>,
        last: bool,
        out: &mut Vec<(Dest, Msg)>,
    ) {
        // A parked submission has no GraphRun or scheduler yet: fold the
        // batch into the stored graph so the eventual activation sees the
        // whole prefix at once.
        if let Some(i) = self.admission.iter().position(|p| p.run == run_id) {
            if self.admission[i].client != client {
                log::warn!("client {client} tried to extend foreign {run_id}; ignored");
                return;
            }
            let p = &mut self.admission[i];
            if !p.open {
                out.push((
                    Dest::Client(client),
                    Msg::GraphFailed {
                        run: run_id,
                        reason: format!("{run_id} is not open for extension"),
                    },
                ));
                let _ = self.admission.remove(i);
                return;
            }
            if !tasks.is_empty() {
                if let Err(e) = p.graph.extend(tasks) {
                    out.push((
                        Dest::Client(client),
                        Msg::GraphFailed {
                            run: run_id,
                            reason: format!("invalid extension: {e}"),
                        },
                    ));
                    let _ = self.admission.remove(i);
                    return;
                }
            }
            if last {
                p.open = false;
            }
            let n_tasks = p.graph.len() as u64;
            out.push((Dest::Client(client), Msg::GraphSubmitted { run: run_id, n_tasks }));
            return;
        }
        enum Outcome {
            Unknown,
            Foreign,
            NotOpen,
            Invalid(String),
            Extended { plan: Option<ExtendPlan>, n_total: u64, n_new: usize },
        }
        let outcome = match self.runs.get_mut(&run_id) {
            None => Outcome::Unknown,
            Some(run) if run.client != client => Outcome::Foreign,
            Some(run) if !run.open => Outcome::NotOpen,
            Some(run) => {
                run.msgs_in += 1;
                let n_new = tasks.len();
                let res = if tasks.is_empty() {
                    Ok(None) // pure close / keep-alive
                } else {
                    run.extend(tasks).map(Some)
                };
                match res {
                    Err(e) => Outcome::Invalid(e.to_string()),
                    Ok(plan) => {
                        if last {
                            run.open = false;
                            run.closed = true;
                        }
                        run.msgs_out += 1; // the graph-submitted ack below
                        Outcome::Extended { plan, n_total: run.graph.len() as u64, n_new }
                    }
                }
            }
        };
        match outcome {
            Outcome::Unknown => {
                // Retired, failed or never-existed: the client's view of
                // the run is stale — tell it so instead of silently eating
                // tasks it believes queued.
                out.push((
                    Dest::Client(client),
                    Msg::GraphFailed {
                        run: run_id,
                        reason: format!("cannot extend unknown or retired run {run_id}"),
                    },
                ));
            }
            Outcome::Foreign => {
                log::warn!("client {client} tried to extend foreign {run_id}; ignored");
            }
            Outcome::NotOpen => {
                // Extending a closed run is fatal protocol misuse: the
                // client has committed ids past the close.
                self.fail_run(run_id, format!("{run_id} is not open for extension"), out);
            }
            Outcome::Invalid(e) => {
                // The rejected graft left nothing mutated server-side, but
                // the two ends now permanently disagree on the id space —
                // the run dies rather than limping on misaligned.
                self.fail_run(run_id, format!("invalid extension: {e}"), out);
            }
            Outcome::Extended { plan, n_total, n_new } => {
                out.push((
                    Dest::Client(client),
                    Msg::GraphSubmitted { run: run_id, n_tasks: n_total },
                ));
                if let Some(plan) = plan {
                    self.charge(self.profile.task_transition_us * n_new as f64 * 0.2);
                    // Raise store refcounts on every holder of a resident
                    // finished input *before* any new assignment can race
                    // its self-eviction.
                    let mut pins: Vec<(WorkerId, TaskId, u32)> = Vec::new();
                    {
                        let run = self.runs.get_mut(&run_id).expect("live run");
                        for &(task, delta) in &plan.pin {
                            for w in run.who_has[task.idx()].iter() {
                                pins.push((w, task, delta));
                            }
                        }
                        run.msgs_out += pins.len() as u64;
                    }
                    for (w, task, consumers) in pins {
                        self.park(
                            run_id,
                            w,
                            Parked::Wire(Msg::PinData { run: run_id, task, consumers }),
                        );
                    }
                    {
                        let run = self.runs.get(&run_id).expect("live run");
                        let sched = self.pool.get(run_id).expect("scheduler for live run");
                        sched.graph_extended(&run.graph);
                        if !plan.ready.is_empty() {
                            sched.tasks_ready(&plan.ready, &mut self.actions_buf);
                        }
                    }
                    self.flush_actions(run_id, out);
                }
                self.maybe_complete(run_id, out);
            }
        }
    }

    /// Activate parked submissions whose client has fallen below its
    /// live-run cap, in FIFO order (entries of still-capped clients are
    /// skipped, not blocking others). Called once per inbound event /
    /// disconnect, after all other processing — retirement is the only
    /// thing that frees capacity, and it only happens inside those.
    fn admit_from_queue(&mut self, out: &mut Vec<(Dest, Msg)>) {
        // Hot-path guard: this runs after *every* inbound event; with no
        // parked submissions (the overwhelmingly common case) it must cost
        // one branch, not a scan over the live runs.
        if self.admission.is_empty() {
            return;
        }
        // Per-client live counts, built once and maintained across the
        // activations below — not recomputed per parked entry.
        let mut live: HashMap<u32, usize> = HashMap::new();
        for r in self.runs.values() {
            *live.entry(r.client).or_insert(0) += 1;
        }
        loop {
            let picked = self.admission.iter().position(|p| {
                live.get(&p.client).copied().unwrap_or(0) < self.max_live_per_client
            });
            let Some(i) = picked else { return };
            let p = self.admission.remove(i).expect("index from position");
            let client = p.client;
            out.push((
                Dest::Client(client),
                Msg::GraphSubmitted { run: p.run, n_tasks: p.graph.len() as u64 },
            ));
            // run-queued + graph-submitted = 2 acks so far. An activated
            // empty graph completes inside `activate_run`, freeing
            // capacity again — re-sync this client's count from the truth
            // (only its own runs can have changed), so a chain of parked
            // trivial runs drains without recursion.
            self.activate_run(p, 2, out);
            live.insert(
                client,
                self.runs.values().filter(|r| r.client == client).count(),
            );
        }
    }

    /// Translate one run's scheduler actions into protocol messages:
    /// state transitions apply here (synchronously, so the scheduler's
    /// model and `GraphRun` never diverge), but the messages are *parked*
    /// on the run's outbox for [`Reactor::pump`] to emit in fairness
    /// order. Iterates because a rejected steal feeds back into the
    /// scheduler which may emit more actions; bounded since every round
    /// retires at least one action.
    fn flush_actions(&mut self, run_id: RunId, out: &mut Vec<(Dest, Msg)>) {
        let mut rounds = 0;
        while !self.actions_buf.is_empty() {
            rounds += 1;
            if rounds >= 10_000 {
                // Convergence is an invariant (every round retires an
                // action); if it breaks, dropping the remainder desyncs
                // this run's scheduler but keeps the server alive, which
                // beats the silent infinite loop a compiled-out assert
                // would leave behind.
                debug_assert!(rounds < 10_000, "steal feedback failed to converge");
                log::error!(
                    "steal feedback for {run_id} failed to converge; dropping {} scheduler action(s)",
                    self.actions_buf.len()
                );
                self.actions_buf.clear();
                return;
            }
            // Charge the scheduler's algorithmic work at the profile's
            // rates (GIL: burns reactor time inline, exactly like CPython).
            let (cost, kind) = match self.pool.get(run_id) {
                Some(s) => (s.take_cost(), s.kind()),
                None => {
                    self.actions_buf.clear();
                    return;
                }
            };
            self.charge(cost.to_us(&self.profile, kind));

            let actions = std::mem::take(&mut self.actions_buf);
            for action in &actions {
                match *action {
                    Action::Assign(a) => {
                        // Schedulers ARE told about disconnects (the pool
                        // propagates `remove_worker` to every live
                        // scheduler before recovery re-seeds it), so an
                        // assignment to a dead worker here is a scheduler
                        // model bug — fail the run fast instead of
                        // stranding it on a connection nobody holds.
                        let connected = self
                            .workers
                            .get(a.worker.idx())
                            .map(|w| w.connected)
                            .unwrap_or(false);
                        if !connected {
                            // Clear leftover feedback actions *before*
                            // failing: `fail_run` may activate a parked
                            // submission whose own actions land in the
                            // same shared buffer.
                            self.actions_buf.clear();
                            self.fail_run(
                                run_id,
                                format!(
                                    "scheduler assigned {} to disconnected worker {}",
                                    a.task, a.worker
                                ),
                                out,
                            );
                            return;
                        }
                        {
                            let run =
                                self.runs.get_mut(&run_id).expect("assign for dead run");
                            run.states[a.task.idx()] = TaskState::Assigned(a.worker);
                            run.priorities[a.task.idx()] = a.priority;
                            run.msgs_out += 1;
                        }
                        self.charge(self.profile.task_transition_us);
                        // Ids only; the message is resolved (and, over TCP,
                        // encoded without allocating) at emission.
                        self.park(
                            run_id,
                            a.worker,
                            Parked::Compute { task: a.task, priority: a.priority },
                        );
                    }
                    Action::Steal { task, from, to } => {
                        // Only steal tasks still assigned; scheduler models
                        // can lag one event behind.
                        let stealable = {
                            let run =
                                self.runs.get_mut(&run_id).expect("steal for dead run");
                            if run.states[task.idx()] == TaskState::Assigned(from) {
                                run.states[task.idx()] = TaskState::Stealing { from, to };
                                run.steals_attempted += 1;
                                run.msgs_out += 1;
                                true
                            } else {
                                false
                            }
                        };
                        if stealable {
                            self.charge(self.profile.task_transition_us);
                            self.park(
                                run_id,
                                from,
                                Parked::Wire(Msg::StealRequest { run: run_id, task }),
                            );
                        } else {
                            // Already finished/stolen — report as failed.
                            let mut buf = Vec::new();
                            self.pool
                                .get(run_id)
                                .expect("scheduler for live run")
                                .steal_result(task, from, to, false, &mut buf);
                            self.actions_buf.extend(buf);
                        }
                    }
                }
            }
        }
    }

    /// Feed one inbound message; outbound messages are appended to `out`.
    ///
    /// Client-facing notices (acks, completion, failure) are appended
    /// directly; worker-bound messages are parked on their run's outbox —
    /// call [`Reactor::pump`] (transport loop) or [`Reactor::drain`]
    /// (tests, single-shot tools) to emit them in fairness order.
    pub fn on_message(&mut self, from: Origin, msg: Msg, out: &mut Vec<(Dest, Msg)>) {
        self.handle_message(from, msg, out);
        // A message can retire runs (completion, task error, unknown
        // scheduler); retired runs free admission capacity. Top-level so
        // activation never re-enters mid-iteration state.
        self.admit_from_queue(out);
    }

    fn handle_message(&mut self, from: Origin, msg: Msg, out: &mut Vec<(Dest, Msg)>) {
        self.charge_msg(128);
        match (from, msg) {
            (Origin::Unregistered { .. }, Msg::RegisterClient { .. }) => {
                let id = match &self.shared_ids {
                    Some(ids) => {
                        ids.next_client.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                    }
                    None => self.n_clients,
                };
                // Local count tracks the high-water mark either way
                // (introspection only; never used for allocation when ids
                // are shared).
                self.n_clients = self.n_clients.max(id.saturating_add(1));
                out.push((Dest::Client(id), Msg::Welcome { id }));
            }
            (Origin::Unregistered { .. }, Msg::RegisterWorker { ncores, node, data_addr, .. }) => {
                let id = match &self.shared_ids {
                    Some(ids) => WorkerId(
                        ids.next_worker.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
                    ),
                    None => WorkerId(self.workers.len() as u32),
                };
                let info = WorkerInfo { id, ncores, node };
                self.ensure_worker_slot(id.idx());
                self.workers[id.idx()] = WorkerMeta { info, connected: true };
                self.worker_addrs[id.idx()] = data_addr;
                self.pool.add_worker(info);
                out.push((Dest::Worker(id), Msg::Welcome { id: id.0 }));
            }
            (Origin::Client(client), Msg::SubmitGraph { graph, scheduler, open }) => {
                let run_id = self.run_ids.allocate();
                let n_tasks = graph.len() as u64;
                // Per-run scheduler choice: an unknown name fails this run
                // now — before it can be parked — so deferred activation
                // can never fail (ack + failure so the client matches it
                // up); other runs and the server itself are unaffected.
                if let Some(name) = scheduler.as_deref() {
                    if !SchedulerPool::is_known(name) {
                        out.push((
                            Dest::Client(client),
                            Msg::GraphSubmitted { run: run_id, n_tasks },
                        ));
                        out.push((
                            Dest::Client(client),
                            Msg::GraphFailed {
                                run: run_id,
                                reason: format!("unknown scheduler {name:?}"),
                            },
                        ));
                        return;
                    }
                }
                // Admission control: cap live runs per client; excess
                // submissions park FIFO and activate as runs retire. The
                // parked ack is `run-queued` so the client can tell the
                // phases apart; `graph-submitted` follows at activation.
                let live = self.runs.values().filter(|r| r.client == client).count();
                if live >= self.max_live_per_client {
                    // The queue itself is bounded too, or a runaway
                    // submitter would just move its unbounded state from
                    // live runs into parked graphs.
                    let queued =
                        self.admission.iter().filter(|p| p.client == client).count();
                    if queued >= self.max_queued_per_client {
                        out.push((
                            Dest::Client(client),
                            Msg::GraphSubmitted { run: run_id, n_tasks },
                        ));
                        out.push((
                            Dest::Client(client),
                            Msg::GraphFailed {
                                run: run_id,
                                reason: format!(
                                    "admission queue full ({queued} submissions parked)"
                                ),
                            },
                        ));
                        return;
                    }
                    // `position` counts THIS client's queued submissions
                    // ahead (activation skips capped clients, so the
                    // global queue length would mostly reflect other
                    // tenants' backlogs).
                    out.push((
                        Dest::Client(client),
                        Msg::RunQueued { run: run_id, position: queued as u64 },
                    ));
                    self.admission.push_back(ParkedRun {
                        run: run_id,
                        client,
                        graph,
                        scheduler,
                        submitted_at_us: self.clock.elapsed_us(),
                        open,
                    });
                    return;
                }
                out.push((Dest::Client(client), Msg::GraphSubmitted { run: run_id, n_tasks }));
                let now = self.clock.elapsed_us();
                self.activate_run(
                    ParkedRun { run: run_id, client, graph, scheduler, submitted_at_us: now, open },
                    1,
                    out,
                );
            }
            (Origin::Client(client), Msg::SubmitExtend { run: run_id, tasks, last }) => {
                self.handle_extend(client, run_id, tasks, last, out);
            }
            (Origin::Worker(worker), Msg::TaskFinished(info)) => {
                self.charge(self.profile.task_transition_us);
                let (newly_ready, replicate, pin_delta) = {
                    let Some(run) = self.runs.get_mut(&info.run) else { return };
                    if info.task.idx() >= run.graph.len() {
                        log::warn!("task-finished for out-of-range {} in {}", info.task, info.run);
                        return;
                    }
                    run.msgs_in += 1;
                    let first_copy =
                        !matches!(run.states[info.task.idx()], TaskState::Finished(_));
                    let newly_ready = run.finish(info.task, worker);
                    // Proactive k-replication: on the FIRST finish of a
                    // hint-flagged output, tell the producer to push copies
                    // to k-1 deterministic peers (duplicate finishes from
                    // recovery races must not re-trigger the push).
                    let replicate = if first_copy
                        && self.replication > 1
                        && run.replicate_hint.get(info.task.idx()).copied().unwrap_or(false)
                    {
                        replica_targets(
                            &self.workers,
                            &self.worker_addrs,
                            &run.who_has[info.task.idx()],
                            worker,
                            self.replication - 1,
                        )
                    } else {
                        Vec::new()
                    };
                    if !replicate.is_empty() {
                        run.msgs_out += 1;
                    }
                    // A graph extension raised this output's consumer count
                    // after its compute-task was emitted with the smaller
                    // one: deliver the gap as a `pin-data` refcount delta
                    // now that the producer's store holds the bytes.
                    let pin_delta = {
                        let told = run.emitted_consumers[info.task.idx()];
                        let now = run.graph.consumers(info.task).len() as u32;
                        if first_copy && told != GraphRun::NEVER_EMITTED && now > told {
                            run.emitted_consumers[info.task.idx()] = now;
                            run.msgs_out += 1;
                            Some(now - told)
                        } else {
                            None
                        }
                    };
                    (newly_ready, replicate, pin_delta)
                };
                if let Some(consumers) = pin_delta {
                    self.park(
                        info.run,
                        worker,
                        Parked::Wire(Msg::PinData { run: info.run, task: info.task, consumers }),
                    );
                }
                if !replicate.is_empty() {
                    self.park(
                        info.run,
                        worker,
                        Parked::Wire(Msg::ReplicateData {
                            run: info.run,
                            task: info.task,
                            addrs: replicate,
                        }),
                    );
                }
                if !newly_ready.is_empty() {
                    self.charge(self.profile.task_transition_us * newly_ready.len() as f64);
                }
                {
                    let Some(sched) = self.pool.get(info.run) else { return };
                    sched.task_finished(
                        info.task,
                        worker,
                        info.nbytes,
                        info.duration_us,
                        &mut self.actions_buf,
                    );
                    if !newly_ready.is_empty() {
                        sched.tasks_ready(&newly_ready, &mut self.actions_buf);
                    }
                }
                self.flush_actions(info.run, out);
                self.maybe_complete(info.run, out);
            }
            (Origin::Worker(worker), Msg::StealResponse { run: run_id, task, ok }) => {
                let Some(run) = self.runs.get_mut(&run_id) else { return };
                if task.idx() >= run.graph.len() {
                    return;
                }
                run.msgs_in += 1;
                // A recovery pass dissolved this steal while the response
                // was in flight: the scheduler already heard `failed`, and
                // the task has been reset (and possibly re-assigned) —
                // resolving it again would corrupt the load model. Only
                // the recorded victim's answer is swallowed: a genuine
                // response for a *new* steal of the re-placed task comes
                // from a different worker (or, per-connection FIFO, after
                // this one) and must resolve normally.
                if let Some(n) = run.cancelled_steals.get_mut(&(task, worker)) {
                    *n -= 1;
                    if *n == 0 {
                        run.cancelled_steals.remove(&(task, worker));
                    }
                    return;
                }
                match run.states[task.idx()] {
                    TaskState::Stealing { from, to } => {
                        if from != worker {
                            // Only the recorded victim may resolve the
                            // steal; accepting a foreign answer would
                            // corrupt the load model (see above). The
                            // swallow table already consumed every known
                            // stale answer, so this is an invariant break.
                            debug_assert_eq!(from, worker, "steal response from non-victim");
                            log::error!(
                                "ignoring steal response for {run_id}/{task:?} from {worker:?} (victim is {from:?})"
                            );
                            return;
                        }
                        if ok {
                            // Retracted: the victim has given the task up.
                            // Reassign to the steal target with the same
                            // scheduler-chosen priority — unless the target
                            // died while the retraction was in flight, in
                            // which case re-land it on the (live) victim
                            // rather than stranding the run on a dead
                            // worker whose messages go nowhere.
                            let to_alive = self
                                .workers
                                .get(to.idx())
                                .map(|m| m.connected)
                                .unwrap_or(false);
                            let target = if to_alive { to } else { from };
                            run.states[task.idx()] = TaskState::Assigned(target);
                            run.msgs_out += 1;
                            if !to_alive {
                                run.steals_failed += 1;
                            }
                            let priority = run.priorities[task.idx()];
                            self.pool
                                .get(run_id)
                                .expect("scheduler for live run")
                                .steal_result(task, from, to, to_alive, &mut self.actions_buf);
                            self.charge(self.profile.task_transition_us);
                            self.park(run_id, target, Parked::Compute { task, priority });
                        } else {
                            run.steals_failed += 1;
                            run.states[task.idx()] = TaskState::Assigned(from);
                            self.pool
                                .get(run_id)
                                .expect("scheduler for live run")
                                .steal_result(task, from, to, false, &mut self.actions_buf);
                        }
                    }
                    _ => {
                        // The finish beat the retraction across connections.
                        // Report the steal's *real* endpoints (recorded by
                        // `GraphRun::finish` before the state was
                        // overwritten), not `(worker, worker)` — otherwise
                        // the scheduler's optimistic-move undo is a no-op
                        // and its load model drifts.
                        let (from, to) =
                            run.raced_steals.remove(&task).unwrap_or((worker, worker));
                        run.steals_failed += 1;
                        self.pool
                            .get(run_id)
                            .expect("scheduler for live run")
                            .steal_result(task, from, to, false, &mut self.actions_buf);
                    }
                }
                self.flush_actions(run_id, out);
            }
            (Origin::Worker(worker), Msg::TaskErred { run: run_id, task, error }) => {
                enum ErrAction {
                    Ignore,
                    /// Re-run the task; `Some((from, to))` if an in-flight
                    /// steal must be dissolved first.
                    Retry(Option<(WorkerId, WorkerId)>),
                    Fail(String),
                }
                let act = {
                    let Some(run) = self.runs.get_mut(&run_id) else { return };
                    if task.idx() >= run.graph.len() {
                        ErrAction::Fail(format!("task {task} erred: {error}"))
                    } else {
                        run.msgs_in += 1;
                        let state = run.states[task.idx()];
                        let responsible = matches!(state, TaskState::Assigned(w) if w == worker)
                            || matches!(state, TaskState::Stealing { from, .. } if from == worker);
                        if !responsible {
                            // A recovery pass already reset (or re-placed)
                            // this task; the error comes from a cancelled
                            // copy — the re-run supersedes it.
                            log::debug!(
                                "{run_id}: stale task-erred for {task} from {worker}; ignored"
                            );
                            ErrAction::Ignore
                        } else if error.starts_with(FETCH_FAILED_PREFIX)
                            && run.fetch_retries.get(&task).copied().unwrap_or(0)
                                < MAX_FETCH_RETRIES
                        {
                            // An input fetch failed — a peer died or the
                            // advertised address went stale mid-recovery.
                            // Re-run the task instead of aborting: lineage
                            // recovery (already done or about to happen
                            // when the peer's disconnect lands) restores
                            // the inputs. Bounded by the per-task retry cap.
                            *run.fetch_retries.entry(task).or_insert(0) += 1;
                            let steal = if let TaskState::Stealing { from, to } = state {
                                *run.cancelled_steals.entry((task, from)).or_insert(0) += 1;
                                run.steals_failed += 1;
                                Some((from, to))
                            } else {
                                None
                            };
                            run.states[task.idx()] = TaskState::Ready;
                            ErrAction::Retry(steal)
                        } else {
                            ErrAction::Fail(format!(
                                "task {} ({}) erred: {error}",
                                task,
                                run.graph.task(task).key
                            ))
                        }
                    }
                };
                match act {
                    ErrAction::Ignore => {}
                    ErrAction::Fail(reason) => self.fail_run(run_id, reason, out),
                    ErrAction::Retry(steal) => {
                        // The retry may be doomed: if every replica of an
                        // input evaporated (self-evicted after the address
                        // was resolved), re-running would hit the same
                        // fetch failure. Resurrect lost lineage first; if
                        // that pushed the task back to Waiting, readiness
                        // re-offers it once the inputs exist again.
                        let (resurrected, task_ready) = {
                            let run = self.runs.get_mut(&run_id).expect("live run");
                            let res = run.resurrect_missing_inputs(task);
                            (res, run.states[task.idx()] == TaskState::Ready)
                        };
                        {
                            let sched =
                                self.pool.get(run_id).expect("scheduler for live run");
                            sched.task_lost(task, worker);
                            if let Some((from, to)) = steal {
                                sched.steal_result(task, from, to, false, &mut self.actions_buf);
                            }
                            if !resurrected.is_empty() {
                                sched.tasks_ready(&resurrected, &mut self.actions_buf);
                            }
                            if task_ready {
                                sched.tasks_ready(&[task], &mut self.actions_buf);
                            }
                        }
                        self.flush_actions(run_id, out);
                    }
                }
            }
            (Origin::Worker(worker), Msg::ReplicaAdded { run: run_id, task }) => {
                let Some(run) = self.runs.get_mut(&run_id) else { return };
                if task.idx() >= run.graph.len() {
                    return;
                }
                run.msgs_in += 1;
                // Only while the output is still finished: a recovery pass
                // may have resurrected the task mid-push, making this copy
                // stale (the run's release broadcast reclaims it).
                if matches!(run.states[task.idx()], TaskState::Finished(_))
                    && !run.who_has[task.idx()].contains(worker)
                {
                    run.who_has[task.idx()].push(worker);
                }
            }
            (Origin::Worker(worker), Msg::ReplicaDropped { run: run_id, task }) => {
                // A store self-evicted its copy (all local consumers done)
                // or spilled state died with a release; the address must
                // leave `who_has` or later assignments would fetch from a
                // worker that will answer `fetch-failed`.
                let Some(run) = self.runs.get_mut(&run_id) else { return };
                if task.idx() >= run.graph.len() {
                    return;
                }
                run.msgs_in += 1;
                run.who_has[task.idx()].retain(|w| w != worker);
            }
            (Origin::Worker(w), Msg::DataToServer { .. }) => {
                // Zero-worker data fetches terminate here (mock payloads).
                let _ = w;
            }
            (_, Msg::Heartbeat) => {}
            (from, msg) => {
                log::warn!("reactor: unexpected {op:?} from {from:?}", op = msg.op());
            }
        }
    }

    /// A registered peer disconnected.
    pub fn on_disconnect(&mut self, origin: Origin, out: &mut Vec<(Dest, Msg)>) {
        self.handle_disconnect(origin, out);
        // A disconnect can retire runs (budget exhaustion, orphaning),
        // freeing admission capacity.
        self.admit_from_queue(out);
    }

    fn handle_disconnect(&mut self, origin: Origin, out: &mut Vec<(Dest, Msg)>) {
        match origin {
            Origin::Worker(w) => {
                if let Some(meta) = self.workers.get_mut(w.idx()) {
                    meta.connected = false;
                }
                // Drop the worker from the pool's replay list AND from
                // every live scheduler's model — recovery re-places the
                // lost tasks through the normal `tasks_ready` path, so
                // placement must already have forgotten the corpse.
                self.pool.remove_worker(w);
                // Dead-letter steal markers: answers from this worker can
                // no longer arrive, on ANY run — a run can hold a marker
                // without otherwise involving the worker (its last steal
                // was already dissolved), so purge everywhere, not just in
                // the affected runs' `recover()` passes.
                for run in self.runs.values_mut() {
                    run.cancelled_steals.retain(|&(_, victim), _| victim != w);
                    // Parked messages bound for the corpse would be dropped
                    // by the transport anyway (no connection); purge them so
                    // pump rounds aren't wasted emitting dead letters.
                    // Live-bound parked messages stay: recovery's dissolve
                    // bookkeeping assumes a parked steal-request WILL reach
                    // its live victim and be answered.
                    run.outbox.retain(|&(to, _)| to != w);
                }
                // Repair exactly the runs that depend on this worker
                // (assigned tasks, in-flight steals or stored outputs) by
                // lineage recovery; unrelated runs are untouched. Past the
                // per-run recovery budget — or with no workers left — the
                // run fails as it did before recovery existed.
                let affected: Vec<RunId> = self
                    .runs
                    .iter()
                    .filter_map(|(&id, r)| r.involves_worker(w).then_some(id))
                    .collect();
                let no_capacity = self.n_workers() == 0;
                for run_id in affected {
                    let plan = if no_capacity {
                        None
                    } else {
                        self.runs.get_mut(&run_id).expect("live run").recover(w)
                    };
                    let Some(plan) = plan else {
                        let reason = if no_capacity {
                            format!("worker {w} disconnected and no workers remain")
                        } else {
                            // The shared needle opt-in clients match on to
                            // resubmit (`Client::with_retry_exhausted`).
                            format!("worker {w} disconnected; {RECOVERY_EXHAUSTED_REASON}")
                        };
                        self.fail_run(run_id, reason, out);
                        continue;
                    };
                    if plan.is_trivial() {
                        continue; // survivors hold replicas of everything
                    }
                    self.charge(
                        self.profile.task_transition_us
                            * (plan.lost_assignments.len() + plan.resurrected.len()) as f64,
                    );
                    {
                        let sched = self.pool.get(run_id).expect("scheduler for live run");
                        for &(task, worker) in &plan.lost_assignments {
                            sched.task_lost(task, worker);
                        }
                        for &(task, from, to) in &plan.dissolved_steals {
                            sched.steal_result(task, from, to, false, &mut self.actions_buf);
                        }
                    }
                    {
                        let run = self.runs.get_mut(&run_id).expect("live run");
                        run.steals_failed += plan.dissolved_steals.len() as u64;
                        run.msgs_out += plan.cancel.len() as u64;
                    }
                    for &(worker, task) in &plan.cancel {
                        let connected = self
                            .workers
                            .get(worker.idx())
                            .map(|m| m.connected)
                            .unwrap_or(false);
                        if connected {
                            // Parked, not pushed: the cancel must stay
                            // FIFO-ordered with this run's earlier compute
                            // messages (a cancel overtaking the compute it
                            // cancels would re-queue the task for good).
                            self.park(
                                run_id,
                                worker,
                                Parked::Wire(Msg::CancelCompute { run: run_id, task }),
                            );
                        }
                    }
                    if !plan.ready.is_empty() {
                        self.pool
                            .get(run_id)
                            .expect("scheduler for live run")
                            .tasks_ready(&plan.ready, &mut self.actions_buf);
                    }
                    self.flush_actions(run_id, out);
                }
            }
            Origin::Client(c) => {
                // Nobody is waiting for these results any more; reclaim the
                // per-run scheduler state AND the workers' per-run state —
                // otherwise an abandoned run keeps executing and its
                // outputs leak on the workers forever. Parked submissions
                // die too: they hold no scheduler/run state yet.
                self.admission.retain(|p| p.client != c);
                let orphaned: Vec<RunId> = self
                    .runs
                    .iter()
                    .filter(|(_, r)| r.client == c)
                    .map(|(&id, _)| id)
                    .collect();
                for run_id in orphaned {
                    self.pool.remove(run_id);
                    self.runs.remove(&run_id);
                    self.release_run(run_id, out);
                }
            }
            Origin::Unregistered { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{merge, tree};
    use crate::overhead::SchedKind;
    use crate::protocol::TaskFinishedInfo;
    use crate::scheduler::{Assignment, SchedCost};
    use crate::taskgraph::TaskGraph;
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    fn reactor(sched: &str) -> Reactor {
        Reactor::new(
            SchedulerPool::new(sched, 42).unwrap(),
            RuntimeProfile::rust(),
            false,
        )
    }

    fn register(r: &mut Reactor, n_clients: u32, n_workers: u32) -> Vec<(Dest, Msg)> {
        let mut out = Vec::new();
        for c in 0..n_clients {
            r.on_message(
                Origin::Unregistered { conn: c as u64 },
                Msg::RegisterClient { name: format!("c{c}") },
                &mut out,
            );
        }
        for i in 0..n_workers {
            r.on_message(
                Origin::Unregistered { conn: 100 + i as u64 },
                Msg::RegisterWorker {
                    name: format!("w{i}"),
                    ncores: 1,
                    node: i / 24,
                    data_addr: format!("127.0.0.1:{}", 9000 + i),
                },
                &mut out,
            );
        }
        out
    }

    /// Recover the worker id behind a registered data address (the
    /// `register` helper assigns `127.0.0.1:{9000+i}` to worker `i`).
    fn worker_of_addr(addr: &str) -> WorkerId {
        let port: u32 = addr.rsplit(':').next().unwrap().parse().unwrap();
        WorkerId(port - 9000)
    }

    /// Drive one or more graphs to completion with instantly-finishing fake
    /// workers, interleaving the per-worker FIFO streams round-robin so
    /// concurrent runs' `TaskFinished` messages arrive interleaved.
    /// Returns (completed runs, per-(run,worker) executed counts).
    fn drive_many(
        r: &mut Reactor,
        submissions: Vec<(u32, TaskGraph)>,
    ) -> (HashMap<RunId, (u32, u64)>, HashMap<(RunId, WorkerId), u64>) {
        let mut out = Vec::new();
        let n_graphs = submissions.len();
        for (client, graph) in submissions {
            r.on_message(
                Origin::Client(client),
                Msg::SubmitGraph { graph, scheduler: None, open: false },
                &mut out,
            );
        }
        let mut executed: HashMap<(RunId, WorkerId), u64> = HashMap::new();
        let mut done: HashMap<RunId, (u32, u64)> = HashMap::new();
        // Worker inboxes: FIFO per worker, like a TCP stream.
        let mut inboxes: HashMap<WorkerId, Vec<Msg>> = HashMap::new();
        let mut rr: Vec<WorkerId> = Vec::new();
        let mut guard = 0u64;
        loop {
            guard += 1;
            assert!(guard < 10_000_000, "drive loop stuck");
            r.drain(&mut out); // emit parked worker-bound messages
            for (dest, msg) in std::mem::take(&mut out) {
                match dest {
                    Dest::Worker(w) => {
                        if !rr.contains(&w) {
                            rr.push(w);
                        }
                        inboxes.entry(w).or_default().push(msg);
                    }
                    Dest::Client(c) => {
                        if let Msg::GraphDone { run, n_tasks, .. } = msg {
                            done.insert(run, (c, n_tasks));
                        } else if let Msg::GraphFailed { reason, .. } = msg {
                            panic!("graph failed: {reason}");
                        }
                    }
                }
            }
            // Round-robin across workers, one message each, so messages of
            // concurrent runs interleave at the reactor.
            let Some(&w) = rr
                .iter()
                .find(|w| inboxes.get(w).map(|q| !q.is_empty()).unwrap_or(false))
            else {
                break;
            };
            rr.rotate_left(1);
            let msg = inboxes.get_mut(&w).unwrap().remove(0);
            match msg {
                Msg::ComputeTask { run, task, output_size, .. } => {
                    *executed.entry((run, w)).or_default() += 1;
                    r.on_message(
                        Origin::Worker(w),
                        Msg::TaskFinished(TaskFinishedInfo {
                            run,
                            task,
                            nbytes: output_size,
                            duration_us: 1,
                        }),
                        &mut out,
                    );
                }
                Msg::StealRequest { run, task } => {
                    // Fake worker: always retractable.
                    r.on_message(
                        Origin::Worker(w),
                        Msg::StealResponse { run, task, ok: true },
                        &mut out,
                    );
                }
                Msg::ReplicateData { run, task, addrs } => {
                    // Fake replica push: each target acks straight away.
                    for a in &addrs {
                        r.on_message(
                            Origin::Worker(worker_of_addr(a)),
                            Msg::ReplicaAdded { run, task },
                            &mut out,
                        );
                    }
                }
                Msg::Welcome { .. } | Msg::ReleaseRun { .. } | Msg::PinData { .. } => {}
                other => panic!("worker got {other:?}"),
            }
            if done.len() == n_graphs
                && inboxes.values().all(|q| q.is_empty())
                && out.is_empty()
            {
                break;
            }
        }
        assert_eq!(done.len(), n_graphs, "all graphs must complete");
        (done, executed)
    }

    fn drive(r: &mut Reactor, graph: TaskGraph) -> (ReactorReport, HashMap<WorkerId, u64>) {
        let (_, executed) = drive_many(r, vec![(0, graph)]);
        let by_worker = executed
            .into_iter()
            .fold(HashMap::new(), |mut acc: HashMap<WorkerId, u64>, ((_, w), n)| {
                *acc.entry(w).or_default() += n;
                acc
            });
        (r.reports().last().unwrap().clone(), by_worker)
    }

    #[test]
    fn registration_assigns_ids() {
        let mut r = reactor("random");
        let out = register(&mut r, 1, 3);
        let welcomes: Vec<_> = out
            .iter()
            .filter(|(d, _)| matches!(d, Dest::Worker(_)))
            .collect();
        assert_eq!(welcomes.len(), 3);
        assert_eq!(r.n_workers(), 3);
    }

    #[test]
    fn merge_runs_to_completion_random() {
        let mut r = reactor("random");
        register(&mut r, 1, 4);
        let (report, executed) = drive(&mut r, merge(200));
        assert_eq!(report.n_tasks, 201);
        assert_eq!(executed.values().sum::<u64>(), 201);
        // Random spread: every worker got something.
        assert_eq!(executed.len(), 4);
    }

    #[test]
    fn merge_runs_to_completion_ws() {
        let mut r = reactor("ws");
        register(&mut r, 1, 4);
        let (report, executed) = drive(&mut r, merge(200));
        assert_eq!(executed.values().sum::<u64>(), 201);
        assert_eq!(report.n_tasks, 201);
    }

    #[test]
    fn tree_respects_dependencies() {
        // The fake worker finishes instantly, so correctness = completion:
        // a dependency violation would deadlock or panic dep counting.
        for sched in ["random", "ws", "dask-ws"] {
            let mut r = reactor(sched);
            register(&mut r, 1, 6);
            let (report, executed) = drive(&mut r, tree(7));
            assert_eq!(report.n_tasks, 127, "{sched}");
            assert_eq!(executed.values().sum::<u64>(), 127, "{sched}");
        }
    }

    #[test]
    fn sequential_graphs_reuse_cluster() {
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let (r1, _) = drive(&mut r, merge(50));
        let (r2, _) = drive(&mut r, tree(5));
        assert_eq!(r1.n_tasks, 51);
        assert_eq!(r2.n_tasks, 31);
        assert_eq!(r.reports().len(), 2);
        // Distinct RunIds even for sequential submissions.
        assert_ne!(r.reports()[0].run, r.reports()[1].run);
    }

    #[test]
    fn two_clients_run_concurrently_interleaved() {
        // The multi-graph acceptance scenario: two clients submit before
        // any task finishes; their TaskFinished streams interleave; both
        // complete with correct per-run reports.
        for sched in ["random", "ws", "dask-ws"] {
            let mut r = reactor(sched);
            register(&mut r, 2, 4);
            let (done, executed) = drive_many(&mut r, vec![(0, merge(120)), (1, tree(6))]);
            assert_eq!(done.len(), 2, "{sched}");
            assert_eq!(r.live_runs(), 0, "{sched}: all runs retired");
            // Identify runs by task count (merge(120) = 121, tree(6) = 63).
            let mut sizes: Vec<u64> = done.values().map(|&(_, n)| n).collect();
            sizes.sort_unstable();
            assert_eq!(sizes, vec![63, 121], "{sched}");
            for (&run, &(client, n_tasks)) in &done {
                let report = r
                    .reports()
                    .iter()
                    .find(|rep| rep.run == run)
                    .expect("report per run");
                assert_eq!(report.client, client, "{sched}");
                assert_eq!(report.n_tasks, n_tasks, "{sched}");
                assert!(report.msgs_in >= n_tasks, "{sched}: per-run msg accounting");
                let run_exec: u64 = executed
                    .iter()
                    .filter(|((rid, _), _)| *rid == run)
                    .map(|(_, &n)| n)
                    .sum();
                assert_eq!(run_exec, n_tasks, "{sched}: every task of {run} ran once");
            }
            // The two clients got *different* runs reported back.
            let clients: std::collections::HashSet<u32> =
                done.values().map(|&(c, _)| c).collect();
            assert_eq!(clients.len(), 2, "{sched}");
        }
    }

    #[test]
    fn eight_interleaved_graphs_complete() {
        let mut r = reactor("ws");
        register(&mut r, 4, 6);
        let subs: Vec<(u32, TaskGraph)> =
            (0..8u32).map(|i| (i % 4, merge(30 + i as usize))).collect();
        let (done, _) = drive_many(&mut r, subs);
        assert_eq!(done.len(), 8);
        assert_eq!(r.reports().len(), 8);
        assert_eq!(r.live_runs(), 0);
    }

    /// Drive to completion with instantly-finishing fake workers, dropping
    /// messages destined to `dead` workers (their sockets are closed).
    /// Returns completed runs; panics on any `GraphFailed`.
    fn drive_until_done(
        r: &mut Reactor,
        mut out: Vec<(Dest, Msg)>,
        dead: &std::collections::HashSet<WorkerId>,
    ) -> HashMap<RunId, (u32, u64)> {
        let mut done = HashMap::new();
        let mut queued: HashMap<WorkerId, Vec<Msg>> = HashMap::new();
        let mut guard = 0u64;
        loop {
            guard += 1;
            assert!(guard < 1_000_000, "drive stuck");
            r.drain(&mut out); // emit parked worker-bound messages
            for (dest, msg) in std::mem::take(&mut out) {
                match dest {
                    Dest::Worker(w) if dead.contains(&w) => {} // socket closed
                    Dest::Worker(w) => queued.entry(w).or_default().push(msg),
                    Dest::Client(c) => match msg {
                        Msg::GraphDone { run, n_tasks, .. } => {
                            done.insert(run, (c, n_tasks));
                        }
                        Msg::GraphFailed { reason, .. } => panic!("graph failed: {reason}"),
                        _ => {}
                    },
                }
            }
            let Some((&w, _)) = queued
                .iter()
                .find(|(w, q)| !dead.contains(w) && !q.is_empty())
            else {
                break;
            };
            let msg = queued.get_mut(&w).unwrap().remove(0);
            match msg {
                Msg::ComputeTask { run, task, output_size, .. } => r.on_message(
                    Origin::Worker(w),
                    Msg::TaskFinished(TaskFinishedInfo {
                        run,
                        task,
                        nbytes: output_size,
                        duration_us: 1,
                    }),
                    &mut out,
                ),
                Msg::StealRequest { run, task } => r.on_message(
                    Origin::Worker(w),
                    Msg::StealResponse { run, task, ok: true },
                    &mut out,
                ),
                Msg::CancelCompute { .. } => {
                    // This fake executes every compute message the instant
                    // it is delivered, so a cancel never finds a queued
                    // copy — everything still in `queued` was sent *after*
                    // the cancel (FIFO) and must not be dropped. The early
                    // finish of the cancelled copy is accepted upstream and
                    // the re-sent copy's finish is the idempotent duplicate.
                }
                Msg::ReplicateData { run, task, addrs } => {
                    // Replica pushes to dead targets vanish with the socket;
                    // live targets ack straight away.
                    for a in &addrs {
                        let target = worker_of_addr(a);
                        if !dead.contains(&target) {
                            r.on_message(
                                Origin::Worker(target),
                                Msg::ReplicaAdded { run, task },
                                &mut out,
                            );
                        }
                    }
                }
                Msg::Welcome { .. } | Msg::ReleaseRun { .. } | Msg::PinData { .. } => {}
                other => panic!("worker got {other:?}"),
            }
        }
        done
    }

    // ---- lineage recovery (PR 3 tentpole) ----

    #[test]
    fn worker_disconnect_recovers_and_completes() {
        // Kill one of two workers before anything ran: the run must NOT
        // fail — every lost assignment is re-placed on the survivor and
        // the graph completes.
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(10), scheduler: None, open: false },
            &mut out,
        );
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert!(
            !out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { .. })),
            "recovery must not fail the run: {out:?}"
        );
        assert_eq!(r.live_runs(), 1);
        let dead: std::collections::HashSet<WorkerId> = [WorkerId(0)].into();
        let done = drive_until_done(&mut r, out, &dead);
        assert_eq!(done.len(), 1);
        assert_eq!(done.values().next().unwrap().1, 11);
        let report = r.reports().last().unwrap();
        assert_eq!(report.n_tasks, 11);
        assert!(report.recoveries >= 1, "report records the recovery");
    }

    #[test]
    fn disconnect_after_partial_progress_recomputes_lost_outputs() {
        // Let w0 finish some leaves (its outputs live only there), then
        // kill it: the finished-but-lost outputs must be resurrected and
        // the whole graph still completes on w1 with every task finished.
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(6), scheduler: None, open: false },
            &mut out,
        );
        r.drain(&mut out);
        // Pre-kill phase: complete exactly the compute-tasks sent to w0 so
        // far (replies from w0), stash w1's messages for later, and leave
        // every steal retraction unanswered — those responses are "in
        // flight" when the kill lands, exercising the dissolve paths.
        let mut pending: Vec<(Dest, Msg)> = std::mem::take(&mut out);
        let mut w1_inbox: Vec<Msg> = Vec::new();
        let mut finished_on_w0 = 0u64;
        while let Some((dest, msg)) = pending.pop() {
            match (dest, msg) {
                (Dest::Worker(w), Msg::ComputeTask { run, task, output_size, .. })
                    if w == WorkerId(0) =>
                {
                    finished_on_w0 += 1;
                    r.on_message(
                        Origin::Worker(w),
                        Msg::TaskFinished(TaskFinishedInfo {
                            run,
                            task,
                            nbytes: output_size,
                            duration_us: 1,
                        }),
                        &mut out,
                    );
                    r.drain(&mut out);
                    pending.append(&mut out);
                }
                (Dest::Worker(w), m) if w == WorkerId(1) => w1_inbox.push(m),
                _ => {} // w0-bound steals etc.: die with the socket below
            }
        }
        assert!(finished_on_w0 > 0, "w0 must have produced something to lose");
        // Kill w0: its outputs are gone; recovery resurrects them.
        let mut out = Vec::new();
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert_eq!(r.live_runs(), 1, "no failure: {out:?}");
        let run_id = *drive_until_done(
            &mut r,
            w1_inbox
                .into_iter()
                .map(|m| (Dest::Worker(WorkerId(1)), m))
                .chain(out)
                .collect(),
            &[WorkerId(0)].into(),
        )
        .keys()
        .next()
        .expect("graph completes");
        let report = r.reports().iter().find(|rep| rep.run == run_id).unwrap();
        assert_eq!(report.n_tasks, 7);
        assert!(report.recoveries >= 1);
    }

    #[test]
    fn cascading_disconnects_still_complete() {
        // Three workers; kill two at different points. The run absorbs
        // both recoveries and completes on the last survivor.
        let mut r = reactor("ws");
        register(&mut r, 1, 3);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: tree(5), scheduler: None, open: false },
            &mut out,
        );
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert_eq!(r.live_runs(), 1);
        r.on_disconnect(Origin::Worker(WorkerId(1)), &mut out);
        assert_eq!(r.live_runs(), 1);
        let dead: std::collections::HashSet<WorkerId> =
            [WorkerId(0), WorkerId(1)].into();
        let done = drive_until_done(&mut r, out, &dead);
        assert_eq!(done.values().next().unwrap().1, 31);
        assert!(r.reports().last().unwrap().recoveries >= 1);
    }

    #[test]
    fn recovery_cap_exhaustion_fails_run() {
        // Budget 0 restores fail-on-disconnect for non-trivial losses.
        let mut r = reactor("ws").with_max_recoveries(0);
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(10), scheduler: None, open: false },
            &mut out,
        );
        out.clear();
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Client(0)
                && matches!(m, Msg::GraphFailed { reason, .. }
                    if reason.contains("recovery budget"))),
            "exhausted budget must fail the run: {out:?}"
        );
        assert_eq!(r.live_runs(), 0);
    }

    #[test]
    fn last_worker_disconnect_fails_run() {
        // No survivors ⇒ nothing to recover onto.
        let mut r = reactor("ws");
        register(&mut r, 1, 1);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(4), scheduler: None, open: false },
            &mut out,
        );
        out.clear();
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { reason, .. }
                if reason.contains("no workers remain"))),
            "{out:?}"
        );
        assert_eq!(r.live_runs(), 0);
    }

    #[test]
    fn uninvolved_runs_survive_disconnect_untouched() {
        // Two runs; only one placed work on the dead worker (the other
        // is finished already). Recovery must leave the unrelated run and
        // its report alone.
        let mut r = reactor("ws");
        register(&mut r, 2, 2);
        let (done, _) = drive_many(&mut r, vec![(0, merge(8))]);
        assert_eq!(done.len(), 1);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(1),
            Msg::SubmitGraph { graph: merge(6), scheduler: None, open: false },
            &mut out,
        );
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert_eq!(r.live_runs(), 1);
        let done2 = drive_until_done(&mut r, out, &[WorkerId(0)].into());
        assert_eq!(done2.values().next().unwrap(), &(1, 7));
        assert_eq!(r.reports().len(), 2);
    }

    #[test]
    fn fetch_failed_error_requeues_instead_of_failing() {
        use crate::protocol::FETCH_FAILED_PREFIX;
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(5), scheduler: None, open: false },
            &mut out,
        );
        r.drain(&mut out);
        let (run, task, worker) = out
            .iter()
            .find_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { run, task, .. }) => {
                    Some((*run, *task, *w))
                }
                _ => None,
            })
            .expect("an assignment went out");
        out.clear();
        r.on_message(
            Origin::Worker(worker),
            Msg::TaskErred {
                run,
                task,
                error: format!("{FETCH_FAILED_PREFIX}peer gone"),
            },
            &mut out,
        );
        r.drain(&mut out);
        assert_eq!(r.live_runs(), 1, "fetch failure is recoverable: {out:?}");
        // The task went out again.
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::ComputeTask { task: t, .. } if *t == task)),
            "{out:?}"
        );
        // A non-fetch error still fails the run.
        let mut out2 = Vec::new();
        let (run2, task2, worker2) = out
            .iter()
            .find_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { run, task, .. }) => {
                    Some((*run, *task, *w))
                }
                _ => None,
            })
            .unwrap();
        r.on_message(
            Origin::Worker(worker2),
            Msg::TaskErred { run: run2, task: task2, error: "oom".into() },
            &mut out2,
        );
        assert!(
            out2.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { .. })),
            "{out2:?}"
        );
        assert_eq!(r.live_runs(), 0);
    }

    #[test]
    fn task_error_fails_only_its_run() {
        let mut r = reactor("random");
        register(&mut r, 2, 1);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(5), scheduler: None, open: false },
            &mut out,
        );
        r.on_message(
            Origin::Client(1),
            Msg::SubmitGraph { graph: merge(7), scheduler: None, open: false },
            &mut out,
        );
        let runs: Vec<RunId> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::GraphSubmitted { run, .. } => Some(*run),
                _ => None,
            })
            .collect();
        assert_eq!(runs.len(), 2);
        out.clear();
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::TaskErred { run: runs[0], task: TaskId(0), error: "boom".into() },
            &mut out,
        );
        assert!(
            matches!(out[0], (Dest::Client(0), Msg::GraphFailed { run, .. }) if run == runs[0])
        );
        // The other client's run is untouched.
        assert_eq!(r.live_runs(), 1);
        assert!(r.run_state(runs[1]).is_some());
    }

    #[test]
    fn per_run_scheduler_choice() {
        // One server, two concurrent runs on different algorithms: the
        // submission names the scheduler, the pool isolates the instances.
        let mut r = reactor("ws");
        register(&mut r, 2, 3);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(12), scheduler: Some("random".into()), open: false },
            &mut out,
        );
        r.on_message(
            Origin::Client(1),
            Msg::SubmitGraph { graph: merge(9), scheduler: None, open: false },
            &mut out,
        );
        let runs: Vec<RunId> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::GraphSubmitted { run, .. } => Some(*run),
                _ => None,
            })
            .collect();
        assert_eq!(runs.len(), 2);
        assert_eq!(r.scheduler_view(runs[0]).unwrap().name(), "random");
        assert_eq!(r.scheduler_view(runs[1]).unwrap().name(), "ws");
    }

    #[test]
    fn unknown_scheduler_fails_submission_only() {
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(5), scheduler: Some("fifo".into()), open: false },
            &mut out,
        );
        // Ack then failure, both naming the same run; no state leaks.
        let run = out
            .iter()
            .find_map(|(_, m)| match m {
                Msg::GraphSubmitted { run, .. } => Some(*run),
                _ => None,
            })
            .expect("submission is acked");
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Client(0)
                && matches!(m, Msg::GraphFailed { run: r2, reason }
                    if *r2 == run && reason.contains("fifo"))),
            "unknown scheduler must fail the run: {out:?}"
        );
        assert_eq!(r.live_runs(), 0);
        // The server still serves the next (valid) submission.
        out.clear();
        let (done, _) = drive_many(&mut r, vec![(0, merge(6))]);
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn report_counts_messages_and_steals() {
        let mut r = reactor("ws");
        register(&mut r, 1, 4);
        let (report, _) = drive(&mut r, merge(100));
        assert!(report.msgs_in >= 101, "at least one status msg per task");
        assert!(report.msgs_out >= 101, "at least one assignment per task");
        assert!(report.aot_us > 0.0);
    }

    #[test]
    fn completed_run_is_released_on_workers() {
        // Workers key state by (run, task); the server must tell them when
        // a run retires or a long-lived worker leaks every graph.
        let mut r = reactor("ws");
        register(&mut r, 1, 3);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(8), scheduler: None, open: false },
            &mut out,
        );
        let mut release_seen: std::collections::HashSet<WorkerId> =
            std::collections::HashSet::new();
        let mut guard = 0;
        r.drain(&mut out);
        let mut pending: Vec<(Dest, Msg)> = std::mem::take(&mut out);
        while let Some((dest, msg)) = pending.pop() {
            guard += 1;
            assert!(guard < 100_000);
            let Dest::Worker(w) = dest else { continue };
            match msg {
                Msg::ComputeTask { run, task, output_size, .. } => r.on_message(
                    Origin::Worker(w),
                    Msg::TaskFinished(TaskFinishedInfo {
                        run,
                        task,
                        nbytes: output_size,
                        duration_us: 1,
                    }),
                    &mut out,
                ),
                Msg::StealRequest { run, task } => r.on_message(
                    Origin::Worker(w),
                    Msg::StealResponse { run, task, ok: false },
                    &mut out,
                ),
                Msg::ReleaseRun { .. } => {
                    release_seen.insert(w);
                }
                _ => {}
            }
            r.drain(&mut out);
            pending.append(&mut out);
        }
        assert_eq!(r.reports().len(), 1);
        assert_eq!(release_seen.len(), 3, "every connected worker told to release");
    }

    #[test]
    fn stale_messages_for_finished_run_ignored() {
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let (report, _) = drive(&mut r, merge(20));
        let mut out = Vec::new();
        // Late duplicate finish + steal response for the retired run: both
        // must be dropped without panicking or emitting anything.
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::TaskFinished(TaskFinishedInfo {
                run: report.run,
                task: TaskId(3),
                nbytes: 1,
                duration_us: 1,
            }),
            &mut out,
        );
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::StealResponse { run: report.run, task: TaskId(3), ok: false },
            &mut out,
        );
        assert!(out.is_empty());
        assert_eq!(r.reports().len(), 1);
    }

    // ---- raced-steal regression (satellite bugfix #3) ----

    /// Probe scheduler: assigns everything to w0, emits one steal of `victim`
    /// (w0 → w1) on the first finish, and records every `steal_result`.
    struct ProbeSched {
        victim: TaskId,
        stolen: bool,
        results: Arc<Mutex<Vec<(TaskId, WorkerId, WorkerId, bool)>>>,
    }

    impl Scheduler for ProbeSched {
        fn name(&self) -> &'static str {
            "probe"
        }
        fn kind(&self) -> SchedKind {
            SchedKind::WorkStealing
        }
        fn add_worker(&mut self, _info: WorkerInfo) {}
        fn graph_submitted(&mut self, _graph: &TaskGraph) {}
        fn tasks_ready(&mut self, tasks: &[TaskId], out: &mut Vec<Action>) {
            for &t in tasks {
                out.push(Action::Assign(Assignment {
                    task: t,
                    worker: WorkerId(0),
                    priority: t.0 as i64,
                }));
            }
        }
        fn task_finished(
            &mut self,
            _task: TaskId,
            _worker: WorkerId,
            _nbytes: u64,
            _duration_us: u64,
            out: &mut Vec<Action>,
        ) {
            if !self.stolen {
                self.stolen = true;
                out.push(Action::Steal {
                    task: self.victim,
                    from: WorkerId(0),
                    to: WorkerId(1),
                });
            }
        }
        fn steal_result(
            &mut self,
            task: TaskId,
            from: WorkerId,
            to: WorkerId,
            success: bool,
            _out: &mut Vec<Action>,
        ) {
            self.results.lock().unwrap().push((task, from, to, success));
        }
        fn take_cost(&mut self) -> SchedCost {
            SchedCost::default()
        }
        fn in_flight_steal_count(&self) -> usize {
            usize::from(self.stolen).saturating_sub(
                self.results.lock().unwrap().iter().filter(|r| r.0 == self.victim).count(),
            )
        }
    }

    #[test]
    fn raced_steal_reports_real_endpoints() {
        // finish(t2 on w0) arrives while StealRequest(t2: w0→w1) is in
        // flight; the late StealResponse must report the *original*
        // (from=w0, to=w1) to the scheduler — the seed reported
        // (worker, worker), silently corrupting the load model.
        let results = Arc::new(Mutex::new(Vec::new()));
        let shared = results.clone();
        let pool = SchedulerPool::with_factory(
            Box::new(move |_seed| {
                Box::new(ProbeSched {
                    victim: TaskId(2),
                    stolen: false,
                    results: shared.clone(),
                })
            }),
            0,
        );
        let mut r = Reactor::new(pool, RuntimeProfile::rust(), false);
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(4), scheduler: None, open: false },
            &mut out,
        );
        let run = out
            .iter()
            .find_map(|(_, m)| match m {
                Msg::GraphSubmitted { run, .. } => Some(*run),
                _ => None,
            })
            .unwrap();
        out.clear();
        // t0 finishes → probe emits Steal(t2, w0→w1) → reactor sends the
        // StealRequest and marks t2 Stealing.
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::TaskFinished(TaskFinishedInfo { run, task: TaskId(0), nbytes: 1, duration_us: 1 }),
            &mut out,
        );
        r.drain(&mut out);
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Worker(WorkerId(0))
                && matches!(m, Msg::StealRequest { task, .. } if *task == TaskId(2))),
            "steal must go out: {out:?}"
        );
        // The finish wins the race.
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::TaskFinished(TaskFinishedInfo { run, task: TaskId(2), nbytes: 1, duration_us: 1 }),
            &mut out,
        );
        // The worker's answer arrives late: it could not retract.
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::StealResponse { run, task: TaskId(2), ok: false },
            &mut out,
        );
        let got = results.lock().unwrap().clone();
        assert_eq!(
            got,
            vec![(TaskId(2), WorkerId(0), WorkerId(1), false)],
            "scheduler must learn the real (from, to) of the raced steal"
        );
        // The steal is resolved — nothing leaks in flight.
        assert_eq!(r.scheduler_view(run).unwrap().in_flight_steal_count(), 0);
        // The run still completes afterwards.
        let report = r.run_state(run).expect("run still live");
        assert_eq!(report.raced_steals.len(), 0, "raced record consumed");
    }

    // ---- run-fair dispatch + admission control (PR 4 tentpole) ----

    use crate::server::fairness;

    fn submit(r: &mut Reactor, client: u32, graph: TaskGraph, out: &mut Vec<(Dest, Msg)>) -> RunId {
        let before = out.len();
        r.on_message(
            Origin::Client(client),
            Msg::SubmitGraph { graph, scheduler: None, open: false },
            out,
        );
        out[before..]
            .iter()
            .find_map(|(_, m)| match m {
                Msg::GraphSubmitted { run, .. } | Msg::RunQueued { run, .. } => Some(*run),
                _ => None,
            })
            .expect("submission is acked")
    }

    #[test]
    fn round_robin_pump_alternates_between_runs() {
        let mut r = reactor("ws").with_dispatch_quota(2);
        register(&mut r, 2, 2);
        let mut out = Vec::new();
        let a = submit(&mut r, 0, merge(8), &mut out);
        let b = submit(&mut r, 1, merge(8), &mut out);
        assert!(r.pending_messages() >= 16, "both runs parked their root assigns");
        let mut serviced = Vec::new();
        while let Some(run) = r.pump(&mut out) {
            serviced.push(run);
        }
        assert_eq!(r.pending_messages(), 0);
        // While both runs are pending, rounds must alternate a,b,a,b…
        assert_eq!(&serviced[..4], &[a, b, a, b][..]);
        // Everything eventually went out: 8 compute-tasks per run.
        for run in [a, b] {
            let n = out
                .iter()
                .filter(|(_, m)| matches!(m, Msg::ComputeTask { run: r2, .. } if *r2 == run))
                .count();
            assert_eq!(n, 8, "{run}");
        }
    }

    #[test]
    fn arrival_policy_drains_first_run_to_exhaustion() {
        let mut r = reactor("ws")
            .with_dispatch_quota(2)
            .with_fairness(fairness::by_name("arrival").unwrap());
        register(&mut r, 2, 2);
        let mut out = Vec::new();
        let a = submit(&mut r, 0, merge(8), &mut out);
        let b = submit(&mut r, 1, merge(8), &mut out);
        let mut serviced = Vec::new();
        while let Some(run) = r.pump(&mut out) {
            serviced.push(run);
        }
        // The pre-fairness baseline: run a's backlog drains fully before
        // run b is serviced at all.
        let first_b = serviced.iter().position(|&run| run == b).expect("b serviced");
        assert!(first_b >= 4, "a had ≥8 messages at quota 2: {serviced:?}");
        assert!(serviced[..first_b].iter().all(|&run| run == a), "{serviced:?}");
        assert!(serviced[first_b..].iter().all(|&run| run == b), "{serviced:?}");
    }

    #[test]
    fn weighted_policy_services_near_completion_run_first() {
        let mut r = reactor("ws")
            .with_dispatch_quota(4)
            .with_fairness(fairness::by_name("weighted").unwrap());
        register(&mut r, 2, 2);
        let mut out = Vec::new();
        let large = submit(&mut r, 0, merge(40), &mut out);
        let small = submit(&mut r, 1, merge(4), &mut out);
        let mut serviced = Vec::new();
        while let Some(run) = r.pump(&mut out) {
            serviced.push(run);
        }
        // Shortest-remaining-first: every round the small run has pending
        // messages it wins, so its rounds all precede the large run's.
        assert_eq!(serviced[0], small, "fewest remaining tasks goes first");
        let first_large =
            serviced.iter().position(|&run| run == large).expect("large serviced");
        assert!(serviced[..first_large].iter().all(|&run| run == small), "{serviced:?}");
        assert!(serviced[first_large..].iter().all(|&run| run == large), "{serviced:?}");
    }

    #[test]
    fn admission_cap_parks_and_activates_fifo() {
        let mut r = reactor("ws").with_admission_cap(1);
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let r1 = submit(&mut r, 0, merge(4), &mut out);
        let r2 = submit(&mut r, 0, merge(5), &mut out);
        let r3 = submit(&mut r, 0, merge(6), &mut out);
        assert_eq!(r.live_runs(), 1, "only the first run executes");
        assert_eq!(r.queued_runs(), 2);
        // Parked acks carry run-queued with the FIFO position at park time.
        let queued: Vec<(RunId, u64)> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::RunQueued { run, position } => Some((*run, *position)),
                _ => None,
            })
            .collect();
        assert_eq!(queued, vec![(r2, 0), (r3, 1)]);
        let done = drive_until_done(&mut r, out, &std::collections::HashSet::new());
        assert_eq!(done.len(), 3, "queued runs activate and complete");
        assert_eq!(r.queued_runs(), 0);
        // FIFO activation ⇒ completion (and report) order r1, r2, r3 under
        // a cap of one.
        let order: Vec<RunId> = r.reports().iter().map(|rep| rep.run).collect();
        assert_eq!(order, vec![r1, r2, r3]);
    }

    #[test]
    fn admission_cap_is_per_client() {
        let mut r = reactor("ws").with_admission_cap(1);
        register(&mut r, 2, 2);
        let mut out = Vec::new();
        submit(&mut r, 0, merge(4), &mut out);
        submit(&mut r, 0, merge(4), &mut out); // parks: client 0 at cap
        submit(&mut r, 1, merge(4), &mut out); // client 1 has its own cap
        assert_eq!(r.live_runs(), 2, "second client unaffected by first's cap");
        assert_eq!(r.queued_runs(), 1);
    }

    #[test]
    fn unknown_scheduler_fails_before_parking() {
        let mut r = reactor("ws").with_admission_cap(1);
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        submit(&mut r, 0, merge(4), &mut out);
        out.clear();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(5), scheduler: Some("fifo".into()), open: false },
            &mut out,
        );
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { reason, .. }
                if reason.contains("fifo"))),
            "bad scheduler must fail now, not at activation: {out:?}"
        );
        assert_eq!(r.queued_runs(), 0, "nothing parked");
    }

    #[test]
    fn admission_queue_overflow_fails_submission() {
        // The parked queue is bounded per client: past the cap a
        // submission fails instead of buffering yet another graph.
        let mut r = reactor("ws").with_admission_cap(1).with_admission_queue_cap(2);
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        submit(&mut r, 0, merge(4), &mut out); // live
        submit(&mut r, 0, merge(4), &mut out); // parked 1
        submit(&mut r, 0, merge(4), &mut out); // parked 2
        out.clear();
        let overflow = submit(&mut r, 0, merge(4), &mut out);
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { run, reason }
                if *run == overflow && reason.contains("admission queue full"))),
            "queue overflow must fail the submission: {out:?}"
        );
        assert_eq!(r.queued_runs(), 2, "nothing extra parked");
        // Another client is unaffected by this client's full queue.
        let mut r2out = Vec::new();
        r.on_message(
            Origin::Unregistered { conn: 55 },
            Msg::RegisterClient { name: "c1".into() },
            &mut r2out,
        );
        let ok = submit(&mut r, 1, merge(4), &mut r2out);
        assert!(r.run_state(ok).is_some(), "other client's run executes");
    }

    #[test]
    fn client_disconnect_drops_parked_submissions() {
        let mut r = reactor("ws").with_admission_cap(1);
        register(&mut r, 2, 2);
        let mut out = Vec::new();
        submit(&mut r, 0, merge(4), &mut out);
        submit(&mut r, 0, merge(5), &mut out); // parked
        submit(&mut r, 1, merge(4), &mut out); // other client, live
        r.on_disconnect(Origin::Client(0), &mut out);
        assert_eq!(r.queued_runs(), 0, "parked submission died with its client");
        assert_eq!(r.live_runs(), 1, "only the other client's run survives");
    }

    #[test]
    fn worker_death_with_parked_run_recovers_and_activates() {
        // Fairness × recovery: a worker dies while a run sits in the
        // admission queue. The live run recovers; the parked run activates
        // on the shrunken cluster once the first retires, and completes.
        let mut r = reactor("ws").with_admission_cap(1);
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let a = submit(&mut r, 0, merge(6), &mut out);
        let b = submit(&mut r, 0, merge(4), &mut out);
        assert_eq!(r.queued_runs(), 1);
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert!(
            !out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { .. })),
            "recovery must absorb the death: {out:?}"
        );
        assert_eq!(r.live_runs(), 1, "run a recovers");
        assert_eq!(r.queued_runs(), 1, "run b still parked");
        let done = drive_until_done(&mut r, out, &[WorkerId(0)].into());
        assert_eq!(done.len(), 2, "both runs complete: {done:?}");
        let rep_a = r.reports().iter().find(|rep| rep.run == a).unwrap();
        assert!(rep_a.recoveries >= 1, "run a recorded its recovery");
        let rep_b = r.reports().iter().find(|rep| rep.run == b).unwrap();
        assert_eq!(rep_b.n_tasks, 5);
        assert_eq!(
            rep_b.recoveries, 0,
            "run b activated after the death; nothing to recover"
        );
    }

    // ---- interned dispatch path (PR 5 tentpole) ----

    /// Sink that exercises BOTH dispatch forms per assignment and asserts
    /// the borrowed encode is byte-identical to encoding the owned
    /// message — the invariant that lets the TCP sink skip materializing
    /// `Msg::ComputeTask` entirely.
    struct DualSink {
        msgs: Vec<(Dest, Msg)>,
        computes_checked: usize,
    }

    impl OutboundSink for DualSink {
        fn emit_msg(&mut self, dest: Dest, msg: Msg) {
            self.msgs.push((dest, msg));
        }

        fn emit_compute(&mut self, d: &ComputeDispatch<'_>) {
            let owned = d.to_msg();
            let owned_bytes = crate::protocol::encode_msg(&owned);
            let mut borrowed = Vec::new();
            d.encode_into(&mut borrowed);
            assert_eq!(
                borrowed, owned_bytes,
                "borrowed dispatch encode must be byte-identical to the owned path"
            );
            // The worker-side borrowed view agrees with the dispatch.
            let view = crate::protocol::ComputeTaskView::decode(&borrowed).unwrap();
            assert_eq!(view.run, d.run);
            assert_eq!(view.task, d.task);
            assert_eq!(view.key, d.key());
            assert_eq!(view.priority, d.priority);
            assert_eq!(view.cores, d.parts().cores);
            assert_eq!(view.n_inputs(), d.inputs().len());
            self.computes_checked += 1;
            self.msgs.push((Dest::Worker(d.worker), owned));
        }
    }

    #[test]
    fn dispatch_paths_stay_byte_identical_through_a_run() {
        // Drive a dependency-bearing graph (w2w addresses in play) through
        // the reactor with the dual sink: every emitted assignment is
        // checked borrowed-vs-owned, including steal re-assignments.
        // Replication is on so alt-bearing input locations go through the
        // byte-identity check too.
        let mut r = reactor("ws").with_replication(2, 1);
        register(&mut r, 1, 3);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: tree(5), scheduler: None, open: false },
            &mut out,
        );
        let mut sink = DualSink { msgs: Vec::new(), computes_checked: 0 };
        let mut inbox: Vec<(Dest, Msg)> = std::mem::take(&mut out);
        let mut done = false;
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 1_000_000, "drive stuck");
            r.drain_into(&mut sink);
            inbox.append(&mut sink.msgs);
            inbox.append(&mut out);
            let Some((dest, msg)) = inbox.pop() else { break };
            match (dest, msg) {
                (Dest::Worker(w), Msg::ComputeTask { run, task, output_size, .. }) => {
                    r.on_message(
                        Origin::Worker(w),
                        Msg::TaskFinished(TaskFinishedInfo {
                            run,
                            task,
                            nbytes: output_size,
                            duration_us: 1,
                        }),
                        &mut out,
                    );
                }
                (Dest::Worker(w), Msg::StealRequest { run, task }) => {
                    // Always retractable: exercises the steal-ok re-assign
                    // park (the second `Parked::Compute` producer).
                    r.on_message(
                        Origin::Worker(w),
                        Msg::StealResponse { run, task, ok: true },
                        &mut out,
                    );
                }
                (Dest::Worker(_), Msg::ReplicateData { run, task, addrs }) => {
                    for a in &addrs {
                        r.on_message(
                            Origin::Worker(worker_of_addr(a)),
                            Msg::ReplicaAdded { run, task },
                            &mut out,
                        );
                    }
                }
                (_, Msg::GraphDone { .. }) => done = true,
                (_, Msg::GraphFailed { reason, .. }) => panic!("graph failed: {reason}"),
                _ => {}
            }
        }
        assert!(done, "graph completes");
        assert!(sink.computes_checked >= 31, "every task dispatched through the dual check");
    }

    #[test]
    fn parked_assignments_resolve_registered_addresses() {
        // Input locations are resolved from `who_has` + the registration
        // table when the parked assignment is *emitted*: every non-local
        // address on a dispatched message must be a registered data
        // address (never stale garbage, never a dangling clone).
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: tree(2), scheduler: None, open: false },
            &mut out,
        );
        r.drain(&mut out);
        // Finish each leaf on its assigned worker without pumping the
        // consumers out yet — their assignments park while who_has fills.
        let leaves: Vec<(WorkerId, RunId, TaskId)> = out
            .iter()
            .filter_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { run, task, .. }) => Some((*w, *run, *task)),
                _ => None,
            })
            .collect();
        assert!(!leaves.is_empty());
        for (w, run, task) in leaves {
            r.on_message(
                Origin::Worker(w),
                Msg::TaskFinished(TaskFinishedInfo { run, task, nbytes: 8, duration_us: 1 }),
                &mut out,
            );
        }
        out.clear();
        r.drain(&mut out);
        let registered = ["127.0.0.1:9000", "127.0.0.1:9001"];
        let mut saw_consumer = false;
        for (_, m) in &out {
            if let Msg::ComputeTask { inputs, .. } = m {
                for l in inputs {
                    saw_consumer = true;
                    assert!(
                        l.addr.is_empty() || registered.contains(&l.addr.as_str()),
                        "input addressed from who_has + registration table: {:?}",
                        l.addr
                    );
                }
            }
        }
        assert!(saw_consumer, "a dependent task was dispatched: {out:?}");
    }

    #[test]
    fn report_retention_bounds_history() {
        let mut r = reactor("ws").with_report_retention(2);
        register(&mut r, 1, 2);
        for i in 0..5usize {
            drive(&mut r, merge(3 + i));
        }
        assert_eq!(r.report_count(), 5, "monotonic completion count");
        assert_eq!(r.reports_dropped(), 3);
        let window: Vec<u64> = r.reports().iter().map(|rep| rep.n_tasks).collect();
        assert_eq!(window, vec![7, 8], "window holds the newest reports");
    }

    // ---- replicated object store (PR 8 tentpole) ----

    #[test]
    fn first_finish_of_hot_output_triggers_one_replicate_directive() {
        let mut r = reactor("ws").with_replication(2, 1);
        register(&mut r, 1, 3);
        let mut out = Vec::new();
        let run = submit(&mut r, 0, merge(2), &mut out);
        out.clear();
        r.drain(&mut out);
        let (task, producer) = out
            .iter()
            .find_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { task, .. }) => Some((*task, *w)),
                _ => None,
            })
            .expect("a leaf assignment went out");
        out.clear();
        r.on_message(
            Origin::Worker(producer),
            Msg::TaskFinished(TaskFinishedInfo { run, task, nbytes: 64, duration_us: 1 }),
            &mut out,
        );
        r.drain(&mut out);
        let (dest, addrs) = out
            .iter()
            .find_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ReplicateData { task: t, addrs, .. }) if *t == task => {
                    Some((*w, addrs.clone()))
                }
                _ => None,
            })
            .expect("hot output must be pushed to a peer");
        assert_eq!(dest, producer, "the producer pushes the copies");
        assert_eq!(addrs.len(), 1, "k = 2 means one extra copy");
        // Deterministic placement: the next connected worker after the
        // producer that does not already hold the output.
        let target = worker_of_addr(&addrs[0]);
        assert_eq!(target, WorkerId((producer.0 + 1) % 3));
        // The ack lands in who_has; a duplicate ack does not double-count.
        r.on_message(Origin::Worker(target), Msg::ReplicaAdded { run, task }, &mut out);
        r.on_message(Origin::Worker(target), Msg::ReplicaAdded { run, task }, &mut out);
        let who = &r.run_state(run).unwrap().who_has[task.idx()];
        assert_eq!(who.len(), 2);
        assert!(who.contains(producer) && who.contains(target));
        // A duplicate finish (recovery race) must not push again.
        out.clear();
        r.on_message(
            Origin::Worker(producer),
            Msg::TaskFinished(TaskFinishedInfo { run, task, nbytes: 64, duration_us: 1 }),
            &mut out,
        );
        r.drain(&mut out);
        assert!(
            !out.iter().any(|(_, m)| matches!(m, Msg::ReplicateData { .. })),
            "duplicate finish re-replicated: {out:?}"
        );
        // A worker-side self-eviction purges the address again.
        r.on_message(Origin::Worker(target), Msg::ReplicaDropped { run, task }, &mut out);
        let who = &r.run_state(run).unwrap().who_has[task.idx()];
        assert_eq!(who.len(), 1);
        assert!(!who.contains(target));
    }

    #[test]
    fn assignments_carry_replica_alternates() {
        // Once an output has several holders, dependent dispatches must
        // carry the extra addresses so the fetch path can fail over
        // without a server round-trip.
        let mut r = reactor("ws").with_replication(2, 1);
        register(&mut r, 1, 3);
        let mut out = Vec::new();
        let run = submit(&mut r, 0, merge(2), &mut out);
        out.clear();
        r.drain(&mut out);
        let leaves: Vec<(WorkerId, TaskId)> = out
            .iter()
            .filter_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { task, .. }) => Some((*w, *task)),
                _ => None,
            })
            .collect();
        assert_eq!(leaves.len(), 2);
        // Finish the leaves WITHOUT draining (the merge assignment parks),
        // then register replicas on every other worker so who_has is full
        // before the parked assignment resolves its addresses.
        for &(w, task) in &leaves {
            r.on_message(
                Origin::Worker(w),
                Msg::TaskFinished(TaskFinishedInfo { run, task, nbytes: 8, duration_us: 1 }),
                &mut out,
            );
        }
        for &(producer, task) in &leaves {
            for w in 0..3u32 {
                if WorkerId(w) != producer {
                    r.on_message(
                        Origin::Worker(WorkerId(w)),
                        Msg::ReplicaAdded { run, task },
                        &mut out,
                    );
                }
            }
        }
        out.clear();
        r.drain(&mut out);
        let mut saw_input = false;
        for (_, m) in &out {
            if let Msg::ComputeTask { inputs, .. } = m {
                for l in inputs {
                    saw_input = true;
                    assert!(
                        !l.alts.is_empty(),
                        "3 holders on 3 workers leave at least one remote alternate"
                    );
                    for a in &l.alts {
                        assert!(a.starts_with("127.0.0.1:"), "registered address: {a}");
                        assert_ne!(*a, l.addr, "alternates differ from the primary");
                    }
                }
            }
        }
        assert!(saw_input, "the merge task was dispatched: {out:?}");
    }

    #[test]
    fn replicated_outputs_make_a_death_trivial() {
        // Kill a worker that holds replicated data but runs nothing: with
        // a surviving copy of everything it held, recovery must be the
        // trivial who_has purge — nothing resurrected, nothing recomputed.
        let mut r = reactor("random").with_replication(2, 1);
        register(&mut r, 1, 3);
        let mut out = Vec::new();
        let run = submit(&mut r, 0, merge(2), &mut out);
        out.clear();
        let mut pending = Vec::new();
        r.drain(&mut pending);
        let mut sink = None;
        let mut guard = 0;
        while let Some((dest, msg)) = pending.pop() {
            guard += 1;
            assert!(guard < 10_000, "drive stuck");
            let Dest::Worker(w) = dest else { continue };
            match msg {
                Msg::ComputeTask { task, inputs, .. } => {
                    if inputs.is_empty() {
                        r.on_message(
                            Origin::Worker(w),
                            Msg::TaskFinished(TaskFinishedInfo {
                                run,
                                task,
                                nbytes: 64,
                                duration_us: 1,
                            }),
                            &mut out,
                        );
                    } else {
                        sink = Some((w, task)); // hold the merge task open
                    }
                }
                Msg::ReplicateData { task, addrs, .. } => {
                    for a in &addrs {
                        r.on_message(
                            Origin::Worker(worker_of_addr(a)),
                            Msg::ReplicaAdded { run, task },
                            &mut out,
                        );
                    }
                }
                _ => {}
            }
            r.drain(&mut out);
            pending.append(&mut out);
        }
        let (sink_worker, sink_task) = sink.expect("merge task dispatched");
        for t in [TaskId(0), TaskId(1)] {
            assert_eq!(
                r.run_state(run).unwrap().who_has[t.idx()].len(),
                2,
                "both leaf outputs replicated"
            );
        }
        // Victim: holds a copy of leaf 0 but is not running the sink.
        let victim = r
            .run_state(run)
            .unwrap()
            .who_has[0]
            .iter()
            .find(|&w| w != sink_worker)
            .expect("two holders, at most one runs the sink");
        out.clear();
        r.on_disconnect(Origin::Worker(victim), &mut out);
        assert!(
            !out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { .. })),
            "replicated loss must not fail the run: {out:?}"
        );
        let state = r.run_state(run).unwrap();
        assert_eq!(state.recoveries, 0, "trivial purge is not charged as a recovery");
        assert_eq!(state.tasks_recomputed, 0);
        assert!(!state.who_has[0].contains(victim), "corpse purged from who_has");
        assert!(state.who_has[0].len() >= 1, "a live replica survives");
        // The sink finishes off the surviving replicas; no reassignment
        // was ever needed.
        r.on_message(
            Origin::Worker(sink_worker),
            Msg::TaskFinished(TaskFinishedInfo {
                run,
                task: sink_task,
                nbytes: 64,
                duration_us: 1,
            }),
            &mut out,
        );
        let done = drive_until_done(&mut r, out, &[victim].into_iter().collect());
        assert_eq!(done.len(), 1, "run completes off the surviving replicas");
        let rep = r.reports().last().unwrap();
        assert_eq!(rep.recoveries, 0);
        assert_eq!(rep.tasks_recomputed, 0);
    }

    #[test]
    fn fetch_retry_resurrects_inputs_lost_to_self_eviction() {
        // A worker's store can drop an output (self-eviction after its
        // consumers were served) and report `replica-dropped`; if a fetch
        // then fails, the retry path must recompute the missing input
        // rather than bounce the consumer forever at an empty who_has.
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let run = submit(&mut r, 0, merge(1), &mut out);
        out.clear();
        r.drain(&mut out);
        let (leaf, producer) = out
            .iter()
            .find_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { task, .. }) => Some((*task, *w)),
                _ => None,
            })
            .expect("leaf assignment");
        out.clear();
        r.on_message(
            Origin::Worker(producer),
            Msg::TaskFinished(TaskFinishedInfo { run, task: leaf, nbytes: 8, duration_us: 1 }),
            &mut out,
        );
        r.drain(&mut out);
        let (sink_task, sink_worker) = out
            .iter()
            .find_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { task, .. }) => Some((*task, *w)),
                _ => None,
            })
            .expect("merge assignment");
        // The producer evicts the leaf output while the fetch is in flight.
        r.on_message(Origin::Worker(producer), Msg::ReplicaDropped { run, task: leaf }, &mut out);
        assert!(r.run_state(run).unwrap().who_has[leaf.idx()].is_empty());
        out.clear();
        r.on_message(
            Origin::Worker(sink_worker),
            Msg::TaskErred {
                run,
                task: sink_task,
                error: format!("{FETCH_FAILED_PREFIX}all sources gone"),
            },
            &mut out,
        );
        r.drain(&mut out);
        assert!(
            out.iter().any(
                |(_, m)| matches!(m, Msg::ComputeTask { task, .. } if *task == leaf)
            ),
            "evicted input goes out for recompute: {out:?}"
        );
        assert_eq!(r.run_state(run).unwrap().tasks_recomputed, 1);
        let done = drive_until_done(&mut r, out, &Default::default());
        assert_eq!(done.len(), 1);
        let rep = r.reports().last().unwrap();
        assert_eq!(rep.tasks_recomputed, 1, "report surfaces the recompute");
        assert_eq!(rep.recoveries, 0, "no worker died; not a recovery pass");
    }

    // ---- incremental graphs / submit-extend (PR 9 tentpole) ----

    fn spec(id: u32, inputs: Vec<u32>) -> crate::taskgraph::TaskSpec {
        crate::taskgraph::TaskSpec {
            id: TaskId(id),
            key: format!("x-{id}"),
            inputs: inputs.into_iter().map(TaskId).collect(),
            duration_us: 5,
            output_size: 8,
            payload: crate::taskgraph::Payload::MergeInputs,
            cores: 1,
        }
    }

    fn submit_open(
        r: &mut Reactor,
        client: u32,
        graph: TaskGraph,
        out: &mut Vec<(Dest, Msg)>,
    ) -> RunId {
        let before = out.len();
        r.on_message(
            Origin::Client(client),
            Msg::SubmitGraph { graph, scheduler: None, open: true },
            out,
        );
        out[before..]
            .iter()
            .find_map(|(_, m)| match m {
                Msg::GraphSubmitted { run, .. } | Msg::RunQueued { run, .. } => Some(*run),
                _ => None,
            })
            .expect("submission is acked")
    }

    /// Compute-task assignments in `out` as (worker, task) pairs.
    fn assignments(out: &[(Dest, Msg)]) -> Vec<(WorkerId, TaskId)> {
        out.iter()
            .filter_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::ComputeTask { task, .. }) => Some((*w, *task)),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn incremental_submission_matches_one_shot() {
        // The same graph delivered in three extension epochs completes with
        // the same task set as the one-shot submission, on every scheduler.
        for sched in ["random", "ws", "dask-ws"] {
            let full = tree(6); // 63 tasks
            let mut r = reactor(sched);
            register(&mut r, 1, 4);
            let specs = full.tasks().to_vec();
            let (a, rest) = specs.split_at(20);
            let (b, c) = rest.split_at(20);
            let base = TaskGraph::new("tree-inc", a.to_vec()).unwrap();
            let mut out = Vec::new();
            let run = submit_open(&mut r, 0, base, &mut out);
            r.on_message(
                Origin::Client(0),
                Msg::SubmitExtend { run, tasks: b.to_vec(), last: false },
                &mut out,
            );
            r.on_message(
                Origin::Client(0),
                Msg::SubmitExtend { run, tasks: c.to_vec(), last: true },
                &mut out,
            );
            // Both extensions acked with the running totals.
            let acks: Vec<u64> = out
                .iter()
                .filter_map(|(_, m)| match m {
                    Msg::GraphSubmitted { n_tasks, .. } => Some(*n_tasks),
                    _ => None,
                })
                .collect();
            assert_eq!(acks, vec![20, 40, 63], "{sched}");
            let done = drive_until_done(&mut r, out, &Default::default());
            assert_eq!(done.len(), 1, "{sched}");
            assert_eq!(done[&run].1, 63, "{sched}: full task count reported");
            let rep = r.reports().last().unwrap();
            assert_eq!(rep.n_tasks, 63, "{sched}");
            assert_eq!(rep.tasks_recomputed, 0, "{sched}: nothing resurrected");
        }
    }

    #[test]
    fn open_run_survives_quiescence_and_pure_close_retires_it() {
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let base = TaskGraph::new("inc", vec![spec(0, vec![])]).unwrap();
        let run = submit_open(&mut r, 0, base, &mut out);
        r.drain(&mut out);
        let (w, t) = assignments(&out)[0];
        r.on_message(
            Origin::Worker(w),
            Msg::TaskFinished(TaskFinishedInfo { run, task: t, nbytes: 8, duration_us: 1 }),
            &mut out,
        );
        // Every task finished, but the run is open: it must NOT retire.
        assert_eq!(r.live_runs(), 1, "open run survives quiescence");
        assert_eq!(r.run_state(run).unwrap().remaining, 0);
        out.clear();
        // An empty closing extension is a pure close: the quiescent run
        // retires immediately, reporting the real task count.
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![], last: true },
            &mut out,
        );
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Client(0)
                && matches!(m, Msg::GraphDone { n_tasks: 1, .. })),
            "pure close retires the quiescent run: {out:?}"
        );
        assert_eq!(r.live_runs(), 0);
    }

    #[test]
    fn extension_after_base_finished_repins_resident_outputs() {
        // New tasks consume outputs that already finished: the reactor must
        // raise the holders' store refcounts (`pin-data`) by exactly the
        // emission gap, then complete the grafted tasks normally.
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let base =
            TaskGraph::new("inc", vec![spec(0, vec![]), spec(1, vec![])]).unwrap();
        let run = submit_open(&mut r, 0, base, &mut out);
        r.drain(&mut out);
        let leaves = assignments(&out);
        assert_eq!(leaves.len(), 2);
        for &(w, t) in &leaves {
            r.on_message(
                Origin::Worker(w),
                Msg::TaskFinished(TaskFinishedInfo { run, task: t, nbytes: 8, duration_us: 1 }),
                &mut out,
            );
        }
        assert_eq!(r.run_state(run).unwrap().remaining, 0);
        out.clear();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![spec(2, vec![0, 1])], last: true },
            &mut out,
        );
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Client(0)
                && matches!(m, Msg::GraphSubmitted { run: r2, n_tasks: 3 } if *r2 == run)),
            "extension acked with the new total: {out:?}"
        );
        r.drain(&mut out);
        // Each finished leaf was emitted with consumers = 0 (sink); the
        // extension made each count 1 → pin delta 1 to the holder.
        let pins: Vec<(WorkerId, TaskId, u32)> = out
            .iter()
            .filter_map(|(d, m)| match (d, m) {
                (Dest::Worker(w), Msg::PinData { task, consumers, .. }) => {
                    Some((*w, *task, *consumers))
                }
                _ => None,
            })
            .collect();
        assert_eq!(pins.len(), 2, "one pin per re-consumed output: {out:?}");
        for (w, t, c) in &pins {
            assert_eq!(*c, 1, "delta = new consumers − emitted consumers");
            let holder = leaves.iter().find(|(_, t2)| t2 == t).unwrap().0;
            assert_eq!(*w, holder, "pin goes to the output's holder");
        }
        assert_eq!(r.run_state(run).unwrap().tasks_recomputed, 0, "nothing resurrected");
        let done = drive_until_done(&mut r, out, &Default::default());
        assert_eq!(done[&run].1, 3);
    }

    #[test]
    fn extension_resurrects_evicted_inputs() {
        // The extension's inputs finished but every replica self-evicted:
        // the producer must be transitively resurrected (PR 3 lineage
        // machinery) and recomputed before the grafted consumer runs.
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let base =
            TaskGraph::new("inc", vec![spec(0, vec![]), spec(1, vec![])]).unwrap();
        let run = submit_open(&mut r, 0, base, &mut out);
        r.drain(&mut out);
        let leaves = assignments(&out);
        for &(w, t) in &leaves {
            r.on_message(
                Origin::Worker(w),
                Msg::TaskFinished(TaskFinishedInfo { run, task: t, nbytes: 8, duration_us: 1 }),
                &mut out,
            );
        }
        // Leaf 0's only copy evaporates (store self-eviction).
        let holder0 = leaves.iter().find(|(_, t)| *t == TaskId(0)).unwrap().0;
        r.on_message(
            Origin::Worker(holder0),
            Msg::ReplicaDropped { run, task: TaskId(0) },
            &mut out,
        );
        assert!(r.run_state(run).unwrap().who_has[0].is_empty());
        out.clear();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![spec(2, vec![0, 1])], last: true },
            &mut out,
        );
        assert_eq!(
            r.run_state(run).unwrap().tasks_recomputed,
            1,
            "evicted producer resurrected"
        );
        r.drain(&mut out);
        assert!(
            out.iter()
                .any(|(_, m)| matches!(m, Msg::ComputeTask { task, .. } if *task == TaskId(0))),
            "resurrected producer re-dispatched: {out:?}"
        );
        // The resident leaf 1 still gets its pin; the evicted leaf 0 must
        // NOT (its refcount is baked into the re-sent compute-task).
        let pinned: Vec<TaskId> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::PinData { task, .. } => Some(*task),
                _ => None,
            })
            .collect();
        assert_eq!(pinned, vec![TaskId(1)], "{out:?}");
        let done = drive_until_done(&mut r, out, &Default::default());
        assert_eq!(done[&run].1, 3);
        assert_eq!(r.reports().last().unwrap().tasks_recomputed, 1);
    }

    #[test]
    fn extension_during_recovery_completes() {
        // A worker dies (recovery in flight), then an extension lands
        // before the re-sent work finishes: epochs and recovery compose.
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let base =
            TaskGraph::new("inc", vec![spec(0, vec![]), spec(1, vec![0])]).unwrap();
        let run = submit_open(&mut r, 0, base, &mut out);
        r.drain(&mut out);
        let (w0, t0) = *assignments(&out)
            .iter()
            .find(|(_, t)| *t == TaskId(0))
            .expect("root assigned");
        r.on_message(
            Origin::Worker(w0),
            Msg::TaskFinished(TaskFinishedInfo { run, task: t0, nbytes: 8, duration_us: 1 }),
            &mut out,
        );
        out.clear();
        r.on_disconnect(Origin::Worker(w0), &mut out);
        assert_eq!(r.live_runs(), 1, "recovery absorbs the death: {out:?}");
        // Extend mid-recovery: new sink over both epochs' outputs.
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![spec(2, vec![0, 1])], last: true },
            &mut out,
        );
        let done = drive_until_done(&mut r, out, &[w0].into());
        assert_eq!(done[&run].1, 3);
        let rep = r.reports().last().unwrap();
        assert!(rep.recoveries >= 1, "the death was a real recovery");
    }

    #[test]
    fn extension_of_parked_run_folds_into_activation() {
        let mut r = reactor("ws").with_admission_cap(1);
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let a = submit(&mut r, 0, merge(4), &mut out); // live
        let base = TaskGraph::new("inc", vec![spec(0, vec![])]).unwrap();
        let b = submit_open(&mut r, 0, base, &mut out); // parked
        assert_eq!(r.queued_runs(), 1);
        out.clear();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run: b, tasks: vec![spec(1, vec![0])], last: true },
            &mut out,
        );
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::GraphSubmitted { run, n_tasks: 2 }
                if *run == b)),
            "parked extension acked with the folded total: {out:?}"
        );
        let done = drive_until_done(&mut r, out, &Default::default());
        assert_eq!(done.len(), 2, "both runs complete: {done:?}");
        assert_eq!(done[&a].1, 5);
        assert_eq!(done[&b].1, 2, "activation saw the folded graph, already closed");
    }

    #[test]
    fn client_disconnect_purges_extended_run() {
        // The client dies with its open run mid-extension (the closing
        // extension never arrives): the run must be purged and released on
        // the workers like any orphan, and a late extension for it answers
        // graph-failed instead of resurrecting state.
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let base = TaskGraph::new("inc", vec![spec(0, vec![])]).unwrap();
        let run = submit_open(&mut r, 0, base, &mut out);
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![spec(1, vec![0])], last: false },
            &mut out,
        );
        out.clear();
        r.on_disconnect(Origin::Client(0), &mut out);
        assert_eq!(r.live_runs(), 0, "orphaned open run purged");
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::ReleaseRun { .. })),
            "workers told to release: {out:?}"
        );
        out.clear();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![spec(2, vec![])], last: true },
            &mut out,
        );
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Client(0)
                && matches!(m, Msg::GraphFailed { run: r2, .. } if *r2 == run)),
            "late extension for a retired run fails cleanly: {out:?}"
        );
    }

    #[test]
    fn extension_of_closed_or_unknown_run_fails() {
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        // One-shot (closed) run: an extension is fatal protocol misuse.
        let run = submit(&mut r, 0, merge(4), &mut out);
        out.clear();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![spec(5, vec![])], last: false },
            &mut out,
        );
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Client(0)
                && matches!(m, Msg::GraphFailed { run: r2, reason }
                    if *r2 == run && reason.contains("not open"))),
            "{out:?}"
        );
        assert_eq!(r.live_runs(), 0);
        // Unknown run: failure names the run so the client can match it.
        out.clear();
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run: RunId(4242), tasks: vec![], last: true },
            &mut out,
        );
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { run, .. }
                if *run == RunId(4242))),
            "{out:?}"
        );
    }

    #[test]
    fn invalid_extension_batch_fails_the_run() {
        let mut r = reactor("ws");
        register(&mut r, 1, 2);
        let mut out = Vec::new();
        let base = TaskGraph::new("inc", vec![spec(0, vec![])]).unwrap();
        let run = submit_open(&mut r, 0, base, &mut out);
        out.clear();
        // Batch ids must continue the dense id space; id 5 ≠ len() = 1.
        r.on_message(
            Origin::Client(0),
            Msg::SubmitExtend { run, tasks: vec![spec(5, vec![])], last: false },
            &mut out,
        );
        assert!(
            out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { run: r2, reason }
                if *r2 == run && reason.contains("invalid extension"))),
            "{out:?}"
        );
        assert_eq!(r.live_runs(), 0, "misaligned id spaces kill the run");
    }

    // ---- replica-ack vs run-retirement race (satellite bugfix) ----

    #[test]
    fn replica_ack_after_run_retirement_is_dropped_silently() {
        let mut r = reactor("ws").with_replication(2, 1);
        register(&mut r, 1, 3);
        // Retire a run cleanly, then deliver a replica-added whose push
        // raced the retirement: the missing-run path must swallow it.
        let (report, _) = drive(&mut r, merge(2));
        let mut out = Vec::new();
        r.on_message(
            Origin::Worker(WorkerId(2)),
            Msg::ReplicaAdded { run: report.run, task: TaskId(0) },
            &mut out,
        );
        assert!(out.is_empty(), "late ack for a retired run must be silent: {out:?}");
        assert_eq!(r.live_runs(), 0);
        // Same for a run that *failed* (run state dropped by fail_run)…
        let run = submit(&mut r, 0, merge(3), &mut out);
        out.clear();
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::TaskErred { run, task: TaskId(0), error: "boom".into() },
            &mut out,
        );
        assert!(out.iter().any(|(_, m)| matches!(m, Msg::GraphFailed { .. })));
        out.clear();
        r.on_message(
            Origin::Worker(WorkerId(1)),
            Msg::ReplicaAdded { run, task: TaskId(1) },
            &mut out,
        );
        assert!(out.is_empty(), "{out:?}");
        // …and for a run id never allocated at all.
        r.on_message(
            Origin::Worker(WorkerId(1)),
            Msg::ReplicaAdded { run: RunId(31337), task: TaskId(0) },
            &mut out,
        );
        assert!(out.is_empty(), "{out:?}");
    }
}
