//! The reactor: connection-facing state machine of the RSDS server (§IV-A).
//!
//! "The reactor manages worker and client connections, maintains
//! bookkeeping information and translates scheduler assignments into DASK
//! messages which are then sent to the workers."
//!
//! Pure state machine: [`Reactor::on_message`] consumes one inbound message
//! and appends outbound `(Dest, Msg)` pairs; no I/O happens here. The TCP
//! layer ([`super::net`]) and the integration tests drive it identically.

use super::state::{GraphRun, TaskState};
use crate::overhead::RuntimeProfile;
use crate::protocol::{Msg, TaskInputLoc};
use crate::scheduler::{Action, Scheduler, WorkerId, WorkerInfo};
use crate::taskgraph::TaskId;
use crate::util::timing::{busy_wait_us, Stopwatch};

/// Message destination, resolved to a socket by the transport layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dest {
    Client(u32),
    Worker(WorkerId),
}

/// Message origin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Origin {
    /// Not yet registered; `conn` is a transport-level token echoed back in
    /// the registration reply path.
    Unregistered { conn: u64 },
    Client(u32),
    Worker(WorkerId),
}

/// Post-run statistics for one graph.
#[derive(Debug, Clone, PartialEq)]
pub struct ReactorReport {
    pub graph_name: String,
    pub n_tasks: u64,
    pub makespan_us: u64,
    /// Average overhead per task: makespan / #tasks (the paper's AOT).
    pub aot_us: f64,
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub msgs_in: u64,
    pub msgs_out: u64,
}

#[derive(Debug, Clone, Copy)]
struct WorkerMeta {
    #[allow(dead_code)] // kept for introspection/debug dumps
    info: WorkerInfo,
    connected: bool,
}

/// The reactor state machine.
pub struct Reactor {
    scheduler: Box<dyn Scheduler>,
    profile: RuntimeProfile,
    /// Busy-wait the profile's costs on the hot path (Dask emulation).
    emulate: bool,
    clock: Stopwatch,
    workers: Vec<WorkerMeta>,
    worker_addrs: Vec<String>,
    n_clients: u32,
    run: Option<GraphRun>,
    reports: Vec<ReactorReport>,
    steals_attempted: u64,
    steals_failed: u64,
    msgs_in: u64,
    msgs_out: u64,
    actions_buf: Vec<Action>,
}

impl Reactor {
    pub fn new(scheduler: Box<dyn Scheduler>, profile: RuntimeProfile, emulate: bool) -> Reactor {
        Reactor {
            scheduler,
            profile,
            emulate,
            clock: Stopwatch::start(),
            workers: Vec::new(),
            worker_addrs: Vec::new(),
            n_clients: 0,
            run: None,
            reports: Vec::new(),
            steals_attempted: 0,
            steals_failed: 0,
            msgs_in: 0,
            msgs_out: 0,
            actions_buf: Vec::new(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.connected).count()
    }

    /// Completed-run reports (one per finished graph).
    pub fn reports(&self) -> &[ReactorReport] {
        &self.reports
    }

    /// Charge emulated runtime cost (no-op unless `emulate`).
    fn charge(&self, us: f64) {
        if self.emulate && us >= 1.0 {
            busy_wait_us(us as u64);
        }
    }

    fn charge_msg(&self, approx_bytes: usize) {
        self.charge(self.profile.msg_cost_us(approx_bytes));
    }

    /// Drain scheduler actions into protocol messages. Iterates because a
    /// rejected steal feeds back into the scheduler which may emit more
    /// actions; bounded since every round retires at least one action.
    fn flush_actions(&mut self, out: &mut Vec<(Dest, Msg)>) {
        let mut rounds = 0;
        while !self.actions_buf.is_empty() {
            rounds += 1;
            debug_assert!(rounds < 10_000, "steal feedback failed to converge");
            // Charge the scheduler's algorithmic work at the profile's
            // rates (GIL: burns reactor time inline, exactly like CPython).
            let cost = self.scheduler.take_cost();
            let kind = self.scheduler.kind();
            self.charge(cost.to_us(&self.profile, kind));

            let actions = std::mem::take(&mut self.actions_buf);
            for action in &actions {
                match *action {
                    Action::Assign(a) => {
                        // Assigning to a dead worker would strand the graph
                        // (the schedulers are not told about disconnects) —
                        // fail fast instead of silently dropping.
                        let connected = self
                            .workers
                            .get(a.worker.idx())
                            .map(|w| w.connected)
                            .unwrap_or(false);
                        if !connected {
                            if let Some(run) = self.run.take() {
                                self.msgs_out += 1;
                                out.push((
                                    Dest::Client(run.client),
                                    Msg::GraphFailed {
                                        reason: format!(
                                            "scheduler assigned {} to disconnected worker {}",
                                            a.task, a.worker
                                        ),
                                    },
                                ));
                            }
                            self.actions_buf.clear();
                            return;
                        }
                        let msg = self.compute_task_msg(a.task, a.worker, a.priority);
                        let run = self.run.as_mut().expect("assign without graph");
                        run.states[a.task.idx()] = TaskState::Assigned(a.worker);
                        self.charge(self.profile.task_transition_us);
                        self.charge_msg(192);
                        self.msgs_out += 1;
                        out.push((Dest::Worker(a.worker), msg));
                    }
                    Action::Steal { task, from, to } => {
                        let run = self.run.as_mut().expect("steal without graph");
                        // Only steal tasks still assigned; scheduler models
                        // can lag one event behind.
                        if run.states[task.idx()] == TaskState::Assigned(from) {
                            run.states[task.idx()] = TaskState::Stealing { from, to };
                            self.steals_attempted += 1;
                            self.charge(self.profile.task_transition_us);
                            self.charge_msg(64);
                            self.msgs_out += 1;
                            out.push((Dest::Worker(from), Msg::StealRequest { task }));
                        } else {
                            // Already finished/stolen — report as failed.
                            let mut buf = Vec::new();
                            self.scheduler.steal_result(task, from, to, false, &mut buf);
                            self.actions_buf.extend(buf);
                        }
                    }
                }
            }
        }
    }

    /// Build a compute-task message with `who_has` input locations.
    fn compute_task_msg(&self, task: TaskId, worker: WorkerId, priority: i64) -> Msg {
        let run = self.run.as_ref().expect("no active graph");
        let spec = run.graph.task(task);
        let inputs = spec
            .inputs
            .iter()
            .map(|&input| {
                let holders = &run.who_has[input.idx()];
                let addr = holders
                    .first()
                    .map(|&h| {
                        if h == worker {
                            String::new() // local
                        } else {
                            self.worker_addrs.get(h.idx()).cloned().unwrap_or_default()
                        }
                    })
                    .unwrap_or_default();
                TaskInputLoc { task: input, addr, nbytes: run.graph.task(input).output_size }
            })
            .collect();
        Msg::ComputeTask {
            task,
            key: spec.key.clone(),
            payload: spec.payload.clone(),
            duration_us: spec.duration_us,
            output_size: spec.output_size,
            inputs,
            priority,
        }
    }

    /// Feed one inbound message; outbound messages are appended to `out`.
    pub fn on_message(&mut self, from: Origin, msg: Msg, out: &mut Vec<(Dest, Msg)>) {
        self.msgs_in += 1;
        self.charge_msg(128);
        match (from, msg) {
            (Origin::Unregistered { .. }, Msg::RegisterClient { .. }) => {
                let id = self.n_clients;
                self.n_clients += 1;
                self.msgs_out += 1;
                out.push((Dest::Client(id), Msg::Welcome { id }));
            }
            (Origin::Unregistered { .. }, Msg::RegisterWorker { ncores, node, data_addr, .. }) => {
                let id = WorkerId(self.workers.len() as u32);
                let info = WorkerInfo { id, ncores, node };
                self.workers.push(WorkerMeta { info, connected: true });
                self.worker_addrs.push(data_addr);
                self.scheduler.add_worker(info);
                self.msgs_out += 1;
                out.push((Dest::Worker(id), Msg::Welcome { id: id.0 }));
            }
            (Origin::Client(client), Msg::SubmitGraph { graph }) => {
                assert!(self.run.is_none(), "one graph at a time (paper's benchmark model)");
                self.charge(self.profile.task_transition_us * graph.len() as f64 * 0.2);
                let run = GraphRun::new(graph, client, self.clock.elapsed_us());
                self.scheduler.graph_submitted(&run.graph);
                let roots = run.ready_roots();
                self.run = Some(run);
                self.scheduler.tasks_ready(&roots, &mut self.actions_buf);
                self.flush_actions(out);
            }
            (Origin::Worker(worker), Msg::TaskFinished(info)) => {
                self.charge(self.profile.task_transition_us);
                let Some(run) = self.run.as_mut() else { return };
                let newly_ready = run.finish(info.task, worker);
                self.scheduler.task_finished(
                    info.task,
                    worker,
                    info.nbytes,
                    info.duration_us,
                    &mut self.actions_buf,
                );
                if !newly_ready.is_empty() {
                    self.charge(self.profile.task_transition_us * newly_ready.len() as f64);
                    self.scheduler.tasks_ready(&newly_ready, &mut self.actions_buf);
                }
                self.flush_actions(out);
                let run = self.run.as_ref().unwrap();
                if run.is_done() {
                    let makespan_us = self.clock.elapsed_us() - run.submitted_at_us;
                    let n_tasks = run.graph.len() as u64;
                    let report = ReactorReport {
                        graph_name: run.graph.name.clone(),
                        n_tasks,
                        makespan_us,
                        aot_us: makespan_us as f64 / n_tasks as f64,
                        steals_attempted: self.steals_attempted,
                        steals_failed: self.steals_failed,
                        msgs_in: self.msgs_in,
                        msgs_out: self.msgs_out,
                    };
                    let client = run.client;
                    self.reports.push(report);
                    self.run = None;
                    self.msgs_out += 1;
                    out.push((Dest::Client(client), Msg::GraphDone { makespan_us, n_tasks }));
                }
            }
            (Origin::Worker(worker), Msg::StealResponse { task, ok }) => {
                let Some(run) = self.run.as_mut() else { return };
                let TaskState::Stealing { from, to } = run.states[task.idx()] else {
                    // Finish raced ahead (possible only across connections);
                    // treat as failed steal.
                    self.scheduler.steal_result(task, worker, worker, false, &mut self.actions_buf);
                    self.flush_actions(out);
                    return;
                };
                debug_assert_eq!(from, worker);
                if ok {
                    // Retracted: reassign to the steal target.
                    run.states[task.idx()] = TaskState::Assigned(to);
                    self.scheduler.steal_result(task, from, to, true, &mut self.actions_buf);
                    let msg = self.compute_task_msg(task, to, task.0 as i64);
                    self.charge(self.profile.task_transition_us);
                    self.charge_msg(192);
                    self.msgs_out += 1;
                    out.push((Dest::Worker(to), msg));
                } else {
                    self.steals_failed += 1;
                    run.states[task.idx()] = TaskState::Assigned(from);
                    self.scheduler.steal_result(task, from, to, false, &mut self.actions_buf);
                }
                self.flush_actions(out);
            }
            (Origin::Worker(_), Msg::TaskErred { task, error }) => {
                let Some(run) = self.run.take() else { return };
                let client = run.client;
                self.msgs_out += 1;
                out.push((
                    Dest::Client(client),
                    Msg::GraphFailed {
                        reason: format!("task {} ({}) erred: {error}", task, run.graph.task(task).key),
                    },
                ));
            }
            (Origin::Worker(w), Msg::DataToServer { .. }) => {
                // Zero-worker data fetches terminate here (mock payloads).
                let _ = w;
            }
            (_, Msg::Heartbeat) => {}
            (from, msg) => {
                log::warn!("reactor: unexpected {op:?} from {from:?}", op = msg.op());
            }
        }
    }

    /// A registered peer disconnected.
    pub fn on_disconnect(&mut self, origin: Origin, out: &mut Vec<(Dest, Msg)>) {
        if let Origin::Worker(w) = origin {
            if let Some(meta) = self.workers.get_mut(w.idx()) {
                meta.connected = false;
            }
            if let Some(run) = self.run.take() {
                let lost = run.tasks_on(w);
                if !lost.is_empty() || run.who_has.iter().flatten().any(|&h| h == w) {
                    self.msgs_out += 1;
                    out.push((
                        Dest::Client(run.client),
                        Msg::GraphFailed { reason: format!("worker {w} disconnected with {} tasks", lost.len()) },
                    ));
                } else {
                    // Worker held nothing for this run; keep going.
                    self.run = Some(run);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{merge, tree};
    use crate::protocol::TaskFinishedInfo;
    use crate::scheduler;
    use crate::taskgraph::TaskGraph;
    use std::collections::HashMap;

    fn reactor(sched: &str) -> Reactor {
        Reactor::new(
            scheduler::by_name(sched, 42).unwrap(),
            RuntimeProfile::rust(),
            false,
        )
    }

    fn register(r: &mut Reactor, n_workers: u32) -> Vec<(Dest, Msg)> {
        let mut out = Vec::new();
        r.on_message(Origin::Unregistered { conn: 0 }, Msg::RegisterClient { name: "c".into() }, &mut out);
        for i in 0..n_workers {
            r.on_message(
                Origin::Unregistered { conn: 1 + i as u64 },
                Msg::RegisterWorker {
                    name: format!("w{i}"),
                    ncores: 1,
                    node: i / 24,
                    data_addr: format!("127.0.0.1:{}", 9000 + i),
                },
                &mut out,
            );
        }
        out
    }

    /// Drive a graph to completion with instantly-finishing fake workers.
    /// Returns (makespan report, per-worker executed counts).
    fn drive(r: &mut Reactor, graph: TaskGraph) -> (ReactorReport, HashMap<WorkerId, u64>) {
        let mut out = Vec::new();
        r.on_message(Origin::Client(0), Msg::SubmitGraph { graph }, &mut out);
        let mut executed: HashMap<WorkerId, u64> = HashMap::new();
        let mut done = None;
        // Worker inboxes: FIFO per worker, like a TCP stream.
        let mut inboxes: HashMap<WorkerId, Vec<Msg>> = HashMap::new();
        loop {
            for (dest, msg) in std::mem::take(&mut out) {
                match dest {
                    Dest::Worker(w) => inboxes.entry(w).or_default().push(msg),
                    Dest::Client(_) => {
                        if let Msg::GraphDone { .. } = msg {
                            done = Some(msg);
                        }
                    }
                }
            }
            // Pick any worker with queued messages and process its first.
            let Some((&w, _)) = inboxes.iter().find(|(_, q)| !q.is_empty()) else {
                break;
            };
            let msg = inboxes.get_mut(&w).unwrap().remove(0);
            match msg {
                Msg::ComputeTask { task, output_size, .. } => {
                    *executed.entry(w).or_default() += 1;
                    r.on_message(
                        Origin::Worker(w),
                        Msg::TaskFinished(TaskFinishedInfo {
                            task,
                            nbytes: output_size,
                            duration_us: 1,
                        }),
                        &mut out,
                    );
                }
                Msg::StealRequest { task } => {
                    // Fake worker: always retractable.
                    r.on_message(
                        Origin::Worker(w),
                        Msg::StealResponse { task, ok: true },
                        &mut out,
                    );
                }
                Msg::Welcome { .. } => {}
                other => panic!("worker got {other:?}"),
            }
            if done.is_some() && inboxes.values().all(|q| q.is_empty()) && out.is_empty() {
                break;
            }
        }
        assert!(done.is_some(), "graph must complete");
        (r.reports().last().unwrap().clone(), executed)
    }

    #[test]
    fn registration_assigns_ids() {
        let mut r = reactor("random");
        let out = register(&mut r, 3);
        let welcomes: Vec<_> = out
            .iter()
            .filter(|(d, _)| matches!(d, Dest::Worker(_)))
            .collect();
        assert_eq!(welcomes.len(), 3);
        assert_eq!(r.n_workers(), 3);
    }

    #[test]
    fn merge_runs_to_completion_random() {
        let mut r = reactor("random");
        register(&mut r, 4);
        let (report, executed) = drive(&mut r, merge(200));
        assert_eq!(report.n_tasks, 201);
        assert_eq!(executed.values().sum::<u64>(), 201);
        // Random spread: every worker got something.
        assert_eq!(executed.len(), 4);
    }

    #[test]
    fn merge_runs_to_completion_ws() {
        let mut r = reactor("ws");
        register(&mut r, 4);
        let (report, executed) = drive(&mut r, merge(200));
        assert_eq!(executed.values().sum::<u64>(), 201);
        assert_eq!(report.n_tasks, 201);
    }

    #[test]
    fn tree_respects_dependencies() {
        // The fake worker finishes instantly, so correctness = completion:
        // a dependency violation would deadlock or panic dep counting.
        for sched in ["random", "ws", "dask-ws"] {
            let mut r = reactor(sched);
            register(&mut r, 6);
            let (report, executed) = drive(&mut r, tree(7));
            assert_eq!(report.n_tasks, 127, "{sched}");
            assert_eq!(executed.values().sum::<u64>(), 127, "{sched}");
        }
    }

    #[test]
    fn sequential_graphs_reuse_cluster() {
        let mut r = reactor("ws");
        register(&mut r, 2);
        let (r1, _) = drive(&mut r, merge(50));
        let (r2, _) = drive(&mut r, tree(5));
        assert_eq!(r1.n_tasks, 51);
        assert_eq!(r2.n_tasks, 31);
        assert_eq!(r.reports().len(), 2);
    }

    #[test]
    fn worker_disconnect_fails_running_graph() {
        let mut r = reactor("ws");
        register(&mut r, 2);
        let mut out = Vec::new();
        r.on_message(Origin::Client(0), Msg::SubmitGraph { graph: merge(10) }, &mut out);
        // Don't let workers reply; kill one instead.
        out.clear();
        r.on_disconnect(Origin::Worker(WorkerId(0)), &mut out);
        assert!(
            out.iter().any(|(d, m)| *d == Dest::Client(0) && matches!(m, Msg::GraphFailed { .. })),
            "client must learn about the failure: {out:?}"
        );
    }

    #[test]
    fn task_error_fails_graph() {
        let mut r = reactor("random");
        register(&mut r, 1);
        let mut out = Vec::new();
        r.on_message(Origin::Client(0), Msg::SubmitGraph { graph: merge(5) }, &mut out);
        out.clear();
        r.on_message(
            Origin::Worker(WorkerId(0)),
            Msg::TaskErred { task: TaskId(0), error: "boom".into() },
            &mut out,
        );
        assert!(matches!(out[0].1, Msg::GraphFailed { .. }));
    }

    #[test]
    fn report_counts_messages_and_steals() {
        let mut r = reactor("ws");
        register(&mut r, 4);
        let (report, _) = drive(&mut r, merge(100));
        assert!(report.msgs_in >= 101, "at least one status msg per task");
        assert!(report.msgs_out >= 101, "at least one assignment per task");
        assert!(report.aot_us > 0.0);
    }
}
