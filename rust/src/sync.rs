//! Synchronization primitives for the lock-protected cores, swappable
//! between `std::sync` and the [`crate::modelcheck`] explorer.
//!
//! Production builds (`cfg(not(loom))`) re-export std directly — zero
//! overhead, identical types. Under `RUSTFLAGS="--cfg loom"` the same
//! paths resolve to the model-checked versions, whose every lock,
//! unlock, wait, notify and atomic access is a schedule point for the
//! exhaustive interleaving explorer (see `docs/verification.md` and
//! `tests/loom_models.rs`). Outside an active [`crate::modelcheck::model`]
//! run the instrumented types behave exactly like std (passthrough), so a
//! `--cfg loom` build of the full library still works.
//!
//! Code under model checking must route *all* of its blocking through
//! this module: a thread blocked in a raw `std::sync` primitive is
//! invisible to the explorer's scheduler and will be reported as a
//! deadlock. Channels (`std::sync::mpsc`) are deliberately not shimmed —
//! the modelled cores only ever use their non-blocking sends, which the
//! explorer tolerates (no interleaving is explored at a send, which only
//! narrows, never widens, the behaviours we test).

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(not(loom))]
pub use std::sync::atomic;

#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use std::sync::Arc;

#[cfg(loom)]
pub use crate::modelcheck::{atomic, thread, Condvar, Mutex, MutexGuard, WaitTimeoutResult};
