//! Simulator tests: conservation invariants, analytic cross-checks, and the
//! paper's qualitative phenomena (overhead collapse, scheduler cost scaling,
//! zero-worker behavior).

use super::*;
use crate::graphgen::{merge, merge_slow, tree};
use crate::overhead::RuntimeProfile;
use crate::taskgraph::{GraphBuilder, Payload};

fn cfg(workers: usize, profile: RuntimeProfile, sched: &str) -> SimConfig {
    SimConfig {
        n_workers: workers,
        profile,
        scheduler: sched.into(),
        ..SimConfig::default()
    }
}

#[test]
fn single_worker_makespan_close_to_total_work() {
    // 100 tasks × 10 ms on one worker ⇒ total work plus the (Dask) worker's
    // per-task overhead, plus small server costs.
    let g = merge_slow(100, 10_000);
    let profile = RuntimeProfile::rust();
    let expected =
        g.total_work_us() as f64 + g.len() as f64 * profile.worker_task_overhead_us;
    let r = simulate(&g, &cfg(1, profile, "ws"));
    assert!(r.makespan_us >= expected, "{} < {}", r.makespan_us, expected);
    assert!(
        r.makespan_us < expected * 1.10,
        "1-worker server overhead should be small: {} vs {}",
        r.makespan_us,
        expected
    );
    assert!(!r.timed_out);
}

#[test]
fn parallel_speedup_on_embarrassing_graph() {
    let g = merge_slow(480, 10_000); // 4.8 s of work
    let r1 = simulate(&g, &cfg(1, RuntimeProfile::rust(), "ws"));
    let r24 = simulate(&g, &cfg(24, RuntimeProfile::rust(), "ws"));
    let speedup = r1.makespan_us / r24.makespan_us;
    assert!(speedup > 10.0, "24 workers speedup only {speedup:.1}×");
}

#[test]
fn pooled_links_charge_peer_latency_once_per_gather() {
    // Wide fan-in across two nodes: the sink gathers many inputs held by
    // the other node's worker. With pooled links (the PR 10 data plane)
    // the per-fetch setup latency is paid once per holder per gather;
    // the connect-per-fetch baseline pays it per object. Bytes moved are
    // identical — only the setup cost differs.
    let mut b = GraphBuilder::new();
    let ids: Vec<_> = (0..32)
        .map(|i| b.add(format!("p{i}"), vec![], 1_000, 10_000, Payload::BusyWait))
        .collect();
    b.add("sink", ids, 1_000, 64, Payload::MergeInputs);
    let g = b.build("fanin").unwrap();

    let mut base = cfg(2, RuntimeProfile::rust(), "ws");
    base.workers_per_node = 1;
    assert!(base.network.pooled_links, "pooled data plane is the default");
    let pooled = simulate(&g, &base);
    let mut unpooled_cfg = base.clone();
    unpooled_cfg.network.pooled_links = false;
    let unpooled = simulate(&g, &unpooled_cfg);

    assert!(!pooled.timed_out && !unpooled.timed_out);
    assert_eq!(pooled.bytes_transferred, unpooled.bytes_transferred);
    assert!(
        pooled.makespan_us + base.network.latency_us <= unpooled.makespan_us,
        "batched gather must save at least one setup latency: {} vs {}",
        pooled.makespan_us,
        unpooled.makespan_us
    );
}

#[test]
fn dependencies_respected_chain() {
    // A chain cannot go faster than its critical path on any cluster.
    let mut b = GraphBuilder::new();
    let mut prev = None;
    for i in 0..50 {
        let inputs = prev.map(|p| vec![p]).unwrap_or_default();
        prev = Some(b.add(format!("c{i}"), inputs, 1_000, 100, Payload::BusyWait));
    }
    let g = b.build("chain").unwrap();
    for sched in ["random", "ws", "dask-ws"] {
        let r = simulate(&g, &cfg(24, RuntimeProfile::rust(), sched));
        assert!(
            r.makespan_us >= 50_000.0,
            "{sched}: chain makespan {} under critical path",
            r.makespan_us
        );
    }
}

#[test]
fn all_schedulers_complete_all_graphs() {
    for g in [merge(300), tree(7), crate::graphgen::xarray(25)] {
        for sched in ["random", "ws", "dask-ws"] {
            for profile in [RuntimeProfile::rust(), RuntimeProfile::python()] {
                let r = simulate(&g, &cfg(24, profile, sched));
                assert!(!r.timed_out, "{} with {sched} timed out", g.name);
                assert_eq!(r.n_tasks, g.len() as u64);
            }
        }
    }
}

#[test]
fn python_profile_slower_than_rust_on_short_tasks() {
    // The paper's core claim: on merge (tiny tasks) the runtime overhead
    // dominates, so the Dask profile must lose clearly.
    // At 24 workers both are largely worker-bound (the paper's 1.28×
    // geomean); at 168 the Dask server saturates and the gap opens.
    let g = merge(5_000);
    let dask24 = simulate(&g, &cfg(24, RuntimeProfile::python(), "dask-ws"));
    let rsds24 = simulate(&g, &cfg(24, RuntimeProfile::rust(), "ws"));
    let s24 = dask24.makespan_us / rsds24.makespan_us;
    assert!(s24 > 1.0, "rsds must win at 24 workers: {s24:.2}×");
    let dask168 = simulate(&g, &cfg(168, RuntimeProfile::python(), "dask-ws"));
    let rsds168 = simulate(&g, &cfg(168, RuntimeProfile::rust(), "ws"));
    let s168 = dask168.makespan_us / rsds168.makespan_us;
    assert!(s168 > 1.5, "gap must open with workers: {s168:.2}×");
    assert!(s168 > s24, "speedup grows with cluster size");
}

#[test]
fn long_tasks_equalize_servers() {
    // With 1 s tasks both servers scale (Fig 5, merge_slow-20K-1s): the gap
    // must shrink to ~1×.
    let g = merge_slow(480, 1_000_000);
    let dask = simulate(&g, &cfg(240, RuntimeProfile::python(), "dask-ws"));
    let rsds = simulate(&g, &cfg(240, RuntimeProfile::rust(), "ws"));
    let speedup = dask.makespan_us / rsds.makespan_us;
    assert!(
        (0.9..2.0).contains(&speedup),
        "1 s tasks should roughly equalize: {speedup:.2}×"
    );
}

#[test]
fn zero_worker_isolates_server_overhead() {
    let g = merge(2_000);
    let real = simulate(&g, &cfg(24, RuntimeProfile::rust(), "ws"));
    let zero = simulate(
        &g,
        &SimConfig { zero_worker: true, ..cfg(24, RuntimeProfile::rust(), "ws") },
    );
    assert!(zero.makespan_us < real.makespan_us, "zero worker must be faster");
    assert_eq!(zero.bytes_transferred, 0, "zero worker has no data plane");
    // AOT must land in the paper's RSDS band (tens of µs).
    assert!(
        (1.0..200.0).contains(&zero.aot_us),
        "rsds zero-worker AOT {} µs",
        zero.aot_us
    );
}

#[test]
fn zero_worker_python_aot_matches_paper_band() {
    // Fig 7/8 + Dask manual: "about 1ms of overhead" per task; measured
    // AOT mostly 0.15–1 ms under the zero worker.
    let g = merge(2_000);
    let zero = simulate(
        &g,
        &SimConfig { zero_worker: true, ..cfg(24, RuntimeProfile::python(), "dask-ws") },
    );
    assert!(
        (150.0..1_200.0).contains(&zero.aot_us),
        "dask zero-worker AOT {} µs",
        zero.aot_us
    );
}

#[test]
fn ws_overhead_grows_with_workers_random_does_not() {
    // Fig 8 (bottom): random's AOT stays ~constant with more workers,
    // work-stealing's grows.
    let g = merge(2_000);
    let aot = |sched: &str, workers: usize| {
        simulate(
            &g,
            &SimConfig {
                zero_worker: true,
                ..cfg(workers, RuntimeProfile::python(), sched)
            },
        )
        .aot_us
    };
    let rand_growth = aot("random", 960) / aot("random", 24);
    let ws_growth = aot("dask-ws", 960) / aot("dask-ws", 24);
    assert!(rand_growth < 1.5, "random AOT grew {rand_growth:.2}× with workers");
    assert!(
        ws_growth > 1.5 && ws_growth > rand_growth * 1.5,
        "ws AOT grew only {ws_growth:.2}× with workers (random {rand_growth:.2}×)"
    );
}

#[test]
fn deterministic_given_seed() {
    let g = merge(500);
    let a = simulate(&g, &cfg(24, RuntimeProfile::rust(), "random"));
    let b = simulate(&g, &cfg(24, RuntimeProfile::rust(), "random"));
    assert_eq!(a.makespan_us, b.makespan_us);
    assert_eq!(a.msgs, b.msgs);
}

#[test]
fn timeout_reports_and_caps() {
    let g = merge_slow(100, 1_000_000); // 100 s of work
    let mut c = cfg(1, RuntimeProfile::rust(), "ws");
    c.timeout_us = 1e6; // 1 s cap
    let r = simulate(&g, &c);
    assert!(r.timed_out);
    assert!((r.makespan_us - 1e6).abs() < 1.0);
}

#[test]
fn message_conservation() {
    // Every task needs ≥1 assignment and ≥1 status message.
    let g = merge(1_000);
    let r = simulate(&g, &cfg(24, RuntimeProfile::rust(), "random"));
    assert!(r.msgs >= 2 * 1_001, "msgs {}", r.msgs);
    assert_eq!(r.steals_attempted, 0, "random never steals");
}

#[test]
fn transfers_happen_only_across_workers() {
    // Single worker: all data local, no transfers.
    let g = tree(6);
    let r = simulate(&g, &cfg(1, RuntimeProfile::rust(), "ws"));
    assert_eq!(r.bytes_transferred, 0);
    // Many workers with random placement: transfers must occur.
    let r = simulate(&g, &cfg(24, RuntimeProfile::rust(), "random"));
    assert!(r.bytes_transferred > 0);
}

#[test]
fn no_task_runs_twice_even_with_non_id_priorities() {
    // Regression (steal-race #1): `StealArrive` used to reconstruct the
    // worker-queue key as `priority == task.id`. Under a scheduler with
    // different priorities (ws-lifo) a "successful" retraction left a ghost
    // entry behind, and the task executed on both the victim and the steal
    // target. After the fix, executions == tasks for every scheduler.
    let mut saw_steals = false;
    for g in [tree(8), merge(2_000), crate::graphgen::xarray(25)] {
        for sched in ["ws", "ws-lifo", "dask-ws"] {
            let r = simulate(&g, &cfg(24, RuntimeProfile::rust(), sched));
            assert!(!r.timed_out, "{}/{sched}", g.name);
            saw_steals |= r.steals_attempted > 0;
            assert_eq!(
                r.tasks_executed,
                g.len() as u64,
                "{}/{sched}: every task must execute exactly once",
                g.name
            );
        }
    }
    assert!(saw_steals, "property is vacuous: no configuration stole at all");
}

#[test]
fn finish_beating_steal_response_resolves_the_steal() {
    // Regression (steal-race #2): when a task finished while its
    // retraction was in flight, the engine dropped the steal record and the
    // late StealResponse returned without `steal_result(.., false)` — the
    // scheduler's in-flight set leaked the task forever. With 100 µs
    // control latency and ~6 µs tasks, finishes overtake steal responses
    // constantly; after the fix every steal is resolved at quiescence.
    let mut saw_steals = false;
    for seed in [1u64, 7, 2020] {
        for (g, workers) in [(merge(3_000), 24), (tree(9), 48), (merge(800), 168)] {
            for sched in ["ws", "ws-lifo", "dask-ws"] {
                let mut c = cfg(workers, RuntimeProfile::rust(), sched);
                c.seed = seed;
                let r = simulate(&g, &c);
                assert!(!r.timed_out, "{}/{sched}", g.name);
                saw_steals |= r.steals_attempted > 0;
                assert_eq!(
                    r.in_flight_steals_at_end, 0,
                    "{}/{sched}/seed{seed}: scheduler leaked in-flight steals \
                     ({} attempted, {} failed)",
                    g.name, r.steals_attempted, r.steals_failed
                );
            }
        }
    }
    assert!(saw_steals, "property is vacuous: no configuration stole at all");
}

#[test]
fn concurrent_graphs_all_complete_with_isolated_state() {
    // Multi-graph engine: several graphs with *identical dense TaskIds*
    // share the cluster; every run completes, executes each task exactly
    // once, and per-run makespans are at least the single-run makespan
    // shape (contention can only slow runs down).
    let graphs: Vec<_> = (0..4).map(|_| merge(400)).collect();
    for sched in ["random", "ws", "dask-ws"] {
        let c = cfg(24, RuntimeProfile::rust(), sched);
        let solo = simulate(&graphs[0], &c);
        let multi = simulate_concurrent(&graphs, &c);
        assert!(!multi.timed_out, "{sched}");
        assert_eq!(multi.runs.len(), 4);
        for run in &multi.runs {
            assert_eq!(run.n_tasks, 401, "{sched}");
            assert_eq!(run.tasks_executed, 401, "{sched}: task aliased across runs?");
            assert!(
                run.makespan_us >= solo.makespan_us * 0.99,
                "{sched}: contended run faster than solo ({} vs {})",
                run.makespan_us,
                solo.makespan_us
            );
        }
        assert_eq!(multi.in_flight_steals_at_end, 0, "{sched}");
    }
}

#[test]
fn single_graph_multi_api_matches_simulate() {
    let g = merge(500);
    let c = cfg(24, RuntimeProfile::rust(), "ws");
    let single = simulate(&g, &c);
    let multi = simulate_concurrent(std::slice::from_ref(&g), &c);
    assert_eq!(single.makespan_us, multi.makespan_us);
    assert_eq!(single.msgs, multi.msgs);
    assert_eq!(single.steals_attempted, multi.steals_attempted);
}

#[test]
fn contention_grows_with_client_count() {
    // The fig9 premise: more concurrent clients ⇒ per-run AOT degrades,
    // because the shared server serializes message handling.
    let aot_at = |n: usize| {
        let graphs: Vec<_> = (0..n).map(|_| merge(600)).collect();
        let r = simulate_concurrent(&graphs, &cfg(24, RuntimeProfile::python(), "dask-ws"));
        assert!(!r.timed_out);
        r.runs.iter().map(|x| x.aot_us).sum::<f64>() / n as f64
    };
    let one = aot_at(1);
    let eight = aot_at(8);
    assert!(
        eight > one,
        "8 concurrent clients must see worse per-run AOT: {one:.1} vs {eight:.1} µs"
    );
}

// ---- injected worker death / lineage recovery (PR 3 tentpole) ----

/// Kill one worker at ~30 % of the clean run's makespan — guaranteed
/// mid-run, deterministic, graph-agnostic.
fn kill_cfg(base: &SimConfig, clean_makespan_us: f64, worker: u32) -> SimConfig {
    SimConfig {
        kill: Some(WorkerKill { worker, at_us: clean_makespan_us * 0.3 }),
        ..base.clone()
    }
}

#[test]
fn injected_kill_recovers_and_completes() {
    let g = merge_slow(200, 5_000);
    for sched in ["random", "ws", "dask-ws"] {
        let base = cfg(4, RuntimeProfile::rust(), sched);
        let clean = simulate(&g, &base);
        assert!(!clean.timed_out);
        assert_eq!(clean.recoveries, 0, "{sched}: clean run must not recover");
        let killed = simulate(&g, &kill_cfg(&base, clean.makespan_us, 0));
        assert!(!killed.timed_out, "{sched}: killed run timed out");
        assert_eq!(killed.n_tasks, g.len() as u64, "{sched}");
        assert!(killed.recoveries >= 1, "{sched}: kill mid-run must trigger recovery");
        assert!(
            killed.tasks_executed >= killed.n_tasks,
            "{sched}: every task ran at least once"
        );
        assert_eq!(killed.in_flight_steals_at_end, 0, "{sched}: steals all resolved");
        assert!(
            killed.makespan_us >= clean.makespan_us * 0.8,
            "{sched}: losing a quarter of the cluster can't speed things up \
             ({} vs clean {})",
            killed.makespan_us,
            clean.makespan_us
        );
    }
}

#[test]
fn injected_kill_recomputes_lost_interior_outputs() {
    // A linear chain under ws locality runs entirely on one worker, so
    // every finished output lives only there. Killing that worker mid-run
    // forces a transitive recompute of the finished prefix (visible as
    // re-executions), and the run still completes on the survivor.
    let mut b = GraphBuilder::new();
    let mut prev = None;
    for i in 0..40 {
        let inputs = prev.map(|p| vec![p]).unwrap_or_default();
        prev = Some(b.add(format!("c{i}"), inputs, 2_000, 100, Payload::BusyWait));
    }
    let g = b.build("chain").unwrap();
    let base = cfg(2, RuntimeProfile::rust(), "ws");
    let clean = simulate(&g, &base);
    assert!(!clean.timed_out);
    let mut any_recomputed = false;
    for w in 0..2 {
        let killed = simulate(&g, &kill_cfg(&base, clean.makespan_us, w));
        assert!(!killed.timed_out, "kill w{w}");
        assert_eq!(killed.n_tasks, g.len() as u64, "kill w{w}");
        any_recomputed |= killed.tasks_executed > killed.n_tasks;
    }
    assert!(
        any_recomputed,
        "killing the chain's worker must recompute the finished prefix"
    );
}

#[test]
fn injected_kill_is_deterministic() {
    let g = merge_slow(100, 2_000);
    let base = cfg(4, RuntimeProfile::rust(), "ws");
    let clean = simulate(&g, &base);
    let a = simulate(&g, &kill_cfg(&base, clean.makespan_us, 1));
    let b = simulate(&g, &kill_cfg(&base, clean.makespan_us, 1));
    assert_eq!(a.makespan_us, b.makespan_us);
    assert_eq!(a.msgs, b.msgs);
    assert_eq!(a.tasks_executed, b.tasks_executed);
    assert_eq!(a.recoveries, b.recoveries);
}

#[test]
fn injected_kill_with_concurrent_runs_completes_all() {
    let graphs: Vec<_> = (0..3).map(|_| merge_slow(120, 2_000)).collect();
    let base = cfg(6, RuntimeProfile::rust(), "ws");
    let clean = simulate_concurrent(&graphs, &base);
    assert!(!clean.timed_out);
    let killed = simulate_concurrent(
        &graphs,
        &SimConfig {
            kill: Some(WorkerKill { worker: 2, at_us: clean.makespan_us * 0.3 }),
            ..base
        },
    );
    assert!(!killed.timed_out);
    for run in &killed.runs {
        assert!(!run.timed_out, "{}", run.name);
        assert!(run.tasks_executed >= run.n_tasks, "{}", run.name);
    }
    assert_eq!(killed.in_flight_steals_at_end, 0);
}

#[test]
fn kill_after_completion_changes_nothing() {
    let g = merge(300);
    let base = cfg(4, RuntimeProfile::rust(), "ws");
    let clean = simulate(&g, &base);
    let late = simulate(
        &g,
        &SimConfig {
            kill: Some(WorkerKill { worker: 0, at_us: clean.makespan_us * 10.0 }),
            ..base
        },
    );
    assert_eq!(late.makespan_us, clean.makespan_us);
    assert_eq!(late.recoveries, 0);
}

// ---- run-fair dispatch (PR 4 tentpole) ----

/// A large background run plus several latency-sensitive small runs — the
/// `fig_fairness` workload shape.
fn fairness_workload() -> Vec<crate::taskgraph::TaskGraph> {
    std::iter::once(merge(3_000)).chain((0..4).map(|_| merge(40))).collect()
}

#[test]
fn fairness_policies_all_complete_and_conserve() {
    let graphs = fairness_workload();
    for policy in ["arrival", "rr", "weighted"] {
        let mut c = cfg(8, RuntimeProfile::rust(), "ws");
        c.fairness = policy.into();
        let r = simulate_concurrent(&graphs, &c);
        assert!(!r.timed_out, "{policy}");
        for run in &r.runs {
            assert_eq!(run.tasks_executed, run.n_tasks, "{policy}/{}", run.name);
        }
        assert_eq!(r.in_flight_steals_at_end, 0, "{policy}: leaked steals");
    }
}

#[test]
fn fair_policies_cut_small_run_latency_under_large_load() {
    // The fig_fairness acceptance property, asserted in-tree: under a
    // large background run, round-robin and weighted dispatch must
    // strictly beat the arrival-order baseline on small-run latency.
    let graphs = fairness_workload();
    let small_worst = |policy: &str| {
        let mut c = cfg(8, RuntimeProfile::rust(), "ws");
        c.fairness = policy.into();
        let r = simulate_concurrent(&graphs, &c);
        assert!(!r.timed_out, "{policy}");
        r.runs[1..].iter().map(|x| x.makespan_us).fold(0.0, f64::max)
    };
    let arrival = small_worst("arrival");
    let rr = small_worst("rr");
    let weighted = small_worst("weighted");
    assert!(
        rr < arrival,
        "round-robin must beat arrival order on small-run latency: {rr} vs {arrival}"
    );
    assert!(
        weighted < arrival,
        "weighted must beat arrival order on small-run latency: {weighted} vs {arrival}"
    );
}

#[test]
fn fairness_is_deterministic() {
    let graphs = fairness_workload();
    let mut c = cfg(8, RuntimeProfile::rust(), "ws");
    c.fairness = "rr".into();
    let a = simulate_concurrent(&graphs, &c);
    let b = simulate_concurrent(&graphs, &c);
    assert_eq!(a.makespan_us, b.makespan_us);
    assert_eq!(a.msgs, b.msgs);
}

// ---- incremental graphs / heterogeneous core slots (PR 9 tentpole) ----

use crate::graphgen::split_incremental;
use crate::taskgraph::TaskSpec;

/// Turn extension batches into a run-0 schedule, one batch every
/// `step_us`, the final one closing the run.
fn ext_schedule(exts: Vec<Vec<TaskSpec>>, step_us: f64) -> Vec<ExtBatch> {
    let n = exts.len();
    exts.into_iter()
        .enumerate()
        .map(|(i, tasks)| ExtBatch {
            run: 0,
            at_us: step_us * (i + 1) as f64,
            tasks,
            last: i + 1 == n,
        })
        .collect()
}

#[test]
fn incremental_submission_completes_for_all_schedulers_on_mixed_cores() {
    // The acceptance shape: a graph submitted in ≥3 extensions over a
    // mixed 1/2/4-core cluster completes (with exactly-once execution)
    // under all three schedulers. Byte-identity of outputs is asserted at
    // the reactor/TCP level; the sim asserts the counting invariants.
    let g = merge(600);
    for sched in ["random", "ws", "dask-ws"] {
        let mut c = cfg(6, RuntimeProfile::rust(), sched);
        c.core_mix = vec![1, 2, 4];
        let one_shot = simulate(&g, &c);
        assert!(!one_shot.timed_out, "{sched}");
        let (base, exts) = split_incremental(&g, 4);
        assert!(exts.len() >= 3, "base plus ≥3 extensions");
        let mut inc_cfg = c.clone();
        inc_cfg.extensions = ext_schedule(exts, 1_000.0);
        let inc = simulate(&base, &inc_cfg);
        assert!(!inc.timed_out, "{sched}");
        assert_eq!(inc.n_tasks, g.len() as u64, "{sched}: run grew to the full graph");
        assert_eq!(inc.tasks_executed, inc.n_tasks, "{sched}: exactly-once under extension");
        assert_eq!(inc.in_flight_steals_at_end, 0, "{sched}");
    }
}

#[test]
fn extension_after_base_finished_still_completes() {
    // merge's sink arrives in the last batch and consumes outputs that
    // finished long before — the run idles open, then the late batch
    // lands and completes. Makespan must cover the idle gap.
    let g = merge(50);
    let (base, exts) = split_incremental(&g, 2);
    let mut c = cfg(4, RuntimeProfile::rust(), "ws");
    c.extensions = ext_schedule(exts, 5e6); // 5 s in: base is long done
    let r = simulate(&base, &c);
    assert!(!r.timed_out);
    assert_eq!(r.n_tasks, g.len() as u64);
    assert_eq!(r.tasks_executed, r.n_tasks);
    assert!(r.makespan_us >= 5e6, "completion waits for the late extension");
}

#[test]
fn multicore_tasks_complete_without_oversubscription() {
    // Wide tasks across a 1/2/4-core mix; the engine itself asserts the
    // capacity invariant on every start, so completing is the proof.
    let mut b = GraphBuilder::new();
    for i in 0..120u32 {
        b.add_with_cores(format!("w{i}"), vec![], 2_000, 64, Payload::BusyWait, 1 + (i % 3));
    }
    let g = b.build("hetero").unwrap();
    for sched in ["random", "ws", "dask-ws"] {
        let mut c = cfg(6, RuntimeProfile::rust(), sched);
        c.core_mix = vec![1, 2, 4];
        let r = simulate(&g, &c);
        assert!(!r.timed_out, "{sched}");
        assert_eq!(r.tasks_executed, g.len() as u64, "{sched}");
    }
}

#[test]
fn multi_slot_worker_runs_tasks_concurrently() {
    // One 4-slot worker must beat one 1-slot worker by ~4× on
    // embarrassingly parallel work — the slots genuinely overlap.
    let g = merge_slow(40, 10_000);
    let narrow = simulate(&g, &cfg(1, RuntimeProfile::rust(), "ws"));
    let mut c = cfg(1, RuntimeProfile::rust(), "ws");
    c.core_mix = vec![4];
    let wide = simulate(&g, &c);
    assert!(!narrow.timed_out && !wide.timed_out);
    assert!(
        wide.makespan_us < narrow.makespan_us * 0.5,
        "4 slots only {:.2}× faster",
        narrow.makespan_us / wide.makespan_us
    );
}

#[test]
fn incremental_simulation_is_deterministic() {
    let g = merge(400);
    let run = || {
        let (base, exts) = split_incremental(&g, 4);
        let mut c = cfg(6, RuntimeProfile::rust(), "ws");
        c.core_mix = vec![1, 2, 4];
        c.extensions = ext_schedule(exts, 500.0);
        simulate(&base, &c)
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan_us, b.makespan_us);
    assert_eq!(a.msgs, b.msgs);
    assert_eq!(a.tasks_executed, b.tasks_executed);
}

#[test]
fn ws_moves_less_data_than_random() {
    // The whole point of locality-aware placement (§IV-C).
    let g = crate::graphgen::xarray(25);
    let ws = simulate(&g, &cfg(24, RuntimeProfile::rust(), "ws"));
    let random = simulate(&g, &cfg(24, RuntimeProfile::rust(), "random"));
    assert!(
        ws.bytes_transferred < random.bytes_transferred,
        "ws {} vs random {}",
        ws.bytes_transferred,
        random.bytes_transferred
    );
}
