//! Discrete-event simulator: the paper's experiments at the paper's scale.
//!
//! The real runtime (server + TCP + workers) validates the full code path
//! on this machine; the simulator replays the *same schedulers* and the
//! *same task graphs* against a virtual cluster of up to 63 nodes × 24
//! workers with a calibrated cost model, regenerating the figures the paper
//! measured on the Salomon supercomputer (DESIGN.md §5).
//!
//! Model:
//! - the **server** processes one message at a time (queueing!): each
//!   inbound status and outbound assignment charges the
//!   [`crate::overhead::RuntimeProfile`]'s per-message and per-transition
//!   costs; the
//!   scheduler's algorithmic work is priced via
//!   [`crate::scheduler::SchedCost`] and runs either on the reactor (GIL —
//!   CPython Dask) or on its own thread (RSDS, §IV-A);
//! - **workers** have a configurable number of core slots (one each in
//!   the paper's setting; [`SimConfig`]'s `core_mix` cycles a
//!   heterogeneous mix): pop highest-priority tasks while their `cores`
//!   requirement fits the free slots, fetch missing inputs from peer
//!   workers over the network, burn the task duration plus per-task
//!   worker overhead — multi-core tasks hold several slots and the
//!   engine asserts capacity is never oversubscribed;
//! - **incremental graphs** ([`SimConfig`]'s `extensions`) graft
//!   `submit-extend` batches onto open runs at virtual times, replaying
//!   the reactor's extension path against the same schedulers;
//! - the **network** has per-transfer latency, bandwidth, per-node NIC
//!   serialization, and a same-node fast path;
//! - the **zero worker** mode answers every assignment instantly with no
//!   data plane (§IV-D);
//! - **failure injection** ([`SimConfig`]'s `kill`) deterministically kills
//!   one worker at a virtual tick and replays the reactor's lineage
//!   recovery against the virtual cluster.
//!
//! Ownership and threading: the whole simulation is one single-threaded
//! event loop — the engine owns every scheduler, worker model and queue;
//! determinism comes from the (time, sequence) event ordering, so a given
//! config + seed always reproduces the same run, kills included.

mod engine;
mod network;

pub use engine::{
    simulate, simulate_concurrent, ExtBatch, MultiSimResult, RunSimResult, SimConfig, SimResult,
    WorkerKill,
};
pub use network::NetworkModel;

#[cfg(test)]
mod tests;
