//! The discrete-event engine.

use super::network::{NetworkModel, NicState};
use crate::overhead::RuntimeProfile;
use crate::scheduler::{self, Action, SchedCost, Scheduler, WorkerId, WorkerInfo};
use crate::taskgraph::{TaskGraph, TaskId};
use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, HashMap, HashSet};

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub n_workers: usize,
    /// Workers per physical node (paper: 24).
    pub workers_per_node: usize,
    pub profile: RuntimeProfile,
    /// Scheduler name (`random` | `ws` | `dask-ws`).
    pub scheduler: String,
    pub seed: u64,
    pub network: NetworkModel,
    /// Use the paper's zero worker (§IV-D) instead of the worker model.
    pub zero_worker: bool,
    /// Abort the run after this much virtual time (paper: 300 s).
    pub timeout_us: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_workers: 24,
            workers_per_node: 24,
            profile: RuntimeProfile::rust(),
            scheduler: "ws".into(),
            seed: 2020,
            network: NetworkModel::default(),
            zero_worker: false,
            timeout_us: 300e6,
        }
    }
}

impl SimConfig {
    /// Paper-style constructor: `nodes` × 24 workers.
    pub fn nodes(nodes: usize, profile: RuntimeProfile, scheduler: &str) -> SimConfig {
        SimConfig {
            n_workers: nodes * 24,
            workers_per_node: 24,
            profile,
            scheduler: scheduler.into(),
            ..SimConfig::default()
        }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_us: f64,
    /// Makespan / #tasks — the paper's AOT (§VI-D).
    pub aot_us: f64,
    pub n_tasks: u64,
    pub msgs: u64,
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub bytes_transferred: u64,
    pub sched_cost: SchedCost,
    pub timed_out: bool,
}

/// Time-ordered event key: (time, seq) with deterministic tie-breaking.
#[derive(Debug, PartialEq)]
struct Key(f64, u64);
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

#[derive(Debug)]
enum Event {
    /// Assignment (or steal reassignment) reaches a worker.
    TaskArrive { worker: WorkerId, task: TaskId, priority: i64 },
    /// Worker core may start its next task.
    WorkerWake { worker: WorkerId },
    /// A task finished executing on a worker (local event).
    TaskDone { worker: WorkerId, task: TaskId },
    /// Steal request reaches a worker.
    StealArrive { worker: WorkerId, task: TaskId },
    /// Status/steal-response arrives at the server.
    ServerRecv { msg: ServerMsg },
}

#[derive(Debug)]
enum ServerMsg {
    Finished { worker: WorkerId, task: TaskId, duration_us: u64 },
    StealResponse { worker: WorkerId, task: TaskId, ok: bool },
}

struct SimWorker {
    node: usize,
    /// Queued (not started) tasks, ordered by (priority, id).
    pending: BTreeSet<(i64, TaskId)>,
    pending_set: HashSet<TaskId>,
    core_free_at: f64,
    core_busy: bool,
    /// Outputs present on this worker.
    has: HashSet<TaskId>,
}

struct Engine<'g> {
    graph: &'g TaskGraph,
    cfg: SimConfig,
    scheduler: Box<dyn Scheduler>,
    events: BinaryHeap<Reverse<(Key, usize)>>,
    payloads: Vec<Event>,
    seq: u64,
    now: f64,
    workers: Vec<SimWorker>,
    nics: Vec<NicState>,
    /// Server (reactor) resource.
    reactor_free_at: f64,
    /// Scheduler resource (only used when !profile.gil).
    sched_free_at: f64,
    /// Producer of each finished task.
    produced_by: HashMap<TaskId, WorkerId>,
    unfinished_deps: Vec<u32>,
    finished: Vec<bool>,
    remaining: usize,
    /// Steal targets in flight: task -> (from, to).
    steals: HashMap<TaskId, (WorkerId, WorkerId)>,
    // metrics
    msgs: u64,
    steals_attempted: u64,
    steals_failed: u64,
    bytes_transferred: u64,
    total_cost: SchedCost,
    last_finish_us: f64,
    actions: Vec<Action>,
}

impl<'g> Engine<'g> {
    fn new(graph: &'g TaskGraph, cfg: SimConfig) -> Engine<'g> {
        let mut scheduler =
            scheduler::by_name(&cfg.scheduler, cfg.seed).expect("unknown scheduler");
        let workers: Vec<SimWorker> = (0..cfg.n_workers)
            .map(|i| SimWorker {
                node: i / cfg.workers_per_node,
                pending: BTreeSet::new(),
                pending_set: HashSet::new(),
                core_free_at: 0.0,
                core_busy: false,
                has: HashSet::new(),
            })
            .collect();
        let n_nodes = cfg.n_workers.div_ceil(cfg.workers_per_node).max(1);
        for (i, w) in workers.iter().enumerate() {
            scheduler.add_worker(WorkerInfo {
                id: WorkerId(i as u32),
                ncores: 1,
                node: w.node as u32,
            });
        }
        scheduler.graph_submitted(graph);
        Engine {
            graph,
            cfg,
            scheduler,
            events: BinaryHeap::new(),
            payloads: Vec::new(),
            seq: 0,
            now: 0.0,
            workers,
            nics: vec![NicState::default(); n_nodes],
            reactor_free_at: 0.0,
            sched_free_at: 0.0,
            produced_by: HashMap::new(),
            unfinished_deps: graph.tasks().iter().map(|t| t.inputs.len() as u32).collect(),
            finished: vec![false; graph.len()],
            remaining: graph.len(),
            steals: HashMap::new(),
            msgs: 0,
            steals_attempted: 0,
            steals_failed: 0,
            bytes_transferred: 0,
            total_cost: SchedCost::default(),
            last_finish_us: 0.0,
            actions: Vec::new(),
        }
    }

    fn push(&mut self, at: f64, ev: Event) {
        let idx = self.payloads.len();
        self.payloads.push(ev);
        self.events.push(Reverse((Key(at, self.seq), idx)));
        self.seq += 1;
    }

    /// Charge reactor CPU; returns completion time of the work.
    fn reactor_work(&mut self, arrival: f64, us: f64) -> f64 {
        let start = self.reactor_free_at.max(arrival);
        self.reactor_free_at = start + us;
        self.reactor_free_at
    }

    /// Charge scheduler CPU starting no earlier than `ready`; under GIL the
    /// scheduler shares the reactor resource (§IV-A).
    fn sched_work(&mut self, ready: f64) -> f64 {
        let cost = self.scheduler.take_cost();
        self.total_cost.add(cost);
        let us = cost.to_us(&self.cfg.profile, self.scheduler.kind());
        if self.cfg.profile.gil {
            self.reactor_work(ready, us)
        } else {
            let start = self.sched_free_at.max(ready);
            self.sched_free_at = start + us;
            self.sched_free_at
        }
    }

    /// Emit the scheduler's pending actions; `ready` = when scheduling done.
    fn dispatch_actions(&mut self, ready: f64) {
        let actions = std::mem::take(&mut self.actions);
        let mut t = ready;
        for action in actions {
            match action {
                Action::Assign(a) => {
                    // Encode + send one assignment message.
                    t = self.reactor_work(t, self.cfg.profile.msg_cost_us(192)
                        + self.cfg.profile.task_transition_us);
                    self.msgs += 1;
                    self.push(
                        t + self.cfg.network.control_msg_us(),
                        Event::TaskArrive { worker: a.worker, task: a.task, priority: a.priority },
                    );
                }
                Action::Steal { task, from, to } => {
                    if self.finished[task.idx()] || self.steals.contains_key(&task) {
                        // Stale; report failure so the model re-syncs.
                        self.scheduler.steal_result(task, from, to, false, &mut self.actions);
                        continue;
                    }
                    self.steals.insert(task, (from, to));
                    self.steals_attempted += 1;
                    t = self.reactor_work(t, self.cfg.profile.msg_cost_us(64));
                    self.msgs += 1;
                    self.push(
                        t + self.cfg.network.control_msg_us(),
                        Event::StealArrive { worker: from, task },
                    );
                }
            }
        }
        if !self.actions.is_empty() {
            let done = self.sched_work(t);
            self.dispatch_actions(done);
        }
    }

    /// Start the next pending task on a worker if its core is free.
    fn maybe_start(&mut self, wid: WorkerId) {
        let now = self.now;
        let w = &mut self.workers[wid.idx()];
        if w.core_busy || w.pending.is_empty() {
            return;
        }
        let &(prio, task) = w.pending.iter().next().expect("nonempty");
        w.pending.remove(&(prio, task));
        w.pending_set.remove(&task);
        w.core_busy = true;
        let fetch_start = w.core_free_at.max(now);

        // Fetch missing inputs (parallel fetches; NIC serialization on the
        // sender side; same-node fast path). `graph` is an independent
        // shared borrow, so no clone of the input list is needed (this
        // clone was the sim hot path's top allocation — EXPERIMENTS.md §Perf).
        let my_node = w.node;
        let mut fetch_done = fetch_start;
        let graph = self.graph;
        let spec = graph.task(task);
        for &input in &spec.inputs {
            let has = self.workers[wid.idx()].has.contains(&input);
            if has {
                continue;
            }
            let holder = *self.produced_by.get(&input).expect("input must be finished");
            let bytes = self.graph.task(input).output_size;
            self.bytes_transferred += bytes;
            let holder_node = self.workers[holder.idx()].node;
            let arrive = if holder_node == my_node {
                fetch_start + self.cfg.network.same_node_us(bytes)
            } else {
                let wire_done =
                    self.nics[holder_node].transmit(fetch_start, bytes, self.cfg.network.net_bw);
                wire_done + self.cfg.network.latency_us
            };
            self.workers[wid.idx()].has.insert(input);
            fetch_done = fetch_done.max(arrive);
        }

        let exec_done = fetch_done
            + self.cfg.profile.worker_task_overhead_us
            + spec.duration_us as f64;
        self.workers[wid.idx()].core_free_at = exec_done;
        self.push(exec_done, Event::TaskDone { worker: wid, task });
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::TaskArrive { worker, task, priority } => {
                if self.cfg.zero_worker {
                    // §IV-D: instantly finished, no data plane.
                    self.push(
                        self.now + self.cfg.network.control_msg_us(),
                        Event::ServerRecv {
                            msg: ServerMsg::Finished { worker, task, duration_us: 0 },
                        },
                    );
                    return;
                }
                let w = &mut self.workers[worker.idx()];
                w.pending.insert((priority, task));
                w.pending_set.insert(task);
                self.maybe_start(worker);
            }
            Event::WorkerWake { worker } => {
                self.maybe_start(worker);
            }
            Event::TaskDone { worker, task } => {
                let w = &mut self.workers[worker.idx()];
                w.core_busy = false;
                w.has.insert(task);
                self.push(self.now, Event::WorkerWake { worker });
                let spec_dur = self.graph.task(task).duration_us;
                self.push(
                    self.now + self.cfg.network.control_msg_us(),
                    Event::ServerRecv {
                        msg: ServerMsg::Finished { worker, task, duration_us: spec_dur },
                    },
                );
            }
            Event::StealArrive { worker, task } => {
                // Retraction succeeds iff the task has not started (§IV-C).
                let w = &mut self.workers[worker.idx()];
                let ok = if w.pending_set.remove(&task) {
                    let prio = self
                        .graph
                        .task(task)
                        .id
                        .0 as i64;
                    // Find exact entry (priority == id in our schedulers).
                    w.pending.remove(&(prio, task));
                    true
                } else {
                    false
                };
                self.push(
                    self.now + self.cfg.network.control_msg_us(),
                    Event::ServerRecv { msg: ServerMsg::StealResponse { worker, task, ok } },
                );
            }
            Event::ServerRecv { msg } => {
                self.msgs += 1;
                let arrived = self.now;
                match msg {
                    ServerMsg::Finished { worker, task, duration_us } => {
                        if self.finished[task.idx()] {
                            return;
                        }
                        self.finished[task.idx()] = true;
                        self.remaining -= 1;
                        self.produced_by.insert(task, worker);
                        self.steals.remove(&task);
                        let decode_done = self.reactor_work(
                            arrived,
                            self.cfg.profile.msg_cost_us(128) + self.cfg.profile.task_transition_us,
                        );
                        self.last_finish_us = decode_done;
                        // Readiness bookkeeping.
                        let mut newly_ready = Vec::new();
                        for &c in self.graph.consumers(task) {
                            let d = &mut self.unfinished_deps[c.idx()];
                            *d -= 1;
                            if *d == 0 {
                                newly_ready.push(c);
                            }
                        }
                        self.scheduler.task_finished(
                            task,
                            worker,
                            self.graph.task(task).output_size,
                            duration_us,
                            &mut self.actions,
                        );
                        if !newly_ready.is_empty() {
                            let t = self.reactor_work(
                                decode_done,
                                self.cfg.profile.task_transition_us * newly_ready.len() as f64,
                            );
                            self.scheduler.tasks_ready(&newly_ready, &mut self.actions);
                            let done = self.sched_work(t);
                            self.dispatch_actions(done);
                        } else {
                            let done = self.sched_work(decode_done);
                            self.dispatch_actions(done);
                        }
                    }
                    ServerMsg::StealResponse { worker, task, ok } => {
                        let decode_done =
                            self.reactor_work(arrived, self.cfg.profile.msg_cost_us(64));
                        let Some((from, to)) = self.steals.remove(&task) else {
                            return; // finished first; already handled
                        };
                        debug_assert_eq!(from, worker);
                        if ok {
                            self.scheduler.steal_result(task, from, to, true, &mut self.actions);
                            let done = self.sched_work(decode_done);
                            // Reassign to the steal target.
                            let t = self.reactor_work(
                                done,
                                self.cfg.profile.msg_cost_us(192)
                                    + self.cfg.profile.task_transition_us,
                            );
                            self.msgs += 1;
                            self.push(
                                t + self.cfg.network.control_msg_us(),
                                Event::TaskArrive { worker: to, task, priority: task.0 as i64 },
                            );
                            self.dispatch_actions(t);
                        } else {
                            self.steals_failed += 1;
                            self.scheduler.steal_result(task, from, to, false, &mut self.actions);
                            let done = self.sched_work(decode_done);
                            self.dispatch_actions(done);
                        }
                    }
                }
            }
        }
    }

    fn run(mut self) -> SimResult {
        // Submission: the server ingests the graph and schedules the roots.
        let ingest = self.cfg.profile.task_transition_us * 0.2 * self.graph.len() as f64;
        let t = self.reactor_work(0.0, ingest);
        let roots = self.graph.roots();
        self.scheduler.tasks_ready(&roots, &mut self.actions);
        let done = self.sched_work(t);
        self.dispatch_actions(done);

        let mut timed_out = false;
        while let Some(Reverse((Key(at, _), idx))) = self.events.pop() {
            self.now = at;
            if self.remaining == 0 {
                break;
            }
            if at > self.cfg.timeout_us {
                timed_out = true;
                break;
            }
            // Take the event out without shifting the arena.
            let ev = std::mem::replace(
                &mut self.payloads[idx],
                Event::WorkerWake { worker: WorkerId(0) },
            );
            self.handle(ev);
        }
        assert!(
            timed_out || self.remaining == 0,
            "simulation drained events with {} tasks unfinished",
            self.remaining
        );
        let makespan = if timed_out { self.cfg.timeout_us } else { self.last_finish_us };
        SimResult {
            makespan_us: makespan,
            aot_us: makespan / self.graph.len() as f64,
            n_tasks: self.graph.len() as u64,
            msgs: self.msgs,
            steals_attempted: self.steals_attempted,
            steals_failed: self.steals_failed,
            bytes_transferred: self.bytes_transferred,
            sched_cost: self.total_cost,
            timed_out,
        }
    }
}

/// Run one simulation.
pub fn simulate(graph: &TaskGraph, cfg: &SimConfig) -> SimResult {
    Engine::new(graph, cfg.clone()).run()
}
