//! The discrete-event engine.
//!
//! Multi-graph: [`simulate_concurrent`] runs several task graphs against
//! the *same* virtual cluster and server, one isolated scheduler per run
//! (mirroring the real server's `SchedulerPool`), with every queue and data
//! map keyed by `(run, task)` so recycled dense `TaskId`s never alias
//! across graphs. [`simulate`] is the single-graph special case.
//!
//! Run-fair dispatch: outbound messages park on per-run outboxes and a
//! `ReactorPump` event charges them to the serialized reactor resource in
//! bounded rounds under the same [`crate::server::fairness`] policies the
//! TCP server uses ([`SimConfig::fairness`], round-robin default) — so a
//! huge submission's backlog interleaves with small runs' messages in
//! virtual time exactly as it does on the wire.
//!
//! Failure injection: [`SimConfig::kill`] deterministically kills one
//! worker at a virtual-time tick, exercising the same lineage recovery the
//! real reactor performs (`server/reactor.rs`): lost queue entries and the
//! running task are re-placed through `Scheduler::task_lost` +
//! `tasks_ready`, outputs whose only copy died are resurrected
//! transitively, assignments and retractions that cross the wire after the
//! death bounce back into the scheduler, and consumers queued elsewhere
//! with evaporated inputs are pulled back (the `cancel-compute`
//! equivalent). Recovery can re-execute tasks whose result was in flight
//! when the worker died, so `tasks_executed` may exceed `n_tasks` on a
//! killed run — duplicate finishes are ignored, mirroring the reactor.

use super::network::{NetworkModel, NicState};
use crate::overhead::RuntimeProfile;
use crate::protocol::RunId;
use crate::scheduler::{self, Action, SchedCost, Scheduler, WorkerId, WorkerInfo};
use crate::server::fairness::{self, FairnessPolicy, RunQueueStat, DEFAULT_DISPATCH_QUOTA};
use crate::taskgraph::{TaskGraph, TaskId, TaskSpec};
use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, HashMap, HashSet, VecDeque};
use std::rc::Rc;

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub n_workers: usize,
    /// Workers per physical node (paper: 24).
    pub workers_per_node: usize,
    pub profile: RuntimeProfile,
    /// Scheduler name (`random` | `ws` | `dask-ws`).
    pub scheduler: String,
    pub seed: u64,
    pub network: NetworkModel,
    /// Use the paper's zero worker (§IV-D) instead of the worker model.
    pub zero_worker: bool,
    /// Abort the run after this much virtual time (paper: 300 s).
    pub timeout_us: f64,
    /// Deterministic failure injection: kill one worker at a virtual tick.
    pub kill: Option<WorkerKill>,
    /// Dispatch fairness policy over concurrent runs (`rr` | `arrival` |
    /// `weighted`) — the same policies the TCP server's reactor uses
    /// ([`crate::server::fairness`]), so sim and runtime stay
    /// behavior-comparable.
    pub fairness: String,
    /// Proactive replica count per hot/critical output, primary included
    /// (1 = off) — the same k the reactor's `with_replication` takes, with
    /// placement mirrored from `replica_targets`, so killed-worker runs
    /// are comparable between sim and TCP runtime.
    pub replication: usize,
    /// Fan-out threshold feeding [`crate::taskgraph::replication_hints`].
    pub replication_fanout: u32,
    /// Per-worker core counts, cycled over the worker index (empty = all
    /// 1-core, the homogeneous default). `[1, 2, 4]` gives worker 0 one
    /// core, worker 1 two, worker 2 four, worker 3 one again, … — the
    /// heterogeneity `fig_dynamic` measures random placement under.
    pub core_mix: Vec<u32>,
    /// Incremental-submission schedule: task batches grafted onto open
    /// runs at virtual times (the sim mirror of `submit-extend`). A run
    /// named by any batch starts *open* and only completes once its
    /// `last: true` batch has been applied and every task finished. The
    /// sim's data plane never self-evicts, so the server's re-pin /
    /// resurrect machinery has no virtual counterpart here — extension
    /// inputs are always fetchable from their producer.
    pub extensions: Vec<ExtBatch>,
}

/// One `submit-extend` batch in virtual time (see [`SimConfig::extensions`]).
#[derive(Debug, Clone)]
pub struct ExtBatch {
    /// Index of the run (graph) this batch extends.
    pub run: u32,
    /// Virtual time (µs) at which the batch arrives at the server.
    pub at_us: f64,
    /// Appended task specs; ids must continue the run's id sequence.
    pub tasks: Vec<TaskSpec>,
    /// Closes the run — no further batches.
    pub last: bool,
}

/// Deterministic worker-death injection (recovery at scale, repeatably).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerKill {
    /// Index of the worker to kill.
    pub worker: u32,
    /// Virtual time (µs) of the death.
    pub at_us: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_workers: 24,
            workers_per_node: 24,
            profile: RuntimeProfile::rust(),
            scheduler: "ws".into(),
            seed: 2020,
            network: NetworkModel::default(),
            zero_worker: false,
            timeout_us: 300e6,
            kill: None,
            fairness: "rr".into(),
            replication: 1,
            replication_fanout: crate::server::DEFAULT_REPLICATION_FANOUT,
            core_mix: Vec::new(),
            extensions: Vec::new(),
        }
    }
}

impl SimConfig {
    /// Paper-style constructor: `nodes` × 24 workers.
    pub fn nodes(nodes: usize, profile: RuntimeProfile, scheduler: &str) -> SimConfig {
        SimConfig {
            n_workers: nodes * 24,
            workers_per_node: 24,
            profile,
            scheduler: scheduler.into(),
            ..SimConfig::default()
        }
    }

    /// Core count of worker `i` under [`SimConfig::core_mix`].
    pub fn worker_cores(&self, i: usize) -> u32 {
        if self.core_mix.is_empty() {
            1
        } else {
            self.core_mix[i % self.core_mix.len()].max(1)
        }
    }
}

/// Simulation outcome (single graph).
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_us: f64,
    /// Makespan / #tasks — the paper's AOT (§VI-D).
    pub aot_us: f64,
    pub n_tasks: u64,
    pub msgs: u64,
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub bytes_transferred: u64,
    pub sched_cost: SchedCost,
    pub timed_out: bool,
    /// Task executions observed. On a clean run, > n_tasks would mean a
    /// steal race made a worker run a retracted task twice; on a run with
    /// an injected kill, recovery legitimately re-executes lost work.
    pub tasks_executed: u64,
    /// Steals the schedulers still considered unresolved at the end; any
    /// nonzero value means the engine dropped a steal notification.
    pub in_flight_steals_at_end: usize,
    /// Per-run lineage-recovery passes performed after worker deaths.
    pub recoveries: u64,
}

/// Per-run outcome of a concurrent simulation.
#[derive(Debug, Clone)]
pub struct RunSimResult {
    pub name: String,
    pub n_tasks: u64,
    /// Submission (t = 0) → last finish of this run.
    pub makespan_us: f64,
    pub aot_us: f64,
    pub tasks_executed: u64,
    pub timed_out: bool,
}

/// Outcome of a multi-graph simulation: per-run results plus cluster-wide
/// aggregates (messages and steals are server-global, like the paper's
/// measurements).
#[derive(Debug, Clone)]
pub struct MultiSimResult {
    pub runs: Vec<RunSimResult>,
    /// Last finish across all runs.
    pub makespan_us: f64,
    pub msgs: u64,
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub bytes_transferred: u64,
    pub sched_cost: SchedCost,
    pub timed_out: bool,
    pub in_flight_steals_at_end: usize,
    /// Per-run lineage-recovery passes performed after worker deaths.
    pub recoveries: u64,
}

/// Time-ordered event key: (time, seq) with deterministic tie-breaking.
#[derive(Debug, PartialEq)]
struct Key(f64, u64);
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

#[derive(Debug)]
enum Event {
    /// Assignment (or steal reassignment) reaches a worker.
    TaskArrive { run: u32, worker: WorkerId, task: TaskId, priority: i64 },
    /// Worker core may start its next task.
    WorkerWake { worker: WorkerId },
    /// A task finished executing on a worker (local event).
    TaskDone { run: u32, worker: WorkerId, task: TaskId },
    /// Steal request reaches a worker.
    StealArrive { run: u32, worker: WorkerId, task: TaskId },
    /// Status/steal-response arrives at the server.
    ServerRecv { msg: ServerMsg },
    /// Injected failure: the worker dies (queue, running task and stored
    /// outputs evaporate); the server reacts with lineage recovery.
    WorkerDie { worker: WorkerId },
    /// One fairness round: the policy picks a run with parked outbound
    /// messages and up to a quota of them are charged to the reactor
    /// resource and put on the wire — the virtual-time mirror of
    /// `Reactor::pump`.
    ReactorPump,
    /// A `submit-extend` batch arrives for an open run
    /// ([`SimConfig::extensions`]).
    Extend { run: u32, tasks: Vec<TaskSpec>, last: bool },
}

/// An outbound message translated from a scheduler action (state already
/// applied — e.g. the steal is registered in `steals`) but not yet charged
/// to the reactor resource; the fairness unit, parked per run.
#[derive(Debug, Clone, Copy)]
enum ParkedOut {
    Assign { worker: WorkerId, task: TaskId, priority: i64, ready: f64 },
    Steal { victim: WorkerId, task: TaskId, ready: f64 },
}

#[derive(Debug)]
enum ServerMsg {
    Finished { run: u32, worker: WorkerId, task: TaskId, duration_us: u64 },
    /// `priority` is the retracted entry's priority (meaningful iff `ok`) so
    /// the reassignment keeps the scheduler-chosen order — the engine must
    /// not reinvent it as `task.id`.
    StealResponse { run: u32, worker: WorkerId, task: TaskId, ok: bool, priority: i64 },
}

struct SimWorker {
    node: usize,
    /// Queued (not started) tasks, ordered by (priority, run, id).
    pending: BTreeSet<(i64, u32, TaskId)>,
    /// Priority each queued task was enqueued with — the exact queue key,
    /// required to retract entries whose priority differs from `task.id`.
    pending_prio: HashMap<(u32, TaskId), i64>,
    /// Core-slot capacity ([`SimConfig::core_mix`]).
    ncores: u32,
    /// Slots held by currently executing tasks; [`Engine::maybe_start`]
    /// gates the queue head on `ncores - used_cores`, mirroring the real
    /// worker's `TaskQueue::with_cores` slot gate.
    used_cores: u32,
    /// Tasks currently executing (needed to requeue them if the worker
    /// dies) — up to `ncores` single-core tasks at once.
    running: HashSet<(u32, TaskId)>,
    /// False once an injected kill fired; a dead worker receives nothing
    /// and answers nothing.
    alive: bool,
    /// Outputs present on this worker (hot-path membership check only).
    has: HashSet<(u32, TaskId)>,
}

/// One submitted graph's execution state (scheduler isolated per run).
///
/// The graph is held by `Rc` so hot-path handlers can take an independent
/// handle (a pointer copy, no allocation) while mutating the rest of the
/// engine — and so `submit-extend` batches can grow it in place through
/// `Rc::make_mut` on the cold extension path.
struct RunCtx {
    graph: Rc<TaskGraph>,
    scheduler: Box<dyn Scheduler>,
    unfinished_deps: Vec<u32>,
    finished: Vec<bool>,
    remaining: usize,
    last_finish_us: f64,
    tasks_executed: u64,
    /// Still accepting `submit-extend` batches; an open run is not done
    /// even at `remaining == 0`.
    open: bool,
    /// Per-task replication flags ([`crate::taskgraph::replication_hints`]);
    /// empty when `SimConfig::replication` is 1.
    hints: Vec<bool>,
}

struct Engine {
    cfg: SimConfig,
    runs: Vec<RunCtx>,
    events: BinaryHeap<Reverse<(Key, usize)>>,
    payloads: Vec<Event>,
    seq: u64,
    now: f64,
    workers: Vec<SimWorker>,
    nics: Vec<NicState>,
    /// Server (reactor) resource.
    reactor_free_at: f64,
    /// Scheduler resource (only used when !profile.gil).
    sched_free_at: f64,
    /// Producer of each finished task.
    produced_by: HashMap<(u32, TaskId), WorkerId>,
    remaining_total: usize,
    /// Runs still open to `submit-extend` batches; the drain condition is
    /// `remaining_total == 0 && open_runs == 0`.
    open_runs: usize,
    /// Steal targets in flight: (run, task) -> (from, to).
    steals: HashMap<(u32, TaskId), (WorkerId, WorkerId)>,
    // metrics
    msgs: u64,
    steals_attempted: u64,
    steals_failed: u64,
    bytes_transferred: u64,
    /// Per-run lineage-recovery passes after injected worker deaths.
    recoveries: u64,
    total_cost: SchedCost,
    actions: Vec<Action>,
    /// Dispatch-order policy over the per-run outboxes (same trait as the
    /// TCP server).
    policy: Box<dyn FairnessPolicy>,
    /// Parked outbound messages per run, FIFO.
    outboxes: Vec<VecDeque<ParkedOut>>,
    /// Tick at which each outbox last became non-empty.
    outbox_since: Vec<u64>,
    outbox_seq: u64,
    /// One pump event outstanding at a time.
    pump_scheduled: bool,
}

impl Engine {
    fn new(graphs: &[TaskGraph], cfg: SimConfig) -> Engine {
        assert!(!graphs.is_empty(), "at least one graph to simulate");
        let workers: Vec<SimWorker> = (0..cfg.n_workers)
            .map(|i| SimWorker {
                node: i / cfg.workers_per_node,
                pending: BTreeSet::new(),
                pending_prio: HashMap::new(),
                ncores: cfg.worker_cores(i),
                used_cores: 0,
                running: HashSet::new(),
                alive: true,
                has: HashSet::new(),
            })
            .collect();
        let n_nodes = cfg.n_workers.div_ceil(cfg.workers_per_node).max(1);
        let runs: Vec<RunCtx> = graphs
            .iter()
            .enumerate()
            .map(|(i, graph)| {
                // Run-decorrelated seed, like the server's SchedulerPool.
                let mut scheduler =
                    scheduler::by_name(&cfg.scheduler, cfg.seed.wrapping_add(i as u64))
                        .expect("unknown scheduler");
                for (w, worker) in workers.iter().enumerate() {
                    scheduler.add_worker(WorkerInfo {
                        id: WorkerId(w as u32),
                        ncores: worker.ncores,
                        node: worker.node as u32,
                    });
                }
                scheduler.graph_submitted(graph);
                RunCtx {
                    graph: Rc::new(graph.clone()),
                    scheduler,
                    unfinished_deps: graph.tasks().iter().map(|t| t.inputs.len() as u32).collect(),
                    finished: vec![false; graph.len()],
                    remaining: graph.len(),
                    last_finish_us: 0.0,
                    tasks_executed: 0,
                    open: cfg.extensions.iter().any(|b| b.run as usize == i),
                    hints: if cfg.replication > 1 {
                        crate::taskgraph::replication_hints(graph, cfg.replication_fanout)
                    } else {
                        Vec::new()
                    },
                }
            })
            .collect();
        let remaining_total = runs.iter().map(|r| r.remaining).sum();
        let open_runs = runs.iter().filter(|r| r.open).count();
        let policy = fairness::by_name(&cfg.fairness)
            .unwrap_or_else(|| panic!("unknown fairness policy {:?}", cfg.fairness));
        let n_runs = runs.len();
        let mut engine = Engine {
            cfg,
            runs,
            events: BinaryHeap::new(),
            payloads: Vec::new(),
            seq: 0,
            now: 0.0,
            workers,
            nics: vec![NicState::default(); n_nodes],
            reactor_free_at: 0.0,
            sched_free_at: 0.0,
            produced_by: HashMap::new(),
            remaining_total,
            open_runs,
            steals: HashMap::new(),
            msgs: 0,
            steals_attempted: 0,
            steals_failed: 0,
            bytes_transferred: 0,
            recoveries: 0,
            total_cost: SchedCost::default(),
            actions: Vec::new(),
            policy,
            outboxes: vec![VecDeque::new(); n_runs],
            outbox_since: vec![0; n_runs],
            outbox_seq: 0,
            pump_scheduled: false,
        };
        if let Some(kill) = engine.cfg.kill {
            assert!(
                (kill.worker as usize) < engine.cfg.n_workers,
                "kill.worker {} out of range (n_workers {})",
                kill.worker,
                engine.cfg.n_workers
            );
            engine.push(kill.at_us, Event::WorkerDie { worker: WorkerId(kill.worker) });
        }
        let batches = std::mem::take(&mut engine.cfg.extensions);
        for b in batches {
            assert!(
                (b.run as usize) < engine.runs.len(),
                "extension names run {} of {}",
                b.run,
                engine.runs.len()
            );
            engine.push(b.at_us, Event::Extend { run: b.run, tasks: b.tasks, last: b.last });
        }
        engine
    }

    fn push(&mut self, at: f64, ev: Event) {
        let idx = self.payloads.len();
        self.payloads.push(ev);
        self.events.push(Reverse((Key(at, self.seq), idx)));
        self.seq += 1;
    }

    /// Charge reactor CPU; returns completion time of the work.
    fn reactor_work(&mut self, arrival: f64, us: f64) -> f64 {
        let start = self.reactor_free_at.max(arrival);
        self.reactor_free_at = start + us;
        self.reactor_free_at
    }

    /// Charge one run's scheduler CPU starting no earlier than `ready`;
    /// under GIL the scheduler shares the reactor resource (§IV-A).
    fn sched_work(&mut self, run: u32, ready: f64) -> f64 {
        let cost = self.runs[run as usize].scheduler.take_cost();
        self.total_cost.add(cost);
        let kind = self.runs[run as usize].scheduler.kind();
        let us = cost.to_us(&self.cfg.profile, kind);
        if self.cfg.profile.gil {
            self.reactor_work(ready, us)
        } else {
            let start = self.sched_free_at.max(ready);
            self.sched_free_at = start + us;
            self.sched_free_at
        }
    }

    /// Park an outbound message on a run's outbox (fairness unit; the
    /// reactor-resource charge happens in the pump rounds).
    fn park(&mut self, run: u32, msg: ParkedOut) {
        let q = &mut self.outboxes[run as usize];
        if q.is_empty() {
            self.outbox_since[run as usize] = self.outbox_seq;
            self.outbox_seq += 1;
        }
        q.push_back(msg);
    }

    /// Ensure a pump event is on the heap while any outbox is non-empty.
    fn schedule_pump(&mut self, at: f64) {
        if self.pump_scheduled || self.outboxes.iter().all(VecDeque::is_empty) {
            return;
        }
        self.pump_scheduled = true;
        self.push(at.max(self.reactor_free_at).max(self.now), Event::ReactorPump);
    }

    /// Translate one run's pending actions into parked messages; `ready` =
    /// when scheduling finished. State (steal registration, counters)
    /// applies here, mirroring the reactor's enqueue-time transitions; the
    /// per-message reactor CPU is charged by the pump rounds, in fairness
    /// order across runs — which is what keeps a 100K-task submission from
    /// monopolizing the virtual reactor.
    fn dispatch_actions(&mut self, run: u32, ready: f64) {
        let mut ready = ready;
        loop {
            let actions = std::mem::take(&mut self.actions);
            if actions.is_empty() {
                break;
            }
            for action in actions {
                match action {
                    Action::Assign(a) => {
                        self.park(
                            run,
                            ParkedOut::Assign {
                                worker: a.worker,
                                task: a.task,
                                priority: a.priority,
                                ready,
                            },
                        );
                    }
                    Action::Steal { task, from, to } => {
                        if self.runs[run as usize].finished[task.idx()]
                            || self.steals.contains_key(&(run, task))
                        {
                            // Stale; report failure so the model re-syncs.
                            self.runs[run as usize]
                                .scheduler
                                .steal_result(task, from, to, false, &mut self.actions);
                            continue;
                        }
                        self.steals.insert((run, task), (from, to));
                        self.steals_attempted += 1;
                        self.park(run, ParkedOut::Steal { victim: from, task, ready });
                    }
                }
            }
            if self.actions.is_empty() {
                break;
            }
            // Steal feedback emitted more actions: charge the scheduler
            // and translate those too.
            ready = self.sched_work(run, ready);
        }
        self.schedule_pump(ready);
    }

    /// One fairness round (the virtual `Reactor::pump`): policy-pick a run,
    /// charge up to a quota of its parked messages to the reactor resource
    /// serially, put them on the wire, then reschedule while work remains.
    fn handle_pump(&mut self) {
        self.pump_scheduled = false;
        let stats: Vec<RunQueueStat> = self
            .outboxes
            .iter()
            .enumerate()
            .filter(|(_, q)| !q.is_empty())
            .map(|(i, q)| RunQueueStat {
                run: RunId(i as u32),
                pending: q.len(),
                remaining: self.runs[i].remaining as u64,
                since: self.outbox_since[i],
            })
            .collect();
        if stats.is_empty() {
            return;
        }
        let pick = self.policy.pick(&stats).0 as usize;
        for _ in 0..DEFAULT_DISPATCH_QUOTA {
            let Some(msg) = self.outboxes[pick].pop_front() else { break };
            match msg {
                ParkedOut::Assign { worker, task, priority, ready } => {
                    let t = self.reactor_work(
                        ready.max(self.now),
                        self.cfg.profile.msg_cost_us(192) + self.cfg.profile.task_transition_us,
                    );
                    self.msgs += 1;
                    self.push(
                        t + self.cfg.network.control_msg_us(),
                        Event::TaskArrive { run: pick as u32, worker, task, priority },
                    );
                }
                ParkedOut::Steal { victim, task, ready } => {
                    let t = self
                        .reactor_work(ready.max(self.now), self.cfg.profile.msg_cost_us(64));
                    self.msgs += 1;
                    self.push(
                        t + self.cfg.network.control_msg_us(),
                        Event::StealArrive { run: pick as u32, worker: victim, task },
                    );
                }
            }
        }
        self.schedule_pump(self.now);
    }

    /// Start pending tasks on a worker while core slots are free. Strict
    /// priority order with a slot gate, mirroring the real worker's
    /// `TaskQueue::with_cores`: the queue head waits for enough free slots
    /// rather than being jumped by a narrower task behind it, and a task
    /// wider than the whole machine runs alone when the worker is idle.
    fn maybe_start(&mut self, wid: WorkerId) {
        let now = self.now;
        loop {
            let (run, task) = {
                let w = &self.workers[wid.idx()];
                if !w.alive || w.pending.is_empty() {
                    return;
                }
                let &(prio, run, task) = w.pending.iter().next().expect("nonempty");
                let cores = self.runs[run as usize].graph.task(task).cores.max(1);
                let w = &mut self.workers[wid.idx()];
                if w.used_cores > 0 && cores > w.ncores.saturating_sub(w.used_cores) {
                    return;
                }
                w.pending.remove(&(prio, run, task));
                w.pending_prio.remove(&(run, task));
                w.used_cores += cores;
                w.running.insert((run, task));
                // The acceptance invariant: multi-core tasks never
                // oversubscribe a worker's capacity. The only allowed
                // excursion is a single task wider than the machine
                // (possible after the cluster shrinks), which runs alone.
                assert!(
                    w.used_cores <= w.ncores || w.running.len() == 1,
                    "worker {} oversubscribed: {} of {} core slots in use",
                    wid.idx(),
                    w.used_cores,
                    w.ncores
                );
                (run, task)
            };
            let fetch_start = now;

            // Fetch missing inputs (parallel fetches; NIC serialization on
            // the sender side; same-node fast path). `graph` is an
            // independent `Rc` handle — a pointer copy, so the input list
            // is still not cloned (that clone was the sim hot path's top
            // allocation — EXPERIMENTS.md §Perf).
            let my_node = self.workers[wid.idx()].node;
            let mut fetch_done = fetch_start;
            let graph = Rc::clone(&self.runs[run as usize].graph);
            let spec = graph.task(task);
            // Pooled-link parity (worker/dataplane.rs): one persistent
            // link per peer and one coalesced fetch-data-many batch per
            // gather means the per-fetch setup latency is charged once
            // per distinct remote holder, not once per object. The Vec
            // only allocates when a gather actually crosses nodes.
            let pooled = self.cfg.network.pooled_links;
            let mut latency_paid: Vec<WorkerId> = Vec::new();
            for &input in &spec.inputs {
                let has = self.workers[wid.idx()].has.contains(&(run, input));
                if has {
                    continue;
                }
                let holder =
                    *self.produced_by.get(&(run, input)).expect("input must be finished");
                let bytes = graph.task(input).output_size;
                self.bytes_transferred += bytes;
                let holder_node = self.workers[holder.idx()].node;
                let arrive = if holder_node == my_node {
                    fetch_start + self.cfg.network.same_node_us(bytes)
                } else {
                    let wire_done = self.nics[holder_node].transmit(
                        fetch_start,
                        bytes,
                        self.cfg.network.net_bw,
                    );
                    let latency = if pooled && latency_paid.contains(&holder) {
                        0.0
                    } else {
                        if pooled {
                            latency_paid.push(holder);
                        }
                        self.cfg.network.latency_us
                    };
                    wire_done + latency
                };
                self.workers[wid.idx()].has.insert((run, input));
                fetch_done = fetch_done.max(arrive);
            }

            let exec_done = fetch_done
                + self.cfg.profile.worker_task_overhead_us
                + spec.duration_us as f64;
            self.push(exec_done, Event::TaskDone { run, worker: wid, task });
        }
    }

    /// Injected worker death: mirror the reactor's lineage recovery
    /// (`server/reactor.rs::on_disconnect`) against the virtual cluster.
    fn handle_worker_death(&mut self, worker: WorkerId) {
        let widx = worker.idx();
        if !self.workers[widx].alive {
            return;
        }
        self.workers[widx].alive = false;
        assert!(
            self.workers.iter().any(|w| w.alive),
            "injected kill removed the last worker; nothing to recover onto"
        );
        // The corpse's queue, running tasks and stored outputs evaporate.
        let pending: Vec<(i64, u32, TaskId)> =
            std::mem::take(&mut self.workers[widx].pending).into_iter().collect();
        self.workers[widx].pending_prio.clear();
        let running: Vec<(u32, TaskId)> =
            std::mem::take(&mut self.workers[widx].running).into_iter().collect();
        self.workers[widx].used_cores = 0;
        self.workers[widx].has.clear();
        // Every run's scheduler forgets the worker before any re-placement.
        for r in &mut self.runs {
            r.scheduler.remove_worker(worker);
        }
        // Lost in-flight work. Retractions headed TO the corpse never
        // answer, so those steals dissolve here; steals whose *target*
        // died resolve naturally — the live victim answers and the
        // reassignment bounces off the dead target (`TaskArrive` on a dead
        // worker) back into the scheduler.
        let mut lost: BTreeSet<(u32, TaskId)> =
            pending.into_iter().map(|(_, run, t)| (run, t)).collect();
        lost.extend(running);
        let dead_victim: Vec<((u32, TaskId), (WorkerId, WorkerId))> = self
            .steals
            .iter()
            .filter(|(_, &(from, _))| from == worker)
            .map(|(&k, &v)| (k, v))
            .collect();
        let mut dissolved: HashMap<u32, Vec<(TaskId, WorkerId, WorkerId)>> = HashMap::new();
        for ((run, task), (from, to)) in dead_victim {
            self.steals.remove(&(run, task));
            lost.insert((run, task));
            dissolved.entry(run).or_default().push((task, from, to));
        }
        // Outputs whose producer record names the corpse: rewire to a live
        // replica (some consumer fetched a copy) or resurrect. A single
        // pass suffices: any output whose data lived only on the corpse
        // has `produced_by == worker`, and a resurrected task's inputs are
        // either alive-produced or orphans in this same list.
        let orphans: Vec<(u32, TaskId)> = self
            .produced_by
            .iter()
            .filter(|(_, &w)| w == worker)
            .map(|(&k, _)| k)
            .collect();
        let mut resurrect: Vec<(u32, TaskId)> = Vec::new();
        for key in orphans {
            let replica = self
                .workers
                .iter()
                .enumerate()
                .find(|(_, w)| w.alive && w.has.contains(&key))
                .map(|(i, _)| WorkerId(i as u32));
            match replica {
                Some(v) => {
                    self.produced_by.insert(key, v);
                }
                None => resurrect.push(key),
            }
        }
        resurrect.sort_unstable();
        // Phase 1: un-finish every resurrected output (all at once, so the
        // consumer-dep bump below is order-independent).
        for &(run, t) in &resurrect {
            let r = run as usize;
            debug_assert!(self.runs[r].finished[t.idx()]);
            self.runs[r].finished[t.idx()] = false;
            self.runs[r].remaining += 1;
            self.remaining_total += 1;
        }
        // Phase 2: consumers of resurrected outputs regain an unfinished
        // dep; queued copies on live workers are pulled back (the
        // `cancel-compute` equivalent — they would fetch from the corpse)
        // and re-enter via normal readiness once the input is recomputed.
        for &(run, t) in &resurrect {
            let r = run as usize;
            let consumers: Vec<TaskId> = self.runs[r].graph.consumers(t).to_vec();
            for c in consumers {
                if self.runs[r].finished[c.idx()] {
                    continue;
                }
                self.runs[r].unfinished_deps[c.idx()] += 1;
                for (i, w) in self.workers.iter_mut().enumerate() {
                    if !w.alive {
                        continue;
                    }
                    if let Some(prio) = w.pending_prio.remove(&(run, c)) {
                        w.pending.remove(&(prio, run, c));
                        self.runs[r].scheduler.task_lost(c, WorkerId(i as u32));
                    }
                }
            }
        }
        // Phase 3: per affected run — sync the scheduler and re-seed what
        // is ready again. (Actions are per-run, so each run's batch is
        // dispatched before the next run is touched.)
        let mut by_run: HashMap<u32, Vec<TaskId>> = HashMap::new();
        for &(run, t) in lost.iter().chain(resurrect.iter()) {
            by_run.entry(run).or_default().push(t);
        }
        let mut touched: Vec<u32> = by_run
            .keys()
            .copied()
            .chain(dissolved.keys().copied())
            .collect();
        touched.sort_unstable();
        touched.dedup();
        for run in touched {
            self.recoveries += 1;
            let r = run as usize;
            for &(task, from, to) in dissolved.get(&run).into_iter().flatten() {
                self.steals_failed += 1;
                self.runs[r]
                    .scheduler
                    .steal_result(task, from, to, false, &mut self.actions);
            }
            let mut ready: Vec<TaskId> = Vec::new();
            for &t in by_run.get(&run).into_iter().flatten() {
                self.runs[r].scheduler.task_lost(t, worker);
                if !self.runs[r].finished[t.idx()]
                    && self.runs[r].unfinished_deps[t.idx()] == 0
                {
                    ready.push(t);
                }
            }
            ready.sort_unstable();
            ready.dedup();
            let t = self.reactor_work(
                self.now,
                self.cfg.profile.task_transition_us * ready.len().max(1) as f64,
            );
            if !ready.is_empty() {
                self.runs[r].scheduler.tasks_ready(&ready, &mut self.actions);
            }
            let done = self.sched_work(run, t);
            self.dispatch_actions(run, done);
        }
    }

    /// A `submit-extend` batch lands: grow the run's graph in place, seed
    /// readiness for the new tasks (dependencies on already-finished
    /// outputs count as satisfied immediately — the sim's data plane never
    /// evicts, so there is nothing to re-pin), and close the run on
    /// `last`. The virtual mirror of `Reactor::handle_extend`.
    fn handle_extend(&mut self, run: u32, tasks: Vec<TaskSpec>, last: bool) {
        let r = run as usize;
        assert!(self.runs[r].open, "extension for a closed run {run}");
        let base = self.runs[r].graph.len();
        let n_new = tasks.len();
        if n_new > 0 {
            Rc::make_mut(&mut self.runs[r].graph)
                .extend(tasks)
                .expect("invalid extension batch");
        }
        let graph = Rc::clone(&self.runs[r].graph);
        {
            let ctx = &mut self.runs[r];
            ctx.finished.resize(base + n_new, false);
            for t in &graph.tasks()[base..] {
                // Intra-batch deps (ids ≥ base) read `false` from the
                // freshly grown `finished`, so they count as unfinished.
                let d = t.inputs.iter().filter(|dep| !ctx.finished[dep.idx()]).count();
                ctx.unfinished_deps.push(d as u32);
            }
            ctx.remaining += n_new;
            if self.cfg.replication > 1 {
                ctx.hints =
                    crate::taskgraph::replication_hints(&graph, self.cfg.replication_fanout);
            }
            ctx.scheduler.graph_extended(&graph);
            if last {
                ctx.open = false;
            }
        }
        self.remaining_total += n_new;
        if last {
            self.open_runs -= 1;
        }
        let ready: Vec<TaskId> = graph.tasks()[base..]
            .iter()
            .filter(|t| self.runs[r].unfinished_deps[t.id.idx()] == 0)
            .map(|t| t.id)
            .collect();
        // Ingest cost scales with the batch, like the initial submission.
        let t = self.reactor_work(
            self.now,
            self.cfg.profile.task_transition_us * 0.2 * n_new.max(1) as f64,
        );
        if !ready.is_empty() {
            self.runs[r].scheduler.tasks_ready(&ready, &mut self.actions);
        }
        let done = self.sched_work(run, t);
        self.dispatch_actions(run, done);
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::TaskArrive { run, worker, task, priority } => {
                if !self.workers[worker.idx()].alive {
                    // The assignment crossed the wire after the worker
                    // died: it never reached a queue — the server re-places
                    // it (the reactor's cancel-and-resend equivalent).
                    let r = run as usize;
                    if self.runs[r].finished[task.idx()] {
                        return; // a surviving copy already finished it
                    }
                    self.runs[r].scheduler.task_lost(task, worker);
                    if self.runs[r].unfinished_deps[task.idx()] == 0 {
                        let t = self
                            .reactor_work(self.now, self.cfg.profile.task_transition_us);
                        self.runs[r].scheduler.tasks_ready(&[task], &mut self.actions);
                        let done = self.sched_work(run, t);
                        self.dispatch_actions(run, done);
                    }
                    // Otherwise an input is being recomputed; normal
                    // readiness re-offers the task when it lands.
                    return;
                }
                {
                    // Stale assignments on LIVE workers: an in-flight
                    // message can race a recovery that resurrected one of
                    // the task's inputs (unfinished deps again) or a
                    // duplicate copy that already finished it. This is the
                    // in-flight equivalent of `cancel-compute`: drop it
                    // rather than execute against evaporated data. On a
                    // clean run deps are always 0 at arrival, so this
                    // never fires.
                    let r = run as usize;
                    if self.runs[r].finished[task.idx()] {
                        return;
                    }
                    if self.runs[r].unfinished_deps[task.idx()] > 0 {
                        self.runs[r].scheduler.task_lost(task, worker);
                        return; // readiness re-offers it after recompute
                    }
                }
                if self.cfg.zero_worker {
                    // §IV-D: instantly finished, no data plane.
                    self.runs[run as usize].tasks_executed += 1;
                    self.push(
                        self.now + self.cfg.network.control_msg_us(),
                        Event::ServerRecv {
                            msg: ServerMsg::Finished { run, worker, task, duration_us: 0 },
                        },
                    );
                    return;
                }
                let w = &mut self.workers[worker.idx()];
                w.pending.insert((priority, run, task));
                w.pending_prio.insert((run, task), priority);
                self.maybe_start(worker);
            }
            Event::WorkerWake { worker } => {
                self.maybe_start(worker);
            }
            Event::TaskDone { run, worker, task } => {
                if !self.workers[worker.idx()].alive {
                    return; // died mid-execution; the death requeued it
                }
                let (spec_dur, cores) = {
                    let s = self.runs[run as usize].graph.task(task);
                    (s.duration_us, s.cores.max(1))
                };
                let w = &mut self.workers[worker.idx()];
                w.used_cores = w.used_cores.saturating_sub(cores);
                w.running.remove(&(run, task));
                w.has.insert((run, task));
                self.runs[run as usize].tasks_executed += 1;
                self.push(self.now, Event::WorkerWake { worker });
                self.push(
                    self.now + self.cfg.network.control_msg_us(),
                    Event::ServerRecv {
                        msg: ServerMsg::Finished { run, worker, task, duration_us: spec_dur },
                    },
                );
            }
            Event::StealArrive { run, worker, task } => {
                // Retraction succeeds iff the task has not started (§IV-C).
                // The queue entry's key is the *enqueued* priority, which a
                // scheduler may choose freely — reconstructing it as
                // `task.id` would leave a ghost entry that runs the task a
                // second time.
                let w = &mut self.workers[worker.idx()];
                if !w.alive {
                    // The corpse answers nothing; the steal was dissolved
                    // when the death was processed.
                    return;
                }
                let (ok, priority) = match w.pending_prio.remove(&(run, task)) {
                    Some(prio) => {
                        let removed = w.pending.remove(&(prio, run, task));
                        debug_assert!(
                            removed,
                            "pending queue/priority-map desync for {task} (prio {prio})"
                        );
                        (true, prio)
                    }
                    None => (false, 0),
                };
                self.push(
                    self.now + self.cfg.network.control_msg_us(),
                    Event::ServerRecv {
                        msg: ServerMsg::StealResponse { run, worker, task, ok, priority },
                    },
                );
            }
            Event::WorkerDie { worker } => self.handle_worker_death(worker),
            Event::ReactorPump => self.handle_pump(),
            Event::Extend { run, tasks, last } => self.handle_extend(run, tasks, last),
            Event::ServerRecv { msg } => {
                self.msgs += 1;
                let arrived = self.now;
                match msg {
                    ServerMsg::Finished { run, worker, task, duration_us } => {
                        let r = run as usize;
                        if self.runs[r].finished[task.idx()] {
                            return;
                        }
                        if !self.workers[worker.idx()].alive {
                            // The result's bytes died with the worker before
                            // the server could advertise them: re-run the
                            // task (its data would be unfetchable).
                            self.runs[r].scheduler.task_lost(task, worker);
                            if self.runs[r].unfinished_deps[task.idx()] == 0 {
                                let t = self.reactor_work(
                                    arrived,
                                    self.cfg.profile.task_transition_us,
                                );
                                self.runs[r]
                                    .scheduler
                                    .tasks_ready(&[task], &mut self.actions);
                                let done = self.sched_work(run, t);
                                self.dispatch_actions(run, done);
                            }
                            return;
                        }
                        self.runs[r].finished[task.idx()] = true;
                        self.runs[r].remaining -= 1;
                        self.remaining_total -= 1;
                        self.produced_by.insert((run, task), worker);
                        // Proactive k-replication, placement mirrored from
                        // the reactor's `replica_targets`: walk the ring
                        // from the producer, skip dead workers and existing
                        // holders, push k-1 copies. A later death of any
                        // single holder then finds a live replica in
                        // `handle_worker_death` instead of resurrecting.
                        if self.cfg.replication > 1
                            && self.runs[r].hints.get(task.idx()).copied().unwrap_or(false)
                        {
                            let n = self.workers.len();
                            let nbytes = self.runs[r].graph.task(task).output_size;
                            let mut want = self.cfg.replication - 1;
                            for off in 1..n {
                                if want == 0 {
                                    break;
                                }
                                let idx = (worker.idx() + off) % n;
                                let w = &mut self.workers[idx];
                                if !w.alive || w.has.contains(&(run, task)) {
                                    continue;
                                }
                                w.has.insert((run, task));
                                self.bytes_transferred += nbytes;
                                self.msgs += 1; // the replica-added ack
                                want -= 1;
                            }
                        }
                        let decode_done = self.reactor_work(
                            arrived,
                            self.cfg.profile.msg_cost_us(128) + self.cfg.profile.task_transition_us,
                        );
                        self.runs[r].last_finish_us = decode_done;
                        // A finish that beats an in-flight retraction
                        // resolves that steal as failed — the scheduler must
                        // hear about it, or its in-flight set leaks for the
                        // rest of the run.
                        if let Some((from, to)) = self.steals.remove(&(run, task)) {
                            self.steals_failed += 1;
                            self.runs[r]
                                .scheduler
                                .steal_result(task, from, to, false, &mut self.actions);
                        }
                        // Readiness bookkeeping. (`graph` is an independent
                        // `Rc` handle, so the deps update can be mutable.)
                        let graph = Rc::clone(&self.runs[r].graph);
                        let mut newly_ready = Vec::new();
                        for &c in graph.consumers(task) {
                            // A consumer can already be finished when a
                            // resurrected input re-finishes (a cancelled
                            // copy reported early); don't re-ready it.
                            if self.runs[r].finished[c.idx()] {
                                continue;
                            }
                            let d = &mut self.runs[r].unfinished_deps[c.idx()];
                            *d -= 1;
                            if *d == 0 {
                                newly_ready.push(c);
                            }
                        }
                        let nbytes = graph.task(task).output_size;
                        self.runs[r].scheduler.task_finished(
                            task,
                            worker,
                            nbytes,
                            duration_us,
                            &mut self.actions,
                        );
                        if !newly_ready.is_empty() {
                            let t = self.reactor_work(
                                decode_done,
                                self.cfg.profile.task_transition_us * newly_ready.len() as f64,
                            );
                            self.runs[r].scheduler.tasks_ready(&newly_ready, &mut self.actions);
                            let done = self.sched_work(run, t);
                            self.dispatch_actions(run, done);
                        } else {
                            let done = self.sched_work(run, decode_done);
                            self.dispatch_actions(run, done);
                        }
                    }
                    ServerMsg::StealResponse { run, worker, task, ok, priority } => {
                        let decode_done =
                            self.reactor_work(arrived, self.cfg.profile.msg_cost_us(64));
                        let Some((from, to)) = self.steals.remove(&(run, task)) else {
                            // The finish won the race; the scheduler was
                            // already notified of the failed steal when the
                            // finish was processed.
                            return;
                        };
                        debug_assert_eq!(from, worker);
                        let r = run as usize;
                        if ok {
                            self.runs[r]
                                .scheduler
                                .steal_result(task, from, to, true, &mut self.actions);
                            let done = self.sched_work(run, decode_done);
                            // Reassign to the steal target, keeping the
                            // scheduler-chosen priority. Parked like any
                            // assignment so it stays FIFO with the run's
                            // other pending messages.
                            self.park(
                                run,
                                ParkedOut::Assign { worker: to, task, priority, ready: done },
                            );
                            self.dispatch_actions(run, done);
                        } else {
                            self.steals_failed += 1;
                            self.runs[r]
                                .scheduler
                                .steal_result(task, from, to, false, &mut self.actions);
                            let done = self.sched_work(run, decode_done);
                            self.dispatch_actions(run, done);
                        }
                    }
                }
            }
        }
    }

    fn run(mut self) -> MultiSimResult {
        // Submissions: the server ingests each graph and schedules its
        // roots; ingest work serializes on the reactor resource, exactly
        // like interleaved client submissions hitting one server thread.
        for i in 0..self.runs.len() {
            let ingest =
                self.cfg.profile.task_transition_us * 0.2 * self.runs[i].graph.len() as f64;
            let t = self.reactor_work(0.0, ingest);
            let roots = self.runs[i].graph.roots();
            self.runs[i].scheduler.tasks_ready(&roots, &mut self.actions);
            let done = self.sched_work(i as u32, t);
            self.dispatch_actions(i as u32, done);
        }

        let mut timed_out = false;
        while let Some(Reverse((Key(at, _), idx))) = self.events.pop() {
            self.now = at;
            if self.remaining_total == 0 && self.open_runs == 0 {
                break;
            }
            if at > self.cfg.timeout_us {
                timed_out = true;
                break;
            }
            // Take the event out without shifting the arena.
            let ev = std::mem::replace(
                &mut self.payloads[idx],
                Event::WorkerWake { worker: WorkerId(0) },
            );
            self.handle(ev);
        }
        assert!(
            timed_out || (self.remaining_total == 0 && self.open_runs == 0),
            "simulation drained events with {} tasks unfinished and {} runs open",
            self.remaining_total,
            self.open_runs
        );
        let in_flight_steals_at_end: usize =
            self.runs.iter().map(|r| r.scheduler.in_flight_steal_count()).sum();
        let runs: Vec<RunSimResult> = self
            .runs
            .iter()
            .map(|r| {
                let run_timed_out = r.remaining > 0 || r.open;
                let makespan =
                    if run_timed_out { self.cfg.timeout_us } else { r.last_finish_us };
                RunSimResult {
                    name: r.graph.name.clone(),
                    n_tasks: r.graph.len() as u64,
                    makespan_us: makespan,
                    aot_us: makespan / r.graph.len() as f64,
                    tasks_executed: r.tasks_executed,
                    timed_out: run_timed_out,
                }
            })
            .collect();
        let makespan = runs.iter().map(|r| r.makespan_us).fold(0.0, f64::max);
        MultiSimResult {
            runs,
            makespan_us: makespan,
            msgs: self.msgs,
            steals_attempted: self.steals_attempted,
            steals_failed: self.steals_failed,
            bytes_transferred: self.bytes_transferred,
            sched_cost: self.total_cost,
            timed_out,
            in_flight_steals_at_end,
            recoveries: self.recoveries,
        }
    }
}

/// Run several graphs concurrently against one shared virtual cluster.
pub fn simulate_concurrent(graphs: &[TaskGraph], cfg: &SimConfig) -> MultiSimResult {
    Engine::new(graphs, cfg.clone()).run()
}

/// Run one simulation.
pub fn simulate(graph: &TaskGraph, cfg: &SimConfig) -> SimResult {
    let multi = Engine::new(std::slice::from_ref(graph), cfg.clone()).run();
    let run = &multi.runs[0];
    SimResult {
        makespan_us: run.makespan_us,
        aot_us: run.aot_us,
        n_tasks: run.n_tasks,
        msgs: multi.msgs,
        steals_attempted: multi.steals_attempted,
        steals_failed: multi.steals_failed,
        bytes_transferred: multi.bytes_transferred,
        sched_cost: multi.sched_cost,
        timed_out: multi.timed_out,
        tasks_executed: run.tasks_executed,
        in_flight_steals_at_end: multi.in_flight_steals_at_end,
        recoveries: multi.recoveries,
    }
}
