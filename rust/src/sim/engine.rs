//! The discrete-event engine.
//!
//! Multi-graph: [`simulate_concurrent`] runs several task graphs against
//! the *same* virtual cluster and server, one isolated scheduler per run
//! (mirroring the real server's `SchedulerPool`), with every queue and data
//! map keyed by `(run, task)` so recycled dense `TaskId`s never alias
//! across graphs. [`simulate`] is the single-graph special case.

use super::network::{NetworkModel, NicState};
use crate::overhead::RuntimeProfile;
use crate::scheduler::{self, Action, SchedCost, Scheduler, WorkerId, WorkerInfo};
use crate::taskgraph::{TaskGraph, TaskId};
use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, HashMap, HashSet};

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub n_workers: usize,
    /// Workers per physical node (paper: 24).
    pub workers_per_node: usize,
    pub profile: RuntimeProfile,
    /// Scheduler name (`random` | `ws` | `dask-ws`).
    pub scheduler: String,
    pub seed: u64,
    pub network: NetworkModel,
    /// Use the paper's zero worker (§IV-D) instead of the worker model.
    pub zero_worker: bool,
    /// Abort the run after this much virtual time (paper: 300 s).
    pub timeout_us: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_workers: 24,
            workers_per_node: 24,
            profile: RuntimeProfile::rust(),
            scheduler: "ws".into(),
            seed: 2020,
            network: NetworkModel::default(),
            zero_worker: false,
            timeout_us: 300e6,
        }
    }
}

impl SimConfig {
    /// Paper-style constructor: `nodes` × 24 workers.
    pub fn nodes(nodes: usize, profile: RuntimeProfile, scheduler: &str) -> SimConfig {
        SimConfig {
            n_workers: nodes * 24,
            workers_per_node: 24,
            profile,
            scheduler: scheduler.into(),
            ..SimConfig::default()
        }
    }
}

/// Simulation outcome (single graph).
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_us: f64,
    /// Makespan / #tasks — the paper's AOT (§VI-D).
    pub aot_us: f64,
    pub n_tasks: u64,
    pub msgs: u64,
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub bytes_transferred: u64,
    pub sched_cost: SchedCost,
    pub timed_out: bool,
    /// Task executions observed (> n_tasks would mean a steal race made a
    /// worker run a retracted task twice).
    pub tasks_executed: u64,
    /// Steals the schedulers still considered unresolved at the end; any
    /// nonzero value means the engine dropped a steal notification.
    pub in_flight_steals_at_end: usize,
}

/// Per-run outcome of a concurrent simulation.
#[derive(Debug, Clone)]
pub struct RunSimResult {
    pub name: String,
    pub n_tasks: u64,
    /// Submission (t = 0) → last finish of this run.
    pub makespan_us: f64,
    pub aot_us: f64,
    pub tasks_executed: u64,
    pub timed_out: bool,
}

/// Outcome of a multi-graph simulation: per-run results plus cluster-wide
/// aggregates (messages and steals are server-global, like the paper's
/// measurements).
#[derive(Debug, Clone)]
pub struct MultiSimResult {
    pub runs: Vec<RunSimResult>,
    /// Last finish across all runs.
    pub makespan_us: f64,
    pub msgs: u64,
    pub steals_attempted: u64,
    pub steals_failed: u64,
    pub bytes_transferred: u64,
    pub sched_cost: SchedCost,
    pub timed_out: bool,
    pub in_flight_steals_at_end: usize,
}

/// Time-ordered event key: (time, seq) with deterministic tie-breaking.
#[derive(Debug, PartialEq)]
struct Key(f64, u64);
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

#[derive(Debug)]
enum Event {
    /// Assignment (or steal reassignment) reaches a worker.
    TaskArrive { run: u32, worker: WorkerId, task: TaskId, priority: i64 },
    /// Worker core may start its next task.
    WorkerWake { worker: WorkerId },
    /// A task finished executing on a worker (local event).
    TaskDone { run: u32, worker: WorkerId, task: TaskId },
    /// Steal request reaches a worker.
    StealArrive { run: u32, worker: WorkerId, task: TaskId },
    /// Status/steal-response arrives at the server.
    ServerRecv { msg: ServerMsg },
}

#[derive(Debug)]
enum ServerMsg {
    Finished { run: u32, worker: WorkerId, task: TaskId, duration_us: u64 },
    /// `priority` is the retracted entry's priority (meaningful iff `ok`) so
    /// the reassignment keeps the scheduler-chosen order — the engine must
    /// not reinvent it as `task.id`.
    StealResponse { run: u32, worker: WorkerId, task: TaskId, ok: bool, priority: i64 },
}

struct SimWorker {
    node: usize,
    /// Queued (not started) tasks, ordered by (priority, run, id).
    pending: BTreeSet<(i64, u32, TaskId)>,
    /// Priority each queued task was enqueued with — the exact queue key,
    /// required to retract entries whose priority differs from `task.id`.
    pending_prio: HashMap<(u32, TaskId), i64>,
    core_free_at: f64,
    core_busy: bool,
    /// Outputs present on this worker (hot-path membership check only).
    has: HashSet<(u32, TaskId)>,
}

/// One submitted graph's execution state (scheduler isolated per run).
struct RunCtx<'g> {
    graph: &'g TaskGraph,
    scheduler: Box<dyn Scheduler>,
    unfinished_deps: Vec<u32>,
    finished: Vec<bool>,
    remaining: usize,
    last_finish_us: f64,
    tasks_executed: u64,
}

struct Engine<'g> {
    cfg: SimConfig,
    runs: Vec<RunCtx<'g>>,
    events: BinaryHeap<Reverse<(Key, usize)>>,
    payloads: Vec<Event>,
    seq: u64,
    now: f64,
    workers: Vec<SimWorker>,
    nics: Vec<NicState>,
    /// Server (reactor) resource.
    reactor_free_at: f64,
    /// Scheduler resource (only used when !profile.gil).
    sched_free_at: f64,
    /// Producer of each finished task.
    produced_by: HashMap<(u32, TaskId), WorkerId>,
    remaining_total: usize,
    /// Steal targets in flight: (run, task) -> (from, to).
    steals: HashMap<(u32, TaskId), (WorkerId, WorkerId)>,
    // metrics
    msgs: u64,
    steals_attempted: u64,
    steals_failed: u64,
    bytes_transferred: u64,
    total_cost: SchedCost,
    actions: Vec<Action>,
}

impl<'g> Engine<'g> {
    fn new(graphs: &'g [TaskGraph], cfg: SimConfig) -> Engine<'g> {
        assert!(!graphs.is_empty(), "at least one graph to simulate");
        let workers: Vec<SimWorker> = (0..cfg.n_workers)
            .map(|i| SimWorker {
                node: i / cfg.workers_per_node,
                pending: BTreeSet::new(),
                pending_prio: HashMap::new(),
                core_free_at: 0.0,
                core_busy: false,
                has: HashSet::new(),
            })
            .collect();
        let n_nodes = cfg.n_workers.div_ceil(cfg.workers_per_node).max(1);
        let runs: Vec<RunCtx<'g>> = graphs
            .iter()
            .enumerate()
            .map(|(i, graph)| {
                // Run-decorrelated seed, like the server's SchedulerPool.
                let mut scheduler =
                    scheduler::by_name(&cfg.scheduler, cfg.seed.wrapping_add(i as u64))
                        .expect("unknown scheduler");
                for (w, worker) in workers.iter().enumerate() {
                    scheduler.add_worker(WorkerInfo {
                        id: WorkerId(w as u32),
                        ncores: 1,
                        node: worker.node as u32,
                    });
                }
                scheduler.graph_submitted(graph);
                RunCtx {
                    graph,
                    scheduler,
                    unfinished_deps: graph.tasks().iter().map(|t| t.inputs.len() as u32).collect(),
                    finished: vec![false; graph.len()],
                    remaining: graph.len(),
                    last_finish_us: 0.0,
                    tasks_executed: 0,
                }
            })
            .collect();
        let remaining_total = runs.iter().map(|r| r.remaining).sum();
        Engine {
            cfg,
            runs,
            events: BinaryHeap::new(),
            payloads: Vec::new(),
            seq: 0,
            now: 0.0,
            workers,
            nics: vec![NicState::default(); n_nodes],
            reactor_free_at: 0.0,
            sched_free_at: 0.0,
            produced_by: HashMap::new(),
            remaining_total,
            steals: HashMap::new(),
            msgs: 0,
            steals_attempted: 0,
            steals_failed: 0,
            bytes_transferred: 0,
            total_cost: SchedCost::default(),
            actions: Vec::new(),
        }
    }

    fn push(&mut self, at: f64, ev: Event) {
        let idx = self.payloads.len();
        self.payloads.push(ev);
        self.events.push(Reverse((Key(at, self.seq), idx)));
        self.seq += 1;
    }

    /// Charge reactor CPU; returns completion time of the work.
    fn reactor_work(&mut self, arrival: f64, us: f64) -> f64 {
        let start = self.reactor_free_at.max(arrival);
        self.reactor_free_at = start + us;
        self.reactor_free_at
    }

    /// Charge one run's scheduler CPU starting no earlier than `ready`;
    /// under GIL the scheduler shares the reactor resource (§IV-A).
    fn sched_work(&mut self, run: u32, ready: f64) -> f64 {
        let cost = self.runs[run as usize].scheduler.take_cost();
        self.total_cost.add(cost);
        let kind = self.runs[run as usize].scheduler.kind();
        let us = cost.to_us(&self.cfg.profile, kind);
        if self.cfg.profile.gil {
            self.reactor_work(ready, us)
        } else {
            let start = self.sched_free_at.max(ready);
            self.sched_free_at = start + us;
            self.sched_free_at
        }
    }

    /// Emit one run's pending actions; `ready` = when scheduling done.
    fn dispatch_actions(&mut self, run: u32, ready: f64) {
        let actions = std::mem::take(&mut self.actions);
        let mut t = ready;
        for action in actions {
            match action {
                Action::Assign(a) => {
                    // Encode + send one assignment message.
                    t = self.reactor_work(t, self.cfg.profile.msg_cost_us(192)
                        + self.cfg.profile.task_transition_us);
                    self.msgs += 1;
                    self.push(
                        t + self.cfg.network.control_msg_us(),
                        Event::TaskArrive { run, worker: a.worker, task: a.task, priority: a.priority },
                    );
                }
                Action::Steal { task, from, to } => {
                    if self.runs[run as usize].finished[task.idx()]
                        || self.steals.contains_key(&(run, task))
                    {
                        // Stale; report failure so the model re-syncs.
                        self.runs[run as usize]
                            .scheduler
                            .steal_result(task, from, to, false, &mut self.actions);
                        continue;
                    }
                    self.steals.insert((run, task), (from, to));
                    self.steals_attempted += 1;
                    t = self.reactor_work(t, self.cfg.profile.msg_cost_us(64));
                    self.msgs += 1;
                    self.push(
                        t + self.cfg.network.control_msg_us(),
                        Event::StealArrive { run, worker: from, task },
                    );
                }
            }
        }
        if !self.actions.is_empty() {
            let done = self.sched_work(run, t);
            self.dispatch_actions(run, done);
        }
    }

    /// Start the next pending task on a worker if its core is free.
    fn maybe_start(&mut self, wid: WorkerId) {
        let now = self.now;
        let w = &mut self.workers[wid.idx()];
        if w.core_busy || w.pending.is_empty() {
            return;
        }
        let &(prio, run, task) = w.pending.iter().next().expect("nonempty");
        w.pending.remove(&(prio, run, task));
        w.pending_prio.remove(&(run, task));
        w.core_busy = true;
        let fetch_start = w.core_free_at.max(now);

        // Fetch missing inputs (parallel fetches; NIC serialization on the
        // sender side; same-node fast path). `graph` is an independent
        // shared borrow, so no clone of the input list is needed (this
        // clone was the sim hot path's top allocation — EXPERIMENTS.md §Perf).
        let my_node = w.node;
        let mut fetch_done = fetch_start;
        let graph = self.runs[run as usize].graph;
        let spec = graph.task(task);
        for &input in &spec.inputs {
            let has = self.workers[wid.idx()].has.contains(&(run, input));
            if has {
                continue;
            }
            let holder = *self.produced_by.get(&(run, input)).expect("input must be finished");
            let bytes = graph.task(input).output_size;
            self.bytes_transferred += bytes;
            let holder_node = self.workers[holder.idx()].node;
            let arrive = if holder_node == my_node {
                fetch_start + self.cfg.network.same_node_us(bytes)
            } else {
                let wire_done =
                    self.nics[holder_node].transmit(fetch_start, bytes, self.cfg.network.net_bw);
                wire_done + self.cfg.network.latency_us
            };
            self.workers[wid.idx()].has.insert((run, input));
            fetch_done = fetch_done.max(arrive);
        }

        let exec_done = fetch_done
            + self.cfg.profile.worker_task_overhead_us
            + spec.duration_us as f64;
        self.workers[wid.idx()].core_free_at = exec_done;
        self.push(exec_done, Event::TaskDone { run, worker: wid, task });
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::TaskArrive { run, worker, task, priority } => {
                if self.cfg.zero_worker {
                    // §IV-D: instantly finished, no data plane.
                    self.runs[run as usize].tasks_executed += 1;
                    self.push(
                        self.now + self.cfg.network.control_msg_us(),
                        Event::ServerRecv {
                            msg: ServerMsg::Finished { run, worker, task, duration_us: 0 },
                        },
                    );
                    return;
                }
                let w = &mut self.workers[worker.idx()];
                w.pending.insert((priority, run, task));
                w.pending_prio.insert((run, task), priority);
                self.maybe_start(worker);
            }
            Event::WorkerWake { worker } => {
                self.maybe_start(worker);
            }
            Event::TaskDone { run, worker, task } => {
                let w = &mut self.workers[worker.idx()];
                w.core_busy = false;
                w.has.insert((run, task));
                self.runs[run as usize].tasks_executed += 1;
                self.push(self.now, Event::WorkerWake { worker });
                let spec_dur = self.runs[run as usize].graph.task(task).duration_us;
                self.push(
                    self.now + self.cfg.network.control_msg_us(),
                    Event::ServerRecv {
                        msg: ServerMsg::Finished { run, worker, task, duration_us: spec_dur },
                    },
                );
            }
            Event::StealArrive { run, worker, task } => {
                // Retraction succeeds iff the task has not started (§IV-C).
                // The queue entry's key is the *enqueued* priority, which a
                // scheduler may choose freely — reconstructing it as
                // `task.id` would leave a ghost entry that runs the task a
                // second time.
                let w = &mut self.workers[worker.idx()];
                let (ok, priority) = match w.pending_prio.remove(&(run, task)) {
                    Some(prio) => {
                        let removed = w.pending.remove(&(prio, run, task));
                        debug_assert!(
                            removed,
                            "pending queue/priority-map desync for {task} (prio {prio})"
                        );
                        (true, prio)
                    }
                    None => (false, 0),
                };
                self.push(
                    self.now + self.cfg.network.control_msg_us(),
                    Event::ServerRecv {
                        msg: ServerMsg::StealResponse { run, worker, task, ok, priority },
                    },
                );
            }
            Event::ServerRecv { msg } => {
                self.msgs += 1;
                let arrived = self.now;
                match msg {
                    ServerMsg::Finished { run, worker, task, duration_us } => {
                        let r = run as usize;
                        if self.runs[r].finished[task.idx()] {
                            return;
                        }
                        self.runs[r].finished[task.idx()] = true;
                        self.runs[r].remaining -= 1;
                        self.remaining_total -= 1;
                        self.produced_by.insert((run, task), worker);
                        let decode_done = self.reactor_work(
                            arrived,
                            self.cfg.profile.msg_cost_us(128) + self.cfg.profile.task_transition_us,
                        );
                        self.runs[r].last_finish_us = decode_done;
                        // A finish that beats an in-flight retraction
                        // resolves that steal as failed — the scheduler must
                        // hear about it, or its in-flight set leaks for the
                        // rest of the run.
                        if let Some((from, to)) = self.steals.remove(&(run, task)) {
                            self.steals_failed += 1;
                            self.runs[r]
                                .scheduler
                                .steal_result(task, from, to, false, &mut self.actions);
                        }
                        // Readiness bookkeeping. (`graph` is an independent
                        // `&'g` borrow, so the deps update can be mutable.)
                        let graph = self.runs[r].graph;
                        let mut newly_ready = Vec::new();
                        for &c in graph.consumers(task) {
                            let d = &mut self.runs[r].unfinished_deps[c.idx()];
                            *d -= 1;
                            if *d == 0 {
                                newly_ready.push(c);
                            }
                        }
                        let nbytes = graph.task(task).output_size;
                        self.runs[r].scheduler.task_finished(
                            task,
                            worker,
                            nbytes,
                            duration_us,
                            &mut self.actions,
                        );
                        if !newly_ready.is_empty() {
                            let t = self.reactor_work(
                                decode_done,
                                self.cfg.profile.task_transition_us * newly_ready.len() as f64,
                            );
                            self.runs[r].scheduler.tasks_ready(&newly_ready, &mut self.actions);
                            let done = self.sched_work(run, t);
                            self.dispatch_actions(run, done);
                        } else {
                            let done = self.sched_work(run, decode_done);
                            self.dispatch_actions(run, done);
                        }
                    }
                    ServerMsg::StealResponse { run, worker, task, ok, priority } => {
                        let decode_done =
                            self.reactor_work(arrived, self.cfg.profile.msg_cost_us(64));
                        let Some((from, to)) = self.steals.remove(&(run, task)) else {
                            // The finish won the race; the scheduler was
                            // already notified of the failed steal when the
                            // finish was processed.
                            return;
                        };
                        debug_assert_eq!(from, worker);
                        let r = run as usize;
                        if ok {
                            self.runs[r]
                                .scheduler
                                .steal_result(task, from, to, true, &mut self.actions);
                            let done = self.sched_work(run, decode_done);
                            // Reassign to the steal target, keeping the
                            // scheduler-chosen priority.
                            let t = self.reactor_work(
                                done,
                                self.cfg.profile.msg_cost_us(192)
                                    + self.cfg.profile.task_transition_us,
                            );
                            self.msgs += 1;
                            self.push(
                                t + self.cfg.network.control_msg_us(),
                                Event::TaskArrive { run, worker: to, task, priority },
                            );
                            self.dispatch_actions(run, t);
                        } else {
                            self.steals_failed += 1;
                            self.runs[r]
                                .scheduler
                                .steal_result(task, from, to, false, &mut self.actions);
                            let done = self.sched_work(run, decode_done);
                            self.dispatch_actions(run, done);
                        }
                    }
                }
            }
        }
    }

    fn run(mut self) -> MultiSimResult {
        // Submissions: the server ingests each graph and schedules its
        // roots; ingest work serializes on the reactor resource, exactly
        // like interleaved client submissions hitting one server thread.
        for i in 0..self.runs.len() {
            let ingest =
                self.cfg.profile.task_transition_us * 0.2 * self.runs[i].graph.len() as f64;
            let t = self.reactor_work(0.0, ingest);
            let roots = self.runs[i].graph.roots();
            self.runs[i].scheduler.tasks_ready(&roots, &mut self.actions);
            let done = self.sched_work(i as u32, t);
            self.dispatch_actions(i as u32, done);
        }

        let mut timed_out = false;
        while let Some(Reverse((Key(at, _), idx))) = self.events.pop() {
            self.now = at;
            if self.remaining_total == 0 {
                break;
            }
            if at > self.cfg.timeout_us {
                timed_out = true;
                break;
            }
            // Take the event out without shifting the arena.
            let ev = std::mem::replace(
                &mut self.payloads[idx],
                Event::WorkerWake { worker: WorkerId(0) },
            );
            self.handle(ev);
        }
        assert!(
            timed_out || self.remaining_total == 0,
            "simulation drained events with {} tasks unfinished",
            self.remaining_total
        );
        let in_flight_steals_at_end: usize =
            self.runs.iter().map(|r| r.scheduler.in_flight_steal_count()).sum();
        let runs: Vec<RunSimResult> = self
            .runs
            .iter()
            .map(|r| {
                let run_timed_out = r.remaining > 0;
                let makespan =
                    if run_timed_out { self.cfg.timeout_us } else { r.last_finish_us };
                RunSimResult {
                    name: r.graph.name.clone(),
                    n_tasks: r.graph.len() as u64,
                    makespan_us: makespan,
                    aot_us: makespan / r.graph.len() as f64,
                    tasks_executed: r.tasks_executed,
                    timed_out: run_timed_out,
                }
            })
            .collect();
        let makespan = runs.iter().map(|r| r.makespan_us).fold(0.0, f64::max);
        MultiSimResult {
            runs,
            makespan_us: makespan,
            msgs: self.msgs,
            steals_attempted: self.steals_attempted,
            steals_failed: self.steals_failed,
            bytes_transferred: self.bytes_transferred,
            sched_cost: self.total_cost,
            timed_out,
            in_flight_steals_at_end,
        }
    }
}

/// Run several graphs concurrently against one shared virtual cluster.
pub fn simulate_concurrent(graphs: &[TaskGraph], cfg: &SimConfig) -> MultiSimResult {
    Engine::new(graphs, cfg.clone()).run()
}

/// Run one simulation.
pub fn simulate(graph: &TaskGraph, cfg: &SimConfig) -> SimResult {
    let multi = Engine::new(std::slice::from_ref(graph), cfg.clone()).run();
    let run = &multi.runs[0];
    SimResult {
        makespan_us: run.makespan_us,
        aot_us: run.aot_us,
        n_tasks: run.n_tasks,
        msgs: multi.msgs,
        steals_attempted: multi.steals_attempted,
        steals_failed: multi.steals_failed,
        bytes_transferred: multi.bytes_transferred,
        sched_cost: multi.sched_cost,
        timed_out: multi.timed_out,
        tasks_executed: run.tasks_executed,
        in_flight_steals_at_end: multi.in_flight_steals_at_end,
    }
}
