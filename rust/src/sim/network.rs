//! Network model: latency + bandwidth pipes with per-node NIC
//! serialization and a same-node fast path.

/// Cluster interconnect parameters.
///
/// Dask's data plane is *serialization-bound*, not wire-bound: the paper's
/// Salomon interconnect is FDR56 (~6.8 GB/s), but a Dask worker moves data
/// through pickle + TCP at a few GB/s with a substantial per-fetch setup
/// cost, also within a node. The defaults model that effective path —
/// which is what makes random placement pay for its extra transfers
/// (Fig 2's 0.88× at 24 workers).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// One-way control/fetch latency (connection + scheduling), µs.
    pub latency_us: f64,
    /// Cross-node effective bandwidth, bytes/µs (1000 ≈ 1 GB/s,
    /// serialization-bound).
    pub net_bw: f64,
    /// Same-node effective bandwidth, bytes/µs (loopback, still pickled).
    pub local_bw: f64,
    /// Model the PR 10 data plane: workers keep pooled persistent peer
    /// links and coalesce a gather's fetches into one batched request per
    /// source, so the per-fetch setup latency is paid once per *peer* per
    /// gather, not once per object. `false` restores the connect-per-fetch
    /// model (per-object latency) — the baseline `benches/fig_dataplane.rs`
    /// measures against.
    pub pooled_links: bool,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel { latency_us: 100.0, net_bw: 1_000.0, local_bw: 800.0, pooled_links: true }
    }
}

impl NetworkModel {
    /// Pure wire time of a payload between nodes (no NIC queueing).
    pub fn cross_node_us(&self, bytes: u64) -> f64 {
        self.latency_us + bytes as f64 / self.net_bw
    }

    /// Same-node copy time.
    pub fn same_node_us(&self, bytes: u64) -> f64 {
        bytes as f64 / self.local_bw
    }

    /// Small control message (assignment/status) time.
    pub fn control_msg_us(&self) -> f64 {
        self.latency_us
    }
}

/// Per-node transmit NIC: transfers serialize on the sender.
#[derive(Debug, Clone, Default)]
pub struct NicState {
    pub tx_free_at: f64,
}

impl NicState {
    /// Schedule `bytes` out of this NIC starting no earlier than `now`;
    /// returns completion time on the wire (excluding propagation latency).
    pub fn transmit(&mut self, now: f64, bytes: u64, bw: f64) -> f64 {
        let start = self.tx_free_at.max(now);
        self.tx_free_at = start + bytes as f64 / bw;
        self.tx_free_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_times() {
        let n = NetworkModel::default();
        assert!((n.cross_node_us(100_000) - 200.0).abs() < 1e-9, "100 µs wire + 100 µs latency");
        assert!(n.same_node_us(250_000) < n.cross_node_us(250_000));
    }

    #[test]
    fn nic_serializes() {
        let net = NetworkModel::default();
        let mut nic = NicState::default();
        let t1 = nic.transmit(0.0, 10_000, net.net_bw); // 10 µs
        let t2 = nic.transmit(0.0, 10_000, net.net_bw); // queued behind
        assert!((t1 - 10.0).abs() < 1e-9);
        assert!((t2 - 20.0).abs() < 1e-9);
        // Idle gap resets the start time.
        let t3 = nic.transmit(100.0, 10_000, net.net_bw);
        assert!((t3 - 110.0).abs() < 1e-9);
    }
}
