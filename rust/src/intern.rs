//! Interned-string arena for the per-task hot path.
//!
//! Task keys and data addresses are the only strings that cross the
//! per-task paths (assignment dispatch, worker enqueue). Owning them per
//! message meant one `String` clone per key plus one per input address per
//! transition — the dominant remaining allocation after the codec went
//! zero-alloc (PR 2). A [`StrArena`] stores each distinct string once in a
//! single append-only byte buffer and hands out compact [`KeyId`] handles;
//! every later layer carries the 4-byte id and resolves to `&str` only at
//! the protocol boundary.
//!
//! Ownership: arenas are *scoped*, not global. The worker keeps one arena
//! set per live run (dropped wholesale on `release-run`, so a long-lived
//! worker's interned state stays bounded); the server never needs one —
//! its keys already live exactly once in the submitted
//! [`crate::taskgraph::TaskGraph`] and its worker addresses exactly once in
//! the registration table, both of which the borrowed dispatch path
//! (`ComputeDispatch`) resolves without cloning.
//!
//! Warm-path guarantee: [`StrArena::intern`] on an already-present string
//! performs no heap allocation (one hash lookup), and [`StrArena::get`] is
//! an index into the shared buffer. Only the *first* occurrence of a
//! string allocates — the property the `hotpath_micro` counting-allocator
//! bench asserts for the worker enqueue path.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// Compact handle to a string interned in one [`StrArena`]. Only
/// meaningful together with the arena that issued it (the worker scopes
/// arenas per run, so the pair `(RunId, KeyId)` is globally unambiguous).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KeyId(pub u32);

impl KeyId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for KeyId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "k{}", self.0)
    }
}

/// Append-only string arena: all interned strings live contiguously in one
/// byte buffer; ids are dense and never invalidated (spans are recorded at
/// append time, and the buffer only grows).
#[derive(Debug, Default)]
pub struct StrArena {
    /// Every interned string, concatenated.
    bytes: String,
    /// `(offset, len)` of each id, in issue order.
    spans: Vec<(u32, u32)>,
    /// Content hash → ids with that hash, for deduplicating
    /// [`StrArena::intern`]. Candidates resolve through the arena bytes —
    /// the arena stays the *only* copy of each string — and a lookup hit
    /// (hash + compare) allocates nothing.
    lookup: HashMap<u64, Vec<KeyId>>,
}

fn content_hash(s: &str) -> u64 {
    let mut h = DefaultHasher::new();
    s.hash(&mut h);
    h.finish()
}

impl StrArena {
    pub fn new() -> StrArena {
        StrArena::default()
    }

    /// Number of distinct strings interned.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Total interned bytes (capacity diagnostics).
    pub fn bytes_used(&self) -> usize {
        self.bytes.len()
    }

    /// Intern with deduplication: a string seen before returns its
    /// existing id without touching the heap; a new string is appended
    /// once (the arena buffer is its only copy). Use for strings that
    /// repeat (peer data addresses).
    pub fn intern(&mut self, s: &str) -> KeyId {
        let h = content_hash(s);
        if let Some(ids) = self.lookup.get(&h) {
            for &id in ids {
                if self.get(id) == s {
                    return id;
                }
            }
        }
        let id = self.append(s);
        self.lookup.entry(h).or_default().push(id);
        id
    }

    /// Append without deduplication. Use when the caller already knows the
    /// string is new (task keys are unique within a run and indexed by
    /// dense task id, so no content lookup is ever needed). Ids from
    /// `append` are still resolvable, but invisible to [`StrArena::intern`].
    pub fn append(&mut self, s: &str) -> KeyId {
        let id = KeyId(self.spans.len() as u32);
        let off = self.bytes.len() as u32;
        self.bytes.push_str(s);
        self.spans.push((off, s.len() as u32));
        id
    }

    /// Resolve an id issued by this arena.
    #[inline]
    pub fn get(&self, id: KeyId) -> &str {
        let (off, len) = self.spans[id.idx()];
        &self.bytes[off as usize..(off + len) as usize]
    }

    /// Resolve, returning `None` for ids this arena never issued (stale id
    /// from another arena — a caller bug, but diagnostics paths prefer
    /// `None` over a panic).
    pub fn try_get(&self, id: KeyId) -> Option<&str> {
        let &(off, len) = self.spans.get(id.idx())?;
        self.bytes.get(off as usize..(off + len) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_dedups_append_does_not() {
        let mut a = StrArena::new();
        let x = a.intern("10.0.0.1:9000");
        let y = a.intern("10.0.0.2:9000");
        let x2 = a.intern("10.0.0.1:9000");
        assert_eq!(x, x2, "repeat intern returns the same id");
        assert_ne!(x, y);
        assert_eq!(a.len(), 2);
        let z = a.append("10.0.0.1:9000");
        assert_ne!(x, z, "append always issues a fresh id");
        assert_eq!(a.len(), 3);
        assert_eq!(a.get(x), "10.0.0.1:9000");
        assert_eq!(a.get(y), "10.0.0.2:9000");
        assert_eq!(a.get(z), "10.0.0.1:9000");
    }

    #[test]
    fn ids_survive_growth() {
        // Spans must stay valid across buffer reallocation.
        let mut a = StrArena::new();
        let ids: Vec<KeyId> = (0..500).map(|i| a.append(&format!("key-{i}"))).collect();
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(a.get(*id), format!("key-{i}"));
        }
        assert_eq!(a.len(), 500);
    }

    #[test]
    fn empty_string_and_try_get() {
        let mut a = StrArena::new();
        let e = a.intern("");
        assert_eq!(a.get(e), "");
        assert_eq!(a.try_get(e), Some(""));
        assert_eq!(a.try_get(KeyId(7)), None, "foreign id resolves to None");
    }
}
