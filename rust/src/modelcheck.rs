//! Offline stand-in for the `loom` exhaustive model checker.
//!
//! The verification layer (see `docs/verification.md`) wants loom-style
//! exhaustive interleaving exploration for the repo's small concurrency
//! cores: the worker's one-mutex [`TaskQueue`](crate::worker::TaskQueue),
//! the reactor's report window behind the [`ServerHandle`] mutex, the
//! cross-shard `deliver_forward` forward/death protocol, and the runtime's
//! global-init pattern. The build environment is offline and the crate is
//! dependency-free, so — exactly like [`crate::testing`] stands in for
//! `proptest` — this module is a small, self-contained model checker with
//! loom's API shape:
//!
//! - [`Mutex`], [`Condvar`], [`thread::spawn`]/[`thread::JoinHandle`] and
//!   the [`atomic`] types mirror their `std::sync` counterparts. Outside a
//!   model run they *are* thin wrappers over std (passthrough mode), so
//!   the library still works normally when compiled with `--cfg loom`.
//! - [`model`] runs a closure repeatedly, exploring every distinguishable
//!   thread interleaving by DFS over the scheduler's decision points. Each
//!   primitive operation (lock, unlock, condvar wait/notify, atomic
//!   access, spawn, join) is a *schedule point*: the single cooperative
//!   scheduler picks which thread runs next, and on later iterations picks
//!   differently, backtracking like loom's `branch` vector.
//! - A model failure (assertion panic inside any model thread, or a
//!   detected deadlock) aborts the iteration and re-panics on the caller's
//!   thread with the failing schedule, so the exact interleaving can be
//!   replayed by eye.
//!
//! # Soundness and limits
//!
//! The explorer is *sequentially consistent*: atomics are executed with
//! their real `Ordering` but interleavings are only explored at operation
//! granularity, so weak-memory reorderings (store buffering etc.) are not
//! modelled — fine for this codebase, which guards everything with mutexes
//! and uses atomics only for stop flags. `notify_one` is modelled as
//! `notify_all` (a legal over-approximation: spurious wakeups are allowed
//! by std, so every `Condvar` consumer must already re-check its predicate
//! in a loop, and the model verifies exactly that). Models must be
//! *deterministic* given a schedule: don't branch on `HashMap` iteration
//! order or wall-clock time, and use only the primitives in this module —
//! a model thread that blocks in a raw `std::sync` primitive is invisible
//! to the scheduler and will be reported as a deadlock.
//!
//! This module is compiled unconditionally (not just under `--cfg loom`)
//! so its own unit tests run in the tier-1 suite; the production library
//! only *routes* through it when built with `--cfg loom` (see
//! [`crate::sync`]).
//!
//! [`ServerHandle`]: crate::server::net::ServerHandle
//! [`TaskQueue`]: crate::worker::queue::TaskQueue

use std::cell::RefCell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, panic_any, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{
    Arc as StdArc, Condvar as StdCondvar, LockResult, Mutex as StdMutex,
    MutexGuard as StdMutexGuard, PoisonError,
};

/// Hard cap on model threads per iteration (models are meant to be tiny).
pub const MAX_THREADS: usize = 16;

/// Default cap on explored schedules before the checker gives up.
pub const DEFAULT_MAX_ITERATIONS: usize = 1 << 20;

/// Summary of a completed (exhaustive) exploration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Report {
    /// Number of distinct schedules executed.
    pub iterations: usize,
}

/// Sentinel panic payload used to unwind model threads when the iteration
/// has already failed elsewhere; never reported as the failure itself.
struct Abort;

#[derive(Debug, Clone, PartialEq, Eq)]
enum ThreadState {
    /// May be chosen by the scheduler.
    Runnable,
    /// Waiting for the mutex at this address to be released.
    BlockedLock(usize),
    /// Waiting for a notify on the condvar at this address.
    BlockedCv(usize),
    /// Waiting for thread `n` to finish.
    BlockedJoin(usize),
    Finished,
}

/// `active` value meaning "no thread scheduled" (iteration complete).
const NOBODY: usize = usize::MAX;

struct SchedState {
    threads: Vec<ThreadState>,
    /// Index of the one thread allowed to execute user code right now.
    active: usize,
    /// DFS decision vector: choice taken at each branching schedule point.
    schedule: Vec<usize>,
    /// Number of enabled threads observed at each branching point.
    branch_counts: Vec<usize>,
    /// Next decision index.
    pos: usize,
    /// Mutex address → owning thread.
    locks: HashMap<usize, usize>,
    /// First failure (assertion message or deadlock report) this iteration.
    panic: Option<String>,
}

/// The per-iteration cooperative scheduler. All model threads block on
/// `cv` until `state.active` names them; every state change that could
/// unblock anyone calls `notify_all`, and every waiter re-checks its
/// predicate, so wakeups cannot be lost.
struct Sched {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    /// OS handles of spawned model threads, joined by the monitor after
    /// the iteration completes (kept outside `state` so joining never
    /// holds the scheduler lock).
    os_handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

thread_local! {
    /// The scheduler context of the current OS thread, when it is a model
    /// thread. `None` means passthrough: primitives behave like std.
    static CTX: RefCell<Option<(StdArc<Sched>, usize)>> = const { RefCell::new(None) };
}

fn ctx() -> Option<(StdArc<Sched>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

fn lock_ignore_poison<T>(m: &StdMutex<T>) -> StdMutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Sched {
    fn new(schedule: Vec<usize>, branch_counts: Vec<usize>) -> Sched {
        Sched {
            state: StdMutex::new(SchedState {
                threads: vec![ThreadState::Runnable],
                active: 0,
                schedule,
                branch_counts,
                pos: 0,
                locks: HashMap::new(),
                panic: None,
            }),
            cv: StdCondvar::new(),
            os_handles: StdMutex::new(Vec::new()),
        }
    }

    /// Pick the next thread to run. Consumes one DFS decision when more
    /// than one thread is enabled; detects deadlock when none is and the
    /// iteration is not complete. Always notifies, so whoever was picked
    /// (or the monitor) wakes up.
    fn pick_locked(&self, st: &mut SchedState) {
        if st.panic.is_some() {
            self.cv.notify_all();
            return;
        }
        let enabled: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| **t == ThreadState::Runnable)
            .map(|(i, _)| i)
            .collect();
        if enabled.is_empty() {
            if st.threads.iter().all(|t| *t == ThreadState::Finished) {
                st.active = NOBODY;
            } else {
                st.panic = Some(format!(
                    "deadlock: every unfinished thread is blocked ({:?})",
                    st.threads
                ));
            }
            self.cv.notify_all();
            return;
        }
        let choice = if enabled.len() == 1 {
            0
        } else {
            let c = if st.pos < st.schedule.len() {
                // Replaying a prefix; clamp defensively in case the model
                // was not schedule-deterministic.
                st.schedule[st.pos].min(enabled.len() - 1)
            } else {
                st.schedule.push(0);
                st.branch_counts.push(enabled.len());
                0
            };
            st.pos += 1;
            c
        };
        st.active = enabled[choice];
        self.cv.notify_all();
    }

    /// Block until this thread is the active one (or the iteration has
    /// failed, in which case unwind with [`Abort`]).
    fn wait_active(&self, mut st: StdMutexGuard<'_, SchedState>, me: usize) {
        loop {
            if st.panic.is_some() {
                drop(st);
                panic_any(Abort);
            }
            if st.active == me {
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// A plain preemption point: let the scheduler (possibly) hand control
    /// to another thread before the caller's next primitive operation.
    fn schedule_point(&self, me: usize) {
        let mut st = lock_ignore_poison(&self.state);
        self.pick_locked(&mut st);
        self.wait_active(st, me);
    }

    /// Block `me` in `blocked`, schedule someone else, and return once
    /// `me` is runnable *and* scheduled again.
    fn block_and_wait(&self, mut st: StdMutexGuard<'_, SchedState>, me: usize, blocked: ThreadState) {
        st.threads[me] = blocked;
        self.pick_locked(&mut st);
        self.wait_active(st, me);
    }

    fn wake(st: &mut SchedState, pred: impl Fn(&ThreadState) -> bool) {
        for t in st.threads.iter_mut() {
            if pred(t) {
                *t = ThreadState::Runnable;
            }
        }
    }

    fn lock_acquire(&self, me: usize, addr: usize) {
        self.schedule_point(me);
        loop {
            let mut st = lock_ignore_poison(&self.state);
            if st.panic.is_some() {
                drop(st);
                panic_any(Abort);
            }
            if let std::collections::hash_map::Entry::Vacant(e) = st.locks.entry(addr) {
                e.insert(me);
                return;
            }
            self.block_and_wait(st, me, ThreadState::BlockedLock(addr));
        }
    }

    /// Release a lock. `during_unwind` skips the handoff wait (a second
    /// panic while unwinding would abort the process).
    fn lock_release(&self, me: usize, addr: usize, during_unwind: bool) {
        let mut st = lock_ignore_poison(&self.state);
        st.locks.remove(&addr);
        Self::wake(&mut st, |t| *t == ThreadState::BlockedLock(addr));
        if during_unwind {
            self.cv.notify_all();
            return;
        }
        self.pick_locked(&mut st);
        self.wait_active(st, me);
    }

    /// Atomically release `lock_addr`, block on `cv_addr`, and re-acquire
    /// the lock once notified and scheduled.
    fn cv_wait(&self, me: usize, cv_addr: usize, lock_addr: usize) {
        {
            let mut st = lock_ignore_poison(&self.state);
            st.locks.remove(&lock_addr);
            Self::wake(&mut st, |t| *t == ThreadState::BlockedLock(lock_addr));
            self.block_and_wait(st, me, ThreadState::BlockedCv(cv_addr));
        }
        loop {
            let mut st = lock_ignore_poison(&self.state);
            if st.panic.is_some() {
                drop(st);
                panic_any(Abort);
            }
            if let std::collections::hash_map::Entry::Vacant(e) = st.locks.entry(lock_addr) {
                e.insert(me);
                return;
            }
            self.block_and_wait(st, me, ThreadState::BlockedLock(lock_addr));
        }
    }

    fn cv_notify(&self, me: usize, cv_addr: usize) {
        self.schedule_point(me);
        let mut st = lock_ignore_poison(&self.state);
        Self::wake(&mut st, |t| *t == ThreadState::BlockedCv(cv_addr));
        self.cv.notify_all();
    }

    fn join_wait(&self, me: usize, target: usize) {
        self.schedule_point(me);
        loop {
            let mut st = lock_ignore_poison(&self.state);
            if st.panic.is_some() {
                drop(st);
                panic_any(Abort);
            }
            if st.threads[target] == ThreadState::Finished {
                return;
            }
            self.block_and_wait(st, me, ThreadState::BlockedJoin(target));
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked with a non-string payload".to_string()
    }
}

/// Body of every model OS thread: wait to be scheduled, run the user
/// closure, then record the outcome and hand control onward.
fn thread_main(sched: StdArc<Sched>, me: usize, body: impl FnOnce()) {
    CTX.with(|c| *c.borrow_mut() = Some((sched.clone(), me)));
    let result = catch_unwind(AssertUnwindSafe(|| {
        let st = lock_ignore_poison(&sched.state);
        sched.wait_active(st, me);
        body();
    }));
    CTX.with(|c| *c.borrow_mut() = None);
    let mut st = lock_ignore_poison(&sched.state);
    if let Err(payload) = result {
        if payload.downcast_ref::<Abort>().is_none() && st.panic.is_none() {
            st.panic = Some(panic_message(payload.as_ref()));
        }
    }
    st.threads[me] = ThreadState::Finished;
    Sched::wake(&mut st, |t| *t == ThreadState::BlockedJoin(me));
    sched.pick_locked(&mut st);
}

// ---------------------------------------------------------------------------
// Public primitives (std-shaped; passthrough outside a model run)
// ---------------------------------------------------------------------------

/// A mutex whose lock/unlock are schedule points under [`model`]; a plain
/// `std::sync::Mutex` otherwise.
#[derive(Debug, Default)]
pub struct Mutex<T: ?Sized> {
    inner: StdMutex<T>,
}

/// Guard for [`Mutex`]; releases the model lock (a schedule point) on drop.
#[derive(Debug)]
pub struct MutexGuard<'a, T: ?Sized> {
    std: Option<StdMutexGuard<'a, T>>,
    lock: &'a Mutex<T>,
    model: bool,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Mutex<T> {
        Mutex { inner: StdMutex::new(t) }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T: ?Sized> Mutex<T> {
    fn addr(&self) -> usize {
        self as *const Mutex<T> as *const () as usize
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some((sched, me)) = ctx() {
            sched.lock_acquire(me, self.addr());
            // Exclusivity is enforced by the model scheduler, so the real
            // mutex is uncontended here.
            let std = lock_ignore_poison(&self.inner);
            Ok(MutexGuard { std: Some(std), lock: self, model: true })
        } else {
            match self.inner.lock() {
                Ok(std) => Ok(MutexGuard { std: Some(std), lock: self, model: false }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    std: Some(p.into_inner()),
                    lock: self,
                    model: false,
                })),
            }
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: ?Sized> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.std.as_deref().unwrap_or_else(|| unreachable!("guard taken"))
    }
}

impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.std.as_deref_mut().unwrap_or_else(|| unreachable!("guard taken"))
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Drop the std guard first so the data is unlocked before any
        // other model thread is scheduled.
        self.std = None;
        if self.model {
            if let Some((sched, me)) = ctx() {
                sched.lock_release(me, self.lock.addr(), std::thread::panicking());
            }
        }
    }
}

/// A condvar whose wait/notify are schedule points under [`model`].
/// `notify_one` is modelled as `notify_all` (legal: spurious wakeups).
#[derive(Debug, Default)]
pub struct Condvar {
    inner: StdCondvar,
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar { inner: StdCondvar::new() }
    }

    fn addr(&self) -> usize {
        self as *const Condvar as usize
    }

    pub fn wait<'a, T: ?Sized>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        if guard.model {
            let (sched, me) = ctx().unwrap_or_else(|| {
                unreachable!("model guard outside model context")
            });
            let lock = guard.lock;
            // Neutralize the guard: we release through the scheduler, not
            // through Drop.
            guard.std = None;
            guard.model = false;
            drop(guard);
            sched.cv_wait(me, self.addr(), lock.addr());
            let std = lock_ignore_poison(&lock.inner);
            Ok(MutexGuard { std: Some(std), lock, model: true })
        } else {
            let std = guard.std.take().unwrap_or_else(|| unreachable!("guard taken"));
            let lock = guard.lock;
            drop(guard);
            match self.inner.wait(std) {
                Ok(std) => Ok(MutexGuard { std: Some(std), lock, model: false }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    std: Some(p.into_inner()),
                    lock,
                    model: false,
                })),
            }
        }
    }

    pub fn wait_while<'a, T: ?Sized, F>(
        &self,
        mut guard: MutexGuard<'a, T>,
        mut condition: F,
    ) -> LockResult<MutexGuard<'a, T>>
    where
        F: FnMut(&mut T) -> bool,
    {
        while condition(&mut guard) {
            guard = self.wait(guard)?;
        }
        Ok(guard)
    }

    /// Timed wait, API-compatible with `std::sync::Condvar::wait_timeout`
    /// (callers go through [`crate::sync`], which resolves to std outside
    /// `--cfg loom`). Timeouts are not modelled: under an active [`model`]
    /// run this behaves as an ordinary [`Condvar::wait`] — the explorer
    /// covers the notify interleavings, and timeout-only liveness is out
    /// of its scope, so modelled code must not rely on the timeout firing.
    /// Outside a model run it is a std passthrough.
    pub fn wait_timeout<'a, T: ?Sized>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if guard.model {
            let never = WaitTimeoutResult { timed_out: false };
            return match self.wait(guard) {
                Ok(g) => Ok((g, never)),
                Err(p) => Err(PoisonError::new((p.into_inner(), never))),
            };
        }
        let std = guard.std.take().unwrap_or_else(|| unreachable!("guard taken"));
        let lock = guard.lock;
        drop(guard);
        match self.inner.wait_timeout(std, dur) {
            Ok((std, res)) => Ok((
                MutexGuard { std: Some(std), lock, model: false },
                WaitTimeoutResult { timed_out: res.timed_out() },
            )),
            Err(p) => {
                let (std, res) = p.into_inner();
                Err(PoisonError::new((
                    MutexGuard { std: Some(std), lock, model: false },
                    WaitTimeoutResult { timed_out: res.timed_out() },
                )))
            }
        }
    }

    pub fn notify_one(&self) {
        self.notify_all();
    }

    pub fn notify_all(&self) {
        if let Some((sched, me)) = ctx() {
            sched.cv_notify(me, self.addr());
        } else {
            self.inner.notify_all();
        }
    }
}

/// Result of [`Condvar::wait_timeout`] — mirrors
/// `std::sync::WaitTimeoutResult` (which has no public constructor, so
/// the instrumented condvar needs its own). Under an active [`model`]
/// run `timed_out` is always `false`; see [`Condvar::wait_timeout`].
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// Atomic types whose every access is a schedule point under [`model`].
/// Operations execute with their real `Ordering`; the explorer itself is
/// sequentially consistent (see the module docs).
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    fn sync_point() {
        if let Some((sched, me)) = super::ctx() {
            sched.schedule_point(me);
        }
    }

    macro_rules! model_atomic {
        ($name:ident, $std:ty, $val:ty) => {
            #[derive(Debug, Default)]
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $val) -> $name {
                    $name { inner: <$std>::new(v) }
                }

                pub fn load(&self, order: Ordering) -> $val {
                    sync_point();
                    self.inner.load(order)
                }

                pub fn store(&self, v: $val, order: Ordering) {
                    sync_point();
                    self.inner.store(v, order);
                }

                pub fn swap(&self, v: $val, order: Ordering) -> $val {
                    sync_point();
                    self.inner.swap(v, order)
                }

                pub fn compare_exchange(
                    &self,
                    current: $val,
                    new: $val,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$val, $val> {
                    sync_point();
                    self.inner.compare_exchange(current, new, success, failure)
                }
            }
        };
    }

    model_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    model_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    model_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    model_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);

    impl AtomicUsize {
        pub fn fetch_add(&self, v: usize, order: Ordering) -> usize {
            sync_point();
            self.inner.fetch_add(v, order)
        }
    }

    impl AtomicU64 {
        pub fn fetch_add(&self, v: u64, order: Ordering) -> u64 {
            sync_point();
            self.inner.fetch_add(v, order)
        }
    }
}

/// Model-aware `thread::spawn`/`JoinHandle`; plain std outside a model.
pub mod thread {
    use super::*;

    enum HandleInner<T> {
        Std(std::thread::JoinHandle<T>),
        Model {
            sched: StdArc<Sched>,
            idx: usize,
            slot: StdArc<StdMutex<Option<T>>>,
        },
    }

    /// Join handle mirroring `std::thread::JoinHandle`.
    pub struct JoinHandle<T>(HandleInner<T>);

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            match self.0 {
                HandleInner::Std(h) => h.join(),
                HandleInner::Model { sched, idx, slot } => {
                    let me = ctx()
                        .map(|(_, me)| me)
                        .unwrap_or_else(|| unreachable!("model join outside model"));
                    sched.join_wait(me, idx);
                    match lock_ignore_poison(&slot).take() {
                        Some(t) => Ok(t),
                        // The child panicked; the explorer already
                        // recorded it and is tearing the iteration down.
                        None => panic_any(Abort),
                    }
                }
            }
        }
    }

    pub fn spawn<T, F>(f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        if let Some((sched, me)) = ctx() {
            let idx = {
                let mut st = lock_ignore_poison(&sched.state);
                let idx = st.threads.len();
                assert!(idx < MAX_THREADS, "model spawned more than {MAX_THREADS} threads");
                st.threads.push(ThreadState::Runnable);
                idx
            };
            let slot = StdArc::new(StdMutex::new(None));
            let slot2 = StdArc::clone(&slot);
            let sched2 = StdArc::clone(&sched);
            let os = std::thread::spawn(move || {
                thread_main(StdArc::clone(&sched2), idx, move || {
                    let t = f();
                    *lock_ignore_poison(&slot2) = Some(t);
                });
            });
            lock_ignore_poison(&sched.os_handles).push(os);
            // The child is runnable from here on — let the scheduler
            // decide whether it preempts the parent immediately.
            sched.schedule_point(me);
            JoinHandle(HandleInner::Model { sched, idx, slot })
        } else {
            JoinHandle(HandleInner::Std(std::thread::spawn(f)))
        }
    }

    /// An explicit extra schedule point (loom's `thread::yield_now`).
    pub fn yield_now() {
        if let Some((sched, me)) = ctx() {
            sched.schedule_point(me);
        } else {
            std::thread::yield_now();
        }
    }
}

// ---------------------------------------------------------------------------
// The explorer
// ---------------------------------------------------------------------------

/// Configuration for [`model`]; the defaults suit the repo's models.
#[derive(Debug, Clone, Copy)]
pub struct Builder {
    pub max_iterations: usize,
}

impl Default for Builder {
    fn default() -> Builder {
        Builder { max_iterations: DEFAULT_MAX_ITERATIONS }
    }
}

impl Builder {
    pub fn new() -> Builder {
        Builder::default()
    }

    pub fn max_iterations(mut self, n: usize) -> Builder {
        self.max_iterations = n;
        self
    }

    /// Exhaustively explore `f`. Panics (on the caller's thread, with the
    /// failing schedule) if any explored interleaving panics or deadlocks.
    pub fn check<F>(self, f: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        let f = StdArc::new(f);
        let mut schedule: Vec<usize> = Vec::new();
        let mut counts: Vec<usize> = Vec::new();
        let mut iterations = 0usize;
        loop {
            iterations += 1;
            assert!(
                iterations <= self.max_iterations,
                "model state space exceeded {} schedules; shrink the model",
                self.max_iterations
            );
            let sched = StdArc::new(Sched::new(schedule, counts));
            {
                let body = StdArc::clone(&f);
                let sched_root = StdArc::clone(&sched);
                let os = std::thread::spawn(move || {
                    thread_main(StdArc::clone(&sched_root), 0, move || body());
                });
                lock_ignore_poison(&sched.os_handles).push(os);
            }
            // Wait for every model thread to finish (on failure they tear
            // themselves down via the panic flag).
            let (out_schedule, out_counts, failure) = {
                let mut st = lock_ignore_poison(&sched.state);
                while !st.threads.iter().all(|t| *t == ThreadState::Finished) {
                    st = sched.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                }
                (st.schedule.clone(), st.branch_counts.clone(), st.panic.clone())
            };
            for h in lock_ignore_poison(&sched.os_handles).drain(..) {
                // The wrapper caught every panic; join cannot fail.
                let _ = h.join();
            }
            if let Some(msg) = failure {
                panic!(
                    "model failed after {iterations} schedule(s): {msg}\n  failing schedule: {out_schedule:?}"
                );
            }
            // DFS backtrack: bump the deepest decision that still has an
            // unexplored branch, drop everything after it.
            let mut next = None;
            for i in (0..out_schedule.len()).rev() {
                if out_schedule[i] + 1 < out_counts[i] {
                    next = Some(i);
                    break;
                }
            }
            match next {
                None => return Report { iterations },
                Some(i) => {
                    schedule = out_schedule[..=i].to_vec();
                    schedule[i] += 1;
                    counts = out_counts[..=i].to_vec();
                }
            }
        }
    }
}

/// Exhaustively explore `f` with default limits. See [`Builder::check`].
pub fn model<F>(f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    Builder::new().check(f)
}

/// Run a model that is *expected to fail* (a seeded-bug regression model),
/// returning the failure message. Panics if the model unexpectedly passes.
pub fn model_fails<F>(f: F) -> String
where
    F: Fn() + Send + Sync + 'static,
{
    let outcome = catch_unwind(AssertUnwindSafe(|| model(f)));
    match outcome {
        Ok(report) => panic!(
            "seeded-bug model unexpectedly passed all {} schedules",
            report.iterations
        ),
        Err(payload) => panic_message(payload.as_ref()),
    }
}

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicUsize, Ordering};
    use super::*;
    use std::collections::HashSet;

    /// Unsynchronized read-modify-write: the explorer must find both the
    /// clean outcome (2) and the lost update (1).
    #[test]
    fn explorer_finds_lost_update() {
        let outcomes: StdArc<StdMutex<HashSet<usize>>> = StdArc::default();
        let sink = StdArc::clone(&outcomes);
        model(move || {
            let x = StdArc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let x = StdArc::clone(&x);
                    thread::spawn(move || {
                        let v = x.load(Ordering::SeqCst);
                        x.store(v + 1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            sink.lock().unwrap().insert(x.load(Ordering::SeqCst));
        });
        let seen = outcomes.lock().unwrap();
        assert!(seen.contains(&1), "lost update never explored: {seen:?}");
        assert!(seen.contains(&2), "serial outcome never explored: {seen:?}");
    }

    /// The same increment under a model mutex can never lose an update.
    #[test]
    fn mutex_serializes_increments() {
        let outcomes: StdArc<StdMutex<HashSet<usize>>> = StdArc::default();
        let sink = StdArc::clone(&outcomes);
        let report = model(move || {
            let x = StdArc::new(Mutex::new(0usize));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let x = StdArc::clone(&x);
                    thread::spawn(move || {
                        *x.lock().unwrap() += 1;
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            sink.lock().unwrap().insert(*x.lock().unwrap());
        });
        assert!(report.iterations >= 2, "no interleavings explored");
        assert_eq!(*outcomes.lock().unwrap(), HashSet::from([2]));
    }

    /// A model assertion that only fires under one interleaving is found,
    /// and the report names the schedule.
    #[test]
    fn explorer_finds_rare_assertion_failure() {
        let msg = model_fails(|| {
            let x = StdArc::new(AtomicUsize::new(0));
            let y = StdArc::clone(&x);
            let h = thread::spawn(move || {
                y.store(1, Ordering::SeqCst);
            });
            let seen = x.load(Ordering::SeqCst);
            h.join().unwrap();
            assert_ne!(seen, 1, "reader observed the writer (expected in SOME schedule)");
        });
        assert!(msg.contains("failing schedule"), "no schedule in: {msg}");
    }

    /// Classic AB/BA lock-order inversion is reported as a deadlock
    /// rather than hanging the test suite.
    #[test]
    fn explorer_detects_deadlock() {
        let msg = model_fails(|| {
            let a = StdArc::new(Mutex::new(()));
            let b = StdArc::new(Mutex::new(()));
            let (a2, b2) = (StdArc::clone(&a), StdArc::clone(&b));
            let h = thread::spawn(move || {
                let _ga = a2.lock().unwrap();
                let _gb = b2.lock().unwrap();
            });
            let _gb = b.lock().unwrap();
            let _ga = a.lock().unwrap();
            drop((_ga, _gb));
            h.join().unwrap();
        });
        assert!(msg.contains("deadlock"), "expected deadlock report, got: {msg}");
    }

    /// Condvar handshake: consumer waits for the producer's flag. The
    /// model must complete in every schedule (notify cannot be lost).
    #[test]
    fn condvar_handshake_never_hangs() {
        let report = model(|| {
            let pair = StdArc::new((Mutex::new(false), Condvar::new()));
            let pair2 = StdArc::clone(&pair);
            let h = thread::spawn(move || {
                let (m, cv) = &*pair2;
                *m.lock().unwrap() = true;
                cv.notify_all();
            });
            let (m, cv) = &*pair;
            let mut ready = m.lock().unwrap();
            while !*ready {
                ready = cv.wait(ready).unwrap();
            }
            drop(ready);
            h.join().unwrap();
        });
        assert!(report.iterations >= 2);
    }

    /// The canonical check-then-wait race: testing the flag *outside* the
    /// lock lets the notify land between the check and the wait, after
    /// which nobody ever notifies again. The explorer must find that
    /// schedule and report it as a deadlock instead of hanging.
    #[test]
    fn condvar_check_then_wait_race_is_caught() {
        use super::atomic::AtomicBool;
        let msg = model_fails(|| {
            let flag = StdArc::new(AtomicBool::new(false));
            let pair = StdArc::new((Mutex::new(()), Condvar::new()));
            let (flag2, pair2) = (StdArc::clone(&flag), StdArc::clone(&pair));
            let h = thread::spawn(move || {
                let (_, cv) = &*pair2;
                flag2.store(true, Ordering::SeqCst);
                cv.notify_all();
            });
            // BUG under test: unlocked check, then an unconditional wait.
            if !flag.load(Ordering::SeqCst) {
                let (m, cv) = &*pair;
                drop(cv.wait(m.lock().unwrap()).unwrap());
            }
            h.join().unwrap();
        });
        assert!(msg.contains("deadlock"), "expected deadlock report, got: {msg}");
    }

    #[test]
    fn join_returns_value() {
        model(|| {
            let h = thread::spawn(|| 41 + 1);
            assert_eq!(h.join().unwrap(), 42);
        });
    }

    /// Outside `model`, the primitives are plain std wrappers.
    #[test]
    fn passthrough_outside_model() {
        let m = Mutex::new(5usize);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 6);
        let cv = Condvar::new();
        cv.notify_all();
        let a = atomic::AtomicU64::new(7);
        assert_eq!(a.fetch_add(1, Ordering::SeqCst), 7);
        let h = thread::spawn(|| "ok");
        assert_eq!(h.join().unwrap(), "ok");
    }

    /// The DFS terminates and the iteration count is sane for a tiny
    /// model (two threads, one op each: a handful of schedules).
    #[test]
    fn exploration_is_bounded() {
        let report = model(|| {
            let x = StdArc::new(AtomicUsize::new(0));
            let y = StdArc::clone(&x);
            let h = thread::spawn(move || y.store(1, Ordering::SeqCst));
            x.store(2, Ordering::SeqCst);
            h.join().unwrap();
        });
        assert!(report.iterations >= 2, "must explore both orders");
        assert!(report.iterations <= 64, "tiny model exploded: {report:?}");
    }

    /// `wait_while` is the predicate-loop wait (used by worker models).
    #[test]
    fn wait_while_loops_predicate() {
        model(|| {
            let pair = StdArc::new((Mutex::new(0usize), Condvar::new()));
            let pair2 = StdArc::clone(&pair);
            let h = thread::spawn(move || {
                let (m, cv) = &*pair2;
                *m.lock().unwrap() = 3;
                cv.notify_one();
            });
            let (m, cv) = &*pair;
            let g = cv.wait_while(m.lock().unwrap(), |v| *v == 0).unwrap();
            assert_eq!(*g, 3);
            drop(g);
            h.join().unwrap();
        });
    }
}
