//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the worker hot path.
//!
//! This is the only place the `xla` crate is touched. Python never runs at
//! request time: `make artifacts` lowers the L2 JAX functions (which call
//! the L1 Pallas kernels) to HLO *text* once, and this module compiles and
//! caches one executable per artifact on first use.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §7).

use anyhow::{anyhow, Context, Result};
use crate::sync::Mutex;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

/// Fixed artifact shapes (must match python/compile/aot.py).
pub const REDUCE_ROWS: usize = 256;
pub const REDUCE_COLS: usize = 128;
pub const TRANSPOSE_N: usize = 128;
pub const HASH_TOKENS: usize = 4096;
pub const HASH_BUCKETS: usize = 1024;

/// The thread-affine xla handles, and *only* those. Private, so the
/// `unsafe impl Send` below is structural: nothing outside this module can
/// obtain a `PjRtClient`/`PjRtLoadedExecutable`, every instance lives
/// inside the one [`Runtime`] stored in [`GLOBAL`], and every method that
/// touches the handles takes `&mut self` — reachable only through that
/// mutex. Keeping the claim on this wrapper (rather than on `Runtime`
/// itself) means adding an innocently-`!Send` field to `Runtime` later
/// cannot silently widen what the unsafe impl vouches for.
struct AffineHandles {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: `PjRtClient` and `PjRtLoadedExecutable` wrap raw pointers into
// xla_extension's C++ runtime, which is not documented thread-safe and is
// thread-affine in places (its CPU client pins callback state to the
// constructing thread's context). Sending the handles to another thread is
// sound iff no two threads ever use them concurrently and no thread keeps
// a borrow across the send. Both are guaranteed structurally: the only
// instance is owned by the `Runtime` inside `GLOBAL: Mutex<Runtime>`,
// this type is private to the module, and no method hands out references
// that outlive the mutex guard. `Runtime` is NOT `Sync`; `&Runtime` never
// crosses threads — cross-thread access exists only via the mutex.
unsafe impl Send for AffineHandles {}

/// A compiled-artifact cache around one PJRT CPU client.
pub struct Runtime {
    handles: AffineHandles,
    dir: PathBuf,
}

static GLOBAL: OnceLock<Mutex<Runtime>> = OnceLock::new();

/// Serializes first-time construction in [`Runtime::global`]. `OnceLock`
/// alone cannot: `set` deduplicates the *store*, but two racing callers
/// would both run `Runtime::new`, constructing two PJRT clients whose
/// process-global state is exactly what the Send invariant above scopes
/// to "one instance". A plain std mutex (never the model-checked shim —
/// it guards init ordering, not modelled state) held only during
/// construction. The loom model `global_init_races_single_construction`
/// in `tests/loom_models.rs` checks this pattern, and its seeded twin
/// demonstrates the double-construction the naive check-then-set allows.
static INIT: std::sync::Mutex<()> = std::sync::Mutex::new(());

impl Runtime {
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            handles: AffineHandles { client, cache: HashMap::new() },
            dir: artifact_dir.into(),
        })
    }

    /// Artifact directory: `$RSDS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("RSDS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Global shared runtime (one PJRT client per process; workers share).
    pub fn global() -> Result<&'static Mutex<Runtime>> {
        if let Some(rt) = GLOBAL.get() {
            return Ok(rt);
        }
        let _init = INIT.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if GLOBAL.get().is_none() {
            let rt = Runtime::new(Self::default_dir())?;
            let _ = GLOBAL.set(Mutex::new(rt));
        }
        Ok(GLOBAL.get().expect("initialized under the init lock"))
    }

    /// Whether the artifacts needed by HLO payloads exist on disk.
    pub fn artifacts_present(dir: &Path) -> bool {
        ["partition_reduce", "numpy_step", "feature_hash"]
            .iter()
            .all(|n| dir.join(format!("{n}.hlo.txt")).exists())
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.handles.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("load {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .handles
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.handles.cache.insert(name.to_string(), exe);
        }
        Ok(self.handles.cache.get(name).expect("inserted above"))
    }

    fn run_f32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("result of {name} not f32: {e:?}"))
    }

    /// Execute the `partition_reduce` kernel (Pallas tiled sum+mean) on a
    /// deterministic pseudo-random (REDUCE_ROWS × REDUCE_COLS) partition.
    /// Returns `[sum, mean]`.
    pub fn partition_reduce(&mut self, seed: u64) -> Result<Vec<f32>> {
        let data = synth_f32(REDUCE_ROWS * REDUCE_COLS, seed);
        let x = xla::Literal::vec1(&data)
            .reshape(&[REDUCE_ROWS as i64, REDUCE_COLS as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        self.run_f32("partition_reduce", &[x])
    }

    /// Execute the `numpy_step` artifact: transpose+add+partial-sum of an
    /// (N × N) chunk. Returns `[partial_sum]`.
    pub fn numpy_step(&mut self, seed: u64) -> Result<Vec<f32>> {
        let data = synth_f32(TRANSPOSE_N * TRANSPOSE_N, seed);
        let x = xla::Literal::vec1(&data)
            .reshape(&[TRANSPOSE_N as i64, TRANSPOSE_N as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        self.run_f32("numpy_step", &[x])
    }

    /// Execute the `feature_hash` kernel (Pallas multiply-shift hashing) on
    /// HASH_TOKENS synthetic token ids. Returns HASH_BUCKETS f32 counts.
    pub fn feature_hash(&mut self, seed: u64) -> Result<Vec<f32>> {
        let tokens = synth_tokens(HASH_TOKENS, seed);
        let x = xla::Literal::vec1(&tokens);
        self.run_f32("feature_hash", &[x])
    }
}

/// Deterministic f32 data in [0, 1): same generator as python's synth
/// (SplitMix64 over the index), so numerics are reproducible end-to-end.
pub fn synth_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed;
    (0..n)
        .map(|_| {
            let x = crate::util::rng::splitmix64(&mut state);
            ((x >> 40) as f32) / ((1u64 << 24) as f32)
        })
        .collect()
}

/// Deterministic token ids in [0, 50k) as i32.
pub fn synth_tokens(n: usize, seed: u64) -> Vec<i32> {
    let mut state = seed;
    (0..n)
        .map(|_| (crate::util::rng::splitmix64(&mut state) % 50_000) as i32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_deterministic() {
        assert_eq!(synth_f32(16, 7), synth_f32(16, 7));
        assert_ne!(synth_f32(16, 7), synth_f32(16, 8));
        assert!(synth_f32(1000, 1).iter().all(|&x| (0.0..1.0).contains(&x)));
        let toks = synth_tokens(1000, 3);
        assert!(toks.iter().all(|&t| (0..50_000).contains(&t)));
    }

    // Kernel-execution tests live in tests/runtime_hlo.rs (they need the
    // artifacts built by `make artifacts`).
}
