//! Benchmark harness (criterion replacement for this offline environment):
//! warmup + timed repetitions with summary statistics, used by the
//! `benches/` binaries that regenerate the paper's tables and figures.

pub mod paper;

use crate::util::stats::{fmt_us, Summary};
use crate::util::timing::time_us;

/// Configuration for a measurement loop.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 1, iters: 5 }
    }
}

impl BenchConfig {
    /// The paper runs 5 repetitions (2 for scaling); honor a quick mode for
    /// CI via `RSDS_BENCH_QUICK=1`.
    pub fn from_env() -> BenchConfig {
        if std::env::var_os("RSDS_BENCH_QUICK").is_some() {
            BenchConfig { warmup_iters: 0, iters: 2 }
        } else {
            BenchConfig::default()
        }
    }
}

/// One named measurement result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.summary.mean
    }
}

/// Measure a closure `cfg.iters` times after warmup.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters.max(1) {
        let (_out, us) = time_us(|| std::hint::black_box(f()));
        samples.push(us);
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("non-empty samples"),
    }
}

/// Render a result row like `name  mean ± stddev  (min … max)`.
pub fn row(r: &BenchResult) -> String {
    format!(
        "{:<44} {:>12} ± {:<10} ({} … {})",
        r.name,
        fmt_us(r.summary.mean),
        fmt_us(r.summary.stddev),
        fmt_us(r.summary.min),
        fmt_us(r.summary.max)
    )
}

/// Throughput helper: ops/sec from a mean µs per op batch.
pub fn throughput(ops: u64, mean_us: f64) -> f64 {
    ops as f64 / (mean_us / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3 };
        let r = bench("spin", cfg, || crate::util::timing::busy_wait_us(300));
        assert_eq!(r.summary.n, 3);
        assert!(r.summary.mean >= 300.0, "mean {}", r.summary.mean);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(1000, 1_000_000.0) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn quick_mode_env() {
        // Not set in tests: default config.
        let cfg = BenchConfig::from_env();
        assert!(cfg.iters >= 2);
    }
}
