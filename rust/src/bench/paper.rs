//! Shared machinery for the figure-regenerating benches: run suite entries
//! through the simulator under a (server profile, scheduler) combination
//! and aggregate the paper's comparison metrics.

use crate::graphgen::SuiteEntry;
use crate::metrics::Measurement;
use crate::overhead::RuntimeProfile;
use crate::sim::{simulate, SimConfig};
use crate::util::stats::geomean;

/// A server/scheduler combination as the paper names them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Combo {
    /// `rsds` or `dask`.
    pub server: &'static str,
    /// `ws` | `random` (scheduler algorithm; the dask server runs its own
    /// ws implementation).
    pub scheduler: &'static str,
}

impl Combo {
    pub const DASK_WS: Combo = Combo { server: "dask", scheduler: "ws" };
    pub const DASK_RANDOM: Combo = Combo { server: "dask", scheduler: "random" };
    pub const RSDS_WS: Combo = Combo { server: "rsds", scheduler: "ws" };
    pub const RSDS_RANDOM: Combo = Combo { server: "rsds", scheduler: "random" };

    pub fn profile(&self) -> RuntimeProfile {
        match self.server {
            "dask" => RuntimeProfile::python(),
            _ => RuntimeProfile::rust(),
        }
    }

    /// Scheduler implementation name: the dask server uses the emulated
    /// Dask work-stealing, rsds its own simplified one (§IV-C).
    pub fn sched_impl(&self) -> &'static str {
        match (self.server, self.scheduler) {
            ("dask", "ws") => "dask-ws",
            (_, "ws") => "ws",
            _ => "random",
        }
    }

    pub fn label(&self) -> String {
        format!("{}/{}", self.server, self.scheduler)
    }
}

/// Run one suite entry under a combo, averaging `reps` seeds (the paper
/// averages 5 runs; 2 for scaling).
pub fn measure(
    entry: &SuiteEntry,
    combo: Combo,
    nodes: usize,
    reps: usize,
    zero_worker: bool,
) -> Measurement {
    let graph = entry.graph();
    let mut makespans = Vec::with_capacity(reps);
    for rep in 0..reps.max(1) {
        let cfg = SimConfig {
            zero_worker,
            seed: 2020 + rep as u64,
            ..SimConfig::nodes(nodes, combo.profile(), combo.sched_impl())
        };
        makespans.push(simulate(&graph, &cfg).makespan_us);
    }
    let mean = makespans.iter().sum::<f64>() / makespans.len() as f64;
    Measurement {
        benchmark: entry.name.to_string(),
        server: combo.server.to_string(),
        scheduler: combo.scheduler.to_string(),
        n_workers: nodes * 24,
        n_nodes: nodes,
        makespan_us: mean,
        reps: makespans.len(),
        aot_us: mean / graph.len() as f64,
    }
}

/// Per-benchmark speedups of `test` vs `baseline` over a suite, plus the
/// geometric mean (the paper's Figs 2–4 + Table II shape).
pub struct SpeedupSeries {
    pub rows: Vec<(String, f64)>,
    pub geomean: f64,
}

pub fn speedups(
    entries: &[SuiteEntry],
    baseline: Combo,
    test: Combo,
    nodes: usize,
    reps: usize,
    zero_worker: bool,
) -> SpeedupSeries {
    let mut rows = Vec::with_capacity(entries.len());
    for e in entries {
        let b = measure(e, baseline, nodes, reps, zero_worker);
        let t = measure(e, test, nodes, reps, zero_worker);
        rows.push((e.name.to_string(), b.makespan_us / t.makespan_us));
    }
    let g = geomean(&rows.iter().map(|(_, s)| *s).collect::<Vec<_>>());
    SpeedupSeries { rows, geomean: g }
}

/// Print a Fig 2/3/4-style speedup block.
pub fn print_speedups(title: &str, series: &SpeedupSeries) {
    println!("\n== {title} ==");
    for (name, s) in &series.rows {
        println!("  {name:<28} {s:>7.2}×");
    }
    println!("  {:<28} {:>7.2}×  (geometric mean)", "ALL", series.geomean);
}

/// Reps from the environment (quick mode = 1).
pub fn reps_from_env(default: usize) -> usize {
    if std::env::var_os("RSDS_BENCH_QUICK").is_some() {
        1
    } else {
        default
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::paper_suite;

    #[test]
    fn combo_wiring() {
        assert_eq!(Combo::DASK_WS.sched_impl(), "dask-ws");
        assert_eq!(Combo::RSDS_WS.sched_impl(), "ws");
        assert_eq!(Combo::DASK_RANDOM.sched_impl(), "random");
        assert_eq!(Combo::DASK_WS.profile().name, "dask");
        assert_eq!(Combo::RSDS_RANDOM.profile().name, "rsds");
    }

    #[test]
    fn measure_produces_sane_numbers() {
        let suite = paper_suite();
        let merge10k = suite.iter().find(|e| e.name == "merge-10K").unwrap();
        let m = measure(merge10k, Combo::RSDS_WS, 1, 2, false);
        assert_eq!(m.n_workers, 24);
        assert!(m.makespan_us > 0.0);
        assert_eq!(m.reps, 2);
        assert!((m.aot_us - m.makespan_us / 10_001.0).abs() < 1e-9);
    }

    #[test]
    fn rsds_beats_dask_on_merge_speedup_series() {
        let suite: Vec<_> =
            paper_suite().into_iter().filter(|e| e.name.starts_with("merge-1")).collect();
        let s = speedups(&suite, Combo::DASK_WS, Combo::RSDS_WS, 1, 1, false);
        assert!(s.geomean > 1.0, "rsds/ws geomean {:.2}", s.geomean);
    }
}
