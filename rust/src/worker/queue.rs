//! The worker's task queue, interned: the allocation-free enqueue path.
//!
//! Before this module, every `compute-task` the worker received was
//! decoded into an owned [`crate::protocol::Msg`] — one `String` for the
//! key, one `Vec` for the inputs, one `String` per input address — and
//! those owned fields sat in the priority queue until execution. Per task,
//! that was the last remaining allocation churn after the codec went
//! zero-alloc (PR 2).
//!
//! Now the reader thread decodes through the borrowed
//! [`ComputeTaskView`] and [`TaskQueue::enqueue`] interns directly into
//! run-local arenas ([`crate::intern::StrArena`]):
//!
//! - the task **key** is appended once per `(run, task)` — a re-delivered
//!   task (steal re-assignment, recovery re-send) hits the existing
//!   [`KeyId`];
//! - input **addresses** are content-interned — a cluster of `w` workers
//!   contributes at most `w` strings per run, no matter how many tasks
//!   name them;
//! - input location triples go into an append-only per-run pool; the
//!   queued entry carries a `(start, len)` span. The pool is reset (with
//!   retained capacity) whenever the queue drains, so steady state — the
//!   worker keeping up — re-enqueues without touching the heap allocator
//!   at all. `hotpath_micro` asserts 0 allocs/task on this warm path.
//!
//! Everything lives behind the worker's single queue mutex; arenas are
//! dropped wholesale on `release-run`, so a long-lived worker's interned
//! state stays bounded by its *live* runs.

use crate::intern::{KeyId, StrArena};
use crate::protocol::{CodecError, ComputeTaskView, RunId};
use crate::taskgraph::{Payload, TaskId};
use std::collections::{BinaryHeap, HashMap, HashSet};

/// Sanity cap on the task ids a worker accepts (16M tasks per run — an
/// order of magnitude past the largest benchmark graph). `key_of` is
/// sized from the wire task id, so without this a single corrupt frame
/// could demand a multi-gigabyte table and abort the process; past the
/// cap the frame is rejected through the normal bad-message path (log +
/// drop connection) like every other malformed input.
pub const MAX_TASK_ID: u32 = 1 << 24;

/// One input location, fully id-encoded: a fixed-size record instead of
/// an owned `String` (plus alternate-address `Vec`) per input.
#[derive(Debug, Clone, Copy)]
struct InputLoc {
    task: TaskId,
    /// Into the run's address arena; the empty string means "local".
    addr: KeyId,
    nbytes: u64,
    /// `(start, len)` span into the run's alternate-address pool —
    /// replica addresses fetch failover walks after `addr`.
    alts: (u32, u32),
}

/// A queued assignment: dense ids and arena handles only — no owned
/// strings, no owned vectors.
#[derive(Debug)]
struct QueuedTask {
    priority: i64,
    run: RunId,
    task: TaskId,
    payload: Payload,
    duration_us: u64,
    output_size: u64,
    /// Into the run's key arena.
    key: KeyId,
    /// `(start, len)` span into the run's input-location pool.
    inputs: (u32, u32),
    /// Graph-wide consumer count of the output (0 = pin in the store).
    consumers: u32,
    /// Core slots the task occupies while it runs (≥ 1).
    cores: u32,
}

// Min-heap by priority (lower value runs first, like Dask priorities);
// (run, task) breaks ties deterministically across interleaved graphs.
impl PartialEq for QueuedTask {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.run == other.run && self.task == other.task
    }
}
impl Eq for QueuedTask {}
impl PartialOrd for QueuedTask {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedTask {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse for BinaryHeap (max-heap) -> min-heap behavior.
        other
            .priority
            .cmp(&self.priority)
            .then(other.run.0.cmp(&self.run.0))
            .then(other.task.0.cmp(&self.task.0))
    }
}

/// Per-run interned state: arenas plus the input-location pool.
#[derive(Debug, Default)]
struct RunStrings {
    /// Task keys, appended once per task (unique within a run by graph
    /// validation, so no content lookup is needed — indexed by task id).
    keys: StrArena,
    key_of: Vec<Option<KeyId>>,
    /// Peer data addresses, content-deduplicated (primaries and replica
    /// alternates share this arena — a worker's address is one string no
    /// matter which role it plays).
    addrs: StrArena,
    /// Append-only input-location pool; reset when the queue drains.
    inputs: Vec<InputLoc>,
    /// Append-only alternate-address pool ([`InputLoc::alts`] spans);
    /// reset alongside `inputs`.
    alt_pool: Vec<KeyId>,
}

/// What [`TaskQueue::pop_into`] returns by value: the scalar task fields.
/// The strings (key, input addresses) land in the caller's reused
/// [`FetchPlan`], copied out under the queue lock so the executor never
/// borrows the arenas across it.
#[derive(Debug, Clone, PartialEq)]
pub struct PoppedTask {
    pub run: RunId,
    pub task: TaskId,
    pub payload: Payload,
    pub duration_us: u64,
    pub output_size: u64,
    pub priority: i64,
    /// Initial store reference count for the output (0 = pinned).
    pub consumers: u32,
    /// Core slots the task occupies; the executor returns them via
    /// [`TaskQueue::task_done`] when the task leaves the machine.
    pub cores: u32,
}

/// Executor-side scratch, reused across tasks: after warm-up a pop copies
/// spans and bytes into retained capacity and allocates nothing.
#[derive(Debug, Default)]
pub struct FetchPlan {
    /// `(input task, nbytes, addr span into addr_bytes, alt span into
    /// alt_spans)`.
    inputs: Vec<(TaskId, u64, (u32, u32), (u32, u32))>,
    /// Alternate-address spans into `addr_bytes`, pooled across inputs.
    alt_spans: Vec<(u32, u32)>,
    addr_bytes: String,
    key: String,
}

impl FetchPlan {
    pub fn new() -> FetchPlan {
        FetchPlan::default()
    }

    pub fn n_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// The i-th input: `(producing task, nbytes, fetch address)` — an
    /// empty address means the input is (or will be) local.
    pub fn input(&self, i: usize) -> (TaskId, u64, &str) {
        let (task, nbytes, (start, len), _) = self.inputs[i];
        (task, nbytes, &self.addr_bytes[start as usize..(start + len) as usize])
    }

    /// Number of alternate replica addresses known for input `i`.
    pub fn n_alts(&self, i: usize) -> usize {
        self.inputs[i].3 .1 as usize
    }

    /// The j-th alternate replica address of input `i` (fetch failover
    /// walks these after the primary).
    pub fn input_alt(&self, i: usize, j: usize) -> &str {
        let (alt_start, alt_len) = self.inputs[i].3;
        debug_assert!(j < alt_len as usize);
        let (start, len) = self.alt_spans[alt_start as usize + j];
        &self.addr_bytes[start as usize..(start + len) as usize]
    }

    /// The popped task's Dask-style key (diagnostics).
    pub fn key(&self) -> &str {
        &self.key
    }
}

/// The worker's `(run, task)`-keyed priority queue with run-local interned
/// strings. One instance lives behind the worker's queue mutex; benches
/// and tests drive it directly.
#[derive(Debug, Default)]
pub struct TaskQueue {
    heap: BinaryHeap<QueuedTask>,
    /// Tasks currently queued (O(1) steal checks).
    pending: HashSet<(RunId, TaskId)>,
    runs: HashMap<RunId, RunStrings>,
    /// Core-slot capacity of the worker; `None` disables the slot gate
    /// (benches and queue-only tests drive pops without completions).
    capacity: Option<u32>,
    /// Slots currently held by popped-but-unfinished tasks.
    used_cores: u32,
}

impl TaskQueue {
    pub fn new() -> TaskQueue {
        TaskQueue::default()
    }

    /// A queue whose [`TaskQueue::pop_into`] gates on core slots: a
    /// multi-core task only pops once enough of the worker's `ncores`
    /// slots are free, so executors never oversubscribe the machine.
    pub fn with_cores(ncores: u32) -> TaskQueue {
        TaskQueue { capacity: Some(ncores.max(1)), ..TaskQueue::default() }
    }

    /// Slots currently held by running tasks (diagnostics/tests).
    pub fn used_cores(&self) -> u32 {
        self.used_cores
    }

    /// A task popped earlier left the machine (finished, failed, or was
    /// skipped as released): return its core slots. Callers must follow
    /// with a condvar wake so gated executors re-check the queue.
    pub fn task_done(&mut self, cores: u32) {
        self.used_cores = self.used_cores.saturating_sub(cores);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Whether `(run, task)` is queued and not yet started (the steal
    /// retraction predicate).
    pub fn is_pending(&self, run: RunId, task: TaskId) -> bool {
        self.pending.contains(&(run, task))
    }

    /// Total input-pool entries across runs (bounded-growth diagnostics).
    pub fn input_pool_len(&self) -> usize {
        self.runs.values().map(|s| s.inputs.len()).sum()
    }

    /// Enqueue straight from the borrowed frame view, interning key and
    /// addresses into the run's arenas. Warm path (run known, key seen,
    /// addresses seen, capacities grown): zero heap allocations.
    ///
    /// Errors on a malformed `inputs` section or an absurd task id
    /// (≥ [`MAX_TASK_ID`] — the view's other scalar fields were already
    /// validated by its decode); a failed enqueue may leave orphaned pool
    /// entries behind, which the next drain-reset or `release-run`
    /// reclaims — the caller drops the connection anyway.
    pub fn enqueue(&mut self, view: &ComputeTaskView<'_>) -> Result<(), CodecError> {
        // Steady-state reclamation: once nothing is queued, no span
        // references the pools — restart them with retained capacity so a
        // worker that keeps up never grows them.
        if self.heap.is_empty() {
            for s in self.runs.values_mut() {
                s.inputs.clear();
                s.alt_pool.clear();
            }
        }
        if view.task.0 >= MAX_TASK_ID {
            // Structurally valid msgpack but an absurd id: reject before
            // it sizes `key_of` (decode must never be able to crash us).
            return Err(CodecError::WrongType("task"));
        }
        let s = self.runs.entry(view.run).or_default();
        let idx = view.task.idx();
        if s.key_of.len() <= idx {
            s.key_of.resize(idx + 1, None);
        }
        let key = match s.key_of[idx] {
            Some(k) => k,
            None => {
                // First delivery of this task: intern its key once. Keys
                // are unique per run, so append without a content lookup.
                let k = s.keys.append(view.key);
                s.key_of[idx] = Some(k);
                k
            }
        };
        let start = s.inputs.len() as u32;
        for input in view.inputs() {
            let input = input?;
            let addr = s.addrs.intern(input.addr);
            let alt_start = s.alt_pool.len() as u32;
            for alt in input.alts() {
                let id = s.addrs.intern(alt);
                s.alt_pool.push(id);
            }
            let alt_len = s.alt_pool.len() as u32 - alt_start;
            s.inputs.push(InputLoc {
                task: input.task,
                addr,
                nbytes: input.nbytes,
                alts: (alt_start, alt_len),
            });
        }
        let len = s.inputs.len() as u32 - start;
        self.pending.insert((view.run, view.task));
        self.heap.push(QueuedTask {
            priority: view.priority,
            run: view.run,
            task: view.task,
            payload: view.payload.clone(), // lint: clone-ok — Payload is all-scalar, clone is a memcpy
            duration_us: view.duration_us,
            output_size: view.output_size,
            key,
            inputs: (start, len),
            consumers: view.consumers,
            cores: view.cores.max(1),
        });
        Ok(())
    }

    /// Pop the highest-priority task, resolving its key and input
    /// addresses into the caller's reused scratch (so nothing borrows the
    /// arenas after the queue lock drops). Warm: zero allocations.
    pub fn pop_into(&mut self, plan: &mut FetchPlan) -> Option<PoppedTask> {
        if let Some(cap) = self.capacity {
            let top = self.heap.peek()?;
            // Gate on free slots — except when the worker is idle: a task
            // wider than the whole machine then runs alone (degraded, but
            // never wedged). The scheduler's can_fit filter makes this the
            // recovery path, not the steady state.
            if self.used_cores > 0 && top.cores > cap.saturating_sub(self.used_cores) {
                return None;
            }
        }
        let qt = self.heap.pop()?;
        if self.capacity.is_some() {
            self.used_cores += qt.cores;
        }
        self.pending.remove(&(qt.run, qt.task));
        plan.inputs.clear();
        plan.alt_spans.clear();
        plan.addr_bytes.clear();
        plan.key.clear();
        // The run's arenas exist whenever one of its tasks is queued
        // (release-run purges heap and arenas atomically under this lock);
        // the defensive miss leaves an empty plan for a task the caller's
        // released-run check will skip anyway.
        if let Some(s) = self.runs.get(&qt.run) {
            plan.key.push_str(s.keys.get(qt.key));
            let (start, len) = qt.inputs;
            for loc in &s.inputs[start as usize..(start + len) as usize] {
                let addr = s.addrs.get(loc.addr);
                let a0 = plan.addr_bytes.len() as u32;
                plan.addr_bytes.push_str(addr);
                let (alt_start, alt_len) = loc.alts;
                let sp0 = plan.alt_spans.len() as u32;
                for &alt_id in
                    &s.alt_pool[alt_start as usize..(alt_start + alt_len) as usize]
                {
                    let alt = s.addrs.get(alt_id);
                    let b0 = plan.addr_bytes.len() as u32;
                    plan.addr_bytes.push_str(alt);
                    plan.alt_spans.push((b0, alt.len() as u32));
                }
                plan.inputs.push((
                    loc.task,
                    loc.nbytes,
                    (a0, addr.len() as u32),
                    (sp0, alt_len),
                ));
            }
        }
        Some(PoppedTask {
            run: qt.run,
            task: qt.task,
            payload: qt.payload,
            duration_us: qt.duration_us,
            output_size: qt.output_size,
            priority: qt.priority,
            consumers: qt.consumers,
            cores: qt.cores,
        })
    }

    /// Remove a task if still queued; returns whether a queued copy was
    /// dropped (shared by steal retraction and `cancel-compute`). Cold
    /// path: rebuilds the heap.
    pub fn drop_queued(&mut self, run: RunId, task: TaskId) -> bool {
        if !self.pending.remove(&(run, task)) {
            return false;
        }
        let drained: Vec<QueuedTask> = self.heap.drain().collect();
        let mut found = false;
        for qt in drained {
            if qt.run == run && qt.task == task {
                found = true;
            } else {
                self.heap.push(qt);
            }
        }
        found
    }

    /// Run retired: drop its queued tasks AND its arenas — the interned
    /// state of a run dies with the run, bounding a long-lived worker.
    pub fn release_run(&mut self, run: RunId) {
        self.pending.retain(|&(r, _)| r != run);
        let kept: Vec<QueuedTask> = self.heap.drain().filter(|qt| qt.run != run).collect();
        self.heap.extend(kept);
        self.runs.remove(&run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{encode_msg, Msg, TaskInputLoc};

    fn compute(run: u32, task: u32, priority: i64, inputs: Vec<(u32, &str, u64)>) -> Vec<u8> {
        compute_with_alts(
            run,
            task,
            priority,
            inputs.into_iter().map(|(t, a, n)| (t, a, n, vec![])).collect(),
            0,
        )
    }

    fn compute_with_alts(
        run: u32,
        task: u32,
        priority: i64,
        inputs: Vec<(u32, &str, u64, Vec<&str>)>,
        consumers: u32,
    ) -> Vec<u8> {
        encode_msg(&Msg::ComputeTask {
            run: RunId(run),
            task: TaskId(task),
            key: format!("k-{run}-{task}"),
            payload: Payload::BusyWait,
            duration_us: 7,
            output_size: 64,
            inputs: inputs
                .into_iter()
                .map(|(t, a, n, alts)| TaskInputLoc {
                    task: TaskId(t),
                    addr: a.into(),
                    alts: alts.into_iter().map(String::from).collect(),
                    nbytes: n,
                })
                .collect(),
            priority,
            consumers,
            cores: 1,
        })
    }

    fn compute_wide(run: u32, task: u32, priority: i64, cores: u32) -> Vec<u8> {
        encode_msg(&Msg::ComputeTask {
            run: RunId(run),
            task: TaskId(task),
            key: format!("k-{run}-{task}"),
            payload: Payload::BusyWait,
            duration_us: 7,
            output_size: 64,
            inputs: vec![],
            priority,
            consumers: 1,
            cores,
        })
    }

    fn enqueue(q: &mut TaskQueue, bytes: &[u8]) {
        let view = ComputeTaskView::decode(bytes).unwrap();
        q.enqueue(&view).unwrap();
    }

    #[test]
    fn pops_in_priority_order_with_resolved_strings() {
        let mut q = TaskQueue::new();
        enqueue(&mut q, &compute(0, 2, 20, vec![(0, "10.0.0.1:9000", 5)]));
        enqueue(&mut q, &compute(0, 1, 10, vec![(0, "", 3), (2, "10.0.0.2:9000", 4)]));
        assert_eq!(q.len(), 2);
        assert!(q.is_pending(RunId(0), TaskId(1)));
        let mut plan = FetchPlan::new();
        let first = q.pop_into(&mut plan).unwrap();
        assert_eq!(first.task, TaskId(1), "lower priority value first");
        assert_eq!(plan.key(), "k-0-1");
        assert_eq!(plan.n_inputs(), 2);
        assert_eq!(plan.input(0), (TaskId(0), 3, ""));
        assert_eq!(plan.input(1), (TaskId(2), 4, "10.0.0.2:9000"));
        assert!(!q.is_pending(RunId(0), TaskId(1)));
        let second = q.pop_into(&mut plan).unwrap();
        assert_eq!(second.task, TaskId(2));
        assert_eq!(plan.input(0), (TaskId(0), 5, "10.0.0.1:9000"));
        assert!(q.pop_into(&mut plan).is_none());
    }

    #[test]
    fn ties_break_by_run_then_task() {
        let mut q = TaskQueue::new();
        enqueue(&mut q, &compute(1, 0, 5, vec![]));
        enqueue(&mut q, &compute(0, 3, 5, vec![]));
        enqueue(&mut q, &compute(0, 1, 5, vec![]));
        let mut plan = FetchPlan::new();
        let order: Vec<(RunId, TaskId)> = std::iter::from_fn(|| {
            q.pop_into(&mut plan).map(|p| (p.run, p.task))
        })
        .collect();
        assert_eq!(
            order,
            vec![
                (RunId(0), TaskId(1)),
                (RunId(0), TaskId(3)),
                (RunId(1), TaskId(0)),
            ]
        );
    }

    #[test]
    fn drop_queued_retracts_only_queued_tasks() {
        let mut q = TaskQueue::new();
        enqueue(&mut q, &compute(0, 1, 1, vec![]));
        enqueue(&mut q, &compute(0, 2, 2, vec![]));
        assert!(q.drop_queued(RunId(0), TaskId(1)), "queued → retractable");
        assert!(!q.drop_queued(RunId(0), TaskId(1)), "second retraction fails");
        let mut plan = FetchPlan::new();
        let p = q.pop_into(&mut plan).unwrap();
        assert_eq!(p.task, TaskId(2), "survivor still pops");
        assert!(!q.drop_queued(RunId(0), TaskId(2)), "started → not retractable");
    }

    #[test]
    fn release_run_purges_queue_and_arenas() {
        let mut q = TaskQueue::new();
        enqueue(&mut q, &compute(0, 1, 1, vec![(0, "10.0.0.1:9000", 5)]));
        enqueue(&mut q, &compute(1, 1, 2, vec![(0, "10.0.0.1:9000", 5)]));
        q.release_run(RunId(0));
        assert_eq!(q.len(), 1);
        assert!(!q.is_pending(RunId(0), TaskId(1)));
        assert!(q.is_pending(RunId(1), TaskId(1)));
        let mut plan = FetchPlan::new();
        let p = q.pop_into(&mut plan).unwrap();
        assert_eq!((p.run, p.task), (RunId(1), TaskId(1)));
        assert_eq!(plan.input(0).2, "10.0.0.1:9000", "other run's arena intact");
    }

    #[test]
    fn redelivery_reuses_the_interned_key() {
        // A steal re-assignment re-delivers the same (run, task): the key
        // arena must not grow a second copy.
        let mut q = TaskQueue::new();
        let bytes = compute(0, 4, 9, vec![(1, "10.0.0.9:9000", 2)]);
        enqueue(&mut q, &bytes);
        let mut plan = FetchPlan::new();
        q.pop_into(&mut plan).unwrap();
        enqueue(&mut q, &bytes);
        q.pop_into(&mut plan).unwrap();
        assert_eq!(plan.key(), "k-0-4");
        let s = q.runs.get(&RunId(0)).unwrap();
        assert_eq!(s.keys.len(), 1, "one interned key despite re-delivery");
        assert_eq!(s.addrs.len(), 1, "address content-deduplicated");
    }

    #[test]
    fn input_pool_resets_when_queue_drains() {
        let mut q = TaskQueue::new();
        let mut plan = FetchPlan::new();
        for wave in 0..50 {
            enqueue(&mut q, &compute(0, 1, 1, vec![(0, "10.0.0.1:9000", 5)]));
            enqueue(&mut q, &compute(0, 2, 2, vec![(0, "10.0.0.1:9000", 5), (1, "", 1)]));
            q.pop_into(&mut plan).unwrap();
            q.pop_into(&mut plan).unwrap();
            assert!(
                q.input_pool_len() <= 3,
                "wave {wave}: pool must reset on drain, got {}",
                q.input_pool_len()
            );
        }
    }

    #[test]
    fn absurd_task_id_is_rejected_not_allocated() {
        // A corrupt frame with a huge task id must error through the
        // bad-message path, never size key_of from it.
        let mut q = TaskQueue::new();
        let bytes = compute(0, MAX_TASK_ID, 1, vec![]);
        let view = ComputeTaskView::decode(&bytes).unwrap();
        assert!(q.enqueue(&view).is_err());
        assert_eq!(q.len(), 0);
        assert!(!q.is_pending(RunId(0), TaskId(MAX_TASK_ID)));
    }

    #[test]
    fn interned_enqueue_matches_owned_decode() {
        // Behavior parity: the fields the executor sees through the
        // interned path equal the owned decode of the same frame.
        let bytes = compute_with_alts(
            3,
            7,
            -5,
            vec![
                (5, "10.1.1.1:9999", 11, vec!["10.1.1.2:9999", "10.1.1.3:9999"]),
                (6, "", 0, vec![]),
            ],
            4,
        );
        let Msg::ComputeTask {
            run,
            task,
            key,
            payload,
            duration_us,
            output_size,
            inputs,
            priority,
            consumers,
            cores,
        } = crate::protocol::decode_msg(&bytes).unwrap()
        else {
            panic!("wrong op")
        };
        let mut q = TaskQueue::new();
        enqueue(&mut q, &bytes);
        let mut plan = FetchPlan::new();
        let p = q.pop_into(&mut plan).unwrap();
        assert_eq!((p.run, p.task, p.priority), (run, task, priority));
        assert_eq!(p.payload, payload);
        assert_eq!((p.duration_us, p.output_size), (duration_us, output_size));
        assert_eq!(p.consumers, consumers);
        assert_eq!(p.cores, cores.max(1));
        assert_eq!(plan.key(), key);
        assert_eq!(plan.n_inputs(), inputs.len());
        for (i, l) in inputs.iter().enumerate() {
            assert_eq!(plan.input(i), (l.task, l.nbytes, l.addr.as_str()));
            assert_eq!(plan.n_alts(i), l.alts.len());
            for (j, alt) in l.alts.iter().enumerate() {
                assert_eq!(plan.input_alt(i, j), alt);
            }
        }
    }

    #[test]
    fn slot_gate_admits_tasks_only_within_capacity() {
        let mut q = TaskQueue::with_cores(2);
        enqueue(&mut q, &compute_wide(0, 1, 1, 2));
        enqueue(&mut q, &compute_wide(0, 2, 2, 1));
        let mut plan = FetchPlan::new();
        let p = q.pop_into(&mut plan).unwrap();
        assert_eq!((p.task, p.cores), (TaskId(1), 2));
        assert_eq!(q.used_cores(), 2);
        assert!(
            q.pop_into(&mut plan).is_none(),
            "1-core task gated while the 2-core task holds both slots"
        );
        assert!(q.is_pending(RunId(0), TaskId(2)), "gated task stays queued");
        q.task_done(2);
        assert_eq!(q.used_cores(), 0);
        let p = q.pop_into(&mut plan).unwrap();
        assert_eq!((p.task, p.cores), (TaskId(2), 1));
        q.task_done(1);
    }

    #[test]
    fn oversize_task_runs_alone_instead_of_wedging() {
        // A 4-core task on a 1-core worker (possible after the cluster
        // shrinks under it) pops when the worker is idle — degraded, not
        // deadlocked — and still blocks everything else while it runs.
        let mut q = TaskQueue::with_cores(1);
        enqueue(&mut q, &compute_wide(0, 1, 1, 4));
        enqueue(&mut q, &compute_wide(0, 2, 2, 1));
        let mut plan = FetchPlan::new();
        let p = q.pop_into(&mut plan).unwrap();
        assert_eq!((p.task, p.cores), (TaskId(1), 4));
        assert!(q.pop_into(&mut plan).is_none());
        q.task_done(4);
        assert_eq!(q.pop_into(&mut plan).unwrap().task, TaskId(2));
    }

    #[test]
    fn ungated_queue_pops_regardless_of_width() {
        // TaskQueue::new() keeps the historical behavior: benches and
        // queue-only tests pop freely without reporting completions.
        let mut q = TaskQueue::new();
        enqueue(&mut q, &compute_wide(0, 1, 1, 8));
        enqueue(&mut q, &compute_wide(0, 2, 2, 8));
        let mut plan = FetchPlan::new();
        assert!(q.pop_into(&mut plan).is_some());
        assert!(q.pop_into(&mut plan).is_some());
        assert_eq!(q.used_cores(), 0);
    }

    #[test]
    fn alt_addresses_share_the_address_arena() {
        // A replica alternate that equals another input's primary must not
        // grow the arena: both roles content-intern to one string.
        let mut q = TaskQueue::new();
        enqueue(
            &mut q,
            &compute_with_alts(
                0,
                1,
                1,
                vec![
                    (8, "10.0.0.1:9000", 5, vec!["10.0.0.2:9000"]),
                    (9, "10.0.0.2:9000", 5, vec!["10.0.0.1:9000"]),
                ],
                2,
            ),
        );
        let s = q.runs.get(&RunId(0)).unwrap();
        assert_eq!(s.addrs.len(), 2, "two distinct addresses total");
        assert_eq!(s.alt_pool.len(), 2);
        let mut plan = FetchPlan::new();
        q.pop_into(&mut plan).unwrap();
        assert_eq!(plan.input(0).2, "10.0.0.1:9000");
        assert_eq!(plan.input_alt(0, 0), "10.0.0.2:9000");
        assert_eq!(plan.input(1).2, "10.0.0.2:9000");
        assert_eq!(plan.input_alt(1, 0), "10.0.0.1:9000");
    }
}
