//! The worker-side object store: reference-counted task outputs with an
//! LRU memory budget and spill-to-disk.
//!
//! Replaces the raw `Mutex<HashMap<DataKey, Arc<Vec<u8>>>>` the worker
//! used through PR 7. Three behaviors the raw map couldn't express:
//!
//! 1. **Self-eviction.** Each entry starts with the graph-wide consumer
//!    count of its task (shipped on `compute-task`); every gather — local
//!    or served to a peer — decrements it, and at zero the bytes drop
//!    immediately instead of lingering until `release-run`. Entries with
//!    consumer count 0 on the wire (sinks, replicas, passive fetch
//!    caches) are *pinned*: only `release-run` removes them.
//! 2. **Spill.** When resident bytes exceed the budget (`--memory-limit`),
//!    least-recently-used entries are written to a [`SpillBackend`] slot
//!    and their memory freed; a later `get` reports [`Lookup::Spilled`]
//!    and the (cold) [`ObjectStore::restore`] reads them back. Graphs
//!    whose live outputs exceed worker RAM complete instead of dying.
//! 3. **Safe concurrent eviction.** The evictor never writes to disk while
//!    holding the store lock: a victim moves `Resident → Spilling` (bytes
//!    still readable), is written *outside* the lock, then commits
//!    `Spilling → Spilled` — or frees the slot if the entry was consumed
//!    or released meanwhile. `tests/loom_models.rs` model-checks the
//!    get/restore-vs-spill race on this state machine.
//!
//! Lock order: store lock, then (optionally) backend-internal lock —
//! never the reverse. The backend `write` in the evictor runs with the
//! store unlocked; `restore` reads the backend under the store lock,
//! which keeps slot free exactly-once without a `Restoring` state.

use super::spill::SpillBackend;
use crate::protocol::RunId;
use crate::sync::{Arc, Condvar, Mutex};
use crate::taskgraph::TaskId;
use std::collections::{HashMap, HashSet};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Callback invoked (outside the store lock) after every successful
/// [`ObjectStore::insert`] — the data server's poll loop registers its
/// waker here so parked peer fetches re-check the store the moment a
/// producer lands, instead of sleep-polling.
type InsertHook = Box<dyn Fn() + Send + Sync>;

/// Store key: task outputs are namespaced by run because [`TaskId`]s
/// recycle across graph submissions.
pub type DataKey = (RunId, TaskId);

/// Where an entry's bytes currently live.
enum Slot {
    /// In memory, counted against the budget.
    Resident(Arc<Vec<u8>>),
    /// In memory *and* being written to the backend by the evictor, which
    /// holds the pending slot id. Readers still hit; the evictor decides
    /// at commit time whether the write sticks.
    Spilling(Arc<Vec<u8>>),
    /// On the backend only; `restore` brings it back.
    Spilled(u64),
}

struct Entry {
    slot: Slot,
    nbytes: u64,
    /// Remaining consumers; `None` = pinned (never self-evicts).
    consumers: Option<u32>,
    /// LRU stamp from the store's monotonic clock.
    last_used: u64,
}

struct Inner {
    entries: HashMap<DataKey, Entry>,
    /// Runs already released — late inserts from in-flight tasks of a
    /// retired run are dropped here, under the same lock as the map, so
    /// there is no release/insert race window.
    released: HashSet<RunId>,
    /// `(run, consuming task, input task)` gathers already counted — the
    /// exactly-once guard behind [`ObjectStore::consume_once`]. A task
    /// re-executed after recovery gathers the same inputs again; without
    /// the mark the double-decrement prematurely self-evicts an output a
    /// sibling consumer still needs. Purged with the run.
    consumed: HashSet<(RunId, TaskId, TaskId)>,
    resident_bytes: u64,
    clock: u64,
    spills: u64,
    restores: u64,
}

/// Result of the hot-path [`ObjectStore::get`].
pub enum Lookup {
    /// Bytes are in memory.
    Hit(Arc<Vec<u8>>),
    /// Key is live but its bytes are on the spill tier — call
    /// [`ObjectStore::restore`] (cold path).
    Spilled,
    /// Key is not in the store (never inserted, consumed away, or its run
    /// was released).
    Miss,
}

pub struct ObjectStore {
    inner: Mutex<Inner>,
    /// Signalled (broadcast) by every successful insert; paired with
    /// `inner`. [`ObjectStore::wait_resident`] blocks here so the gather
    /// path's wait for a local producer is event-driven instead of a
    /// sleep poll.
    cv: Condvar,
    /// See [`InsertHook`]; set at most once, called with the lock
    /// released.
    insert_hook: OnceLock<InsertHook>,
    backend: Arc<dyn SpillBackend>,
    /// Resident-byte budget; `None` disables eviction entirely.
    limit: Option<u64>,
}

impl ObjectStore {
    pub fn new(limit: Option<u64>, backend: Arc<dyn SpillBackend>) -> ObjectStore {
        ObjectStore {
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                released: HashSet::new(),
                consumed: HashSet::new(),
                resident_bytes: 0,
                clock: 0,
                spills: 0,
                restores: 0,
            }),
            cv: Condvar::new(),
            insert_hook: OnceLock::new(),
            backend,
            limit,
        }
    }

    /// Budget-less store (no eviction; the backend is never written).
    /// What the worker runs without `--memory-limit`.
    pub fn unbounded(backend: Arc<dyn SpillBackend>) -> ObjectStore {
        ObjectStore::new(None, backend)
    }

    /// Look a key up and touch its LRU stamp. Hot path (registered in
    /// `xtask/hotpath.txt`): no allocation, no I/O — a spilled entry is
    /// reported, not restored.
    pub fn get(&self, key: &DataKey) -> Lookup {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.entries.get_mut(key) {
            Some(e) => {
                e.last_used = clock;
                match &e.slot {
                    Slot::Resident(b) | Slot::Spilling(b) => {
                        Lookup::Hit(b.clone()) // lint: clone-ok — Arc refcount bump
                    }
                    Slot::Spilled(_) => Lookup::Spilled,
                }
            }
            None => Lookup::Miss,
        }
    }

    /// Insert a task output. `consumers` is the graph-wide consumer count
    /// (0 = pinned until `release-run`). Returns `false` — without
    /// storing — when the key is already present (duplicate results are
    /// legal after recovery) or its run was released. Hot path: no
    /// allocation beyond map growth.
    pub fn insert(&self, key: DataKey, bytes: Arc<Vec<u8>>, consumers: u32) -> bool {
        let nbytes = bytes.len() as u64;
        let mut inner = self.inner.lock().unwrap();
        if inner.released.contains(&key.0) || inner.entries.contains_key(&key) {
            return false;
        }
        inner.clock += 1;
        let clock = inner.clock;
        inner.entries.insert(
            key,
            Entry {
                slot: Slot::Resident(bytes),
                nbytes,
                consumers: if consumers == 0 { None } else { Some(consumers) },
                last_used: clock,
            },
        );
        inner.resident_bytes += nbytes;
        drop(inner);
        self.cv.notify_all();
        if let Some(hook) = self.insert_hook.get() {
            hook();
        }
        true
    }

    /// Register the insert notification callback (see [`InsertHook`]).
    /// At most one hook can be set; later calls are ignored.
    pub fn set_insert_hook(&self, hook: InsertHook) {
        let _ = self.insert_hook.set(hook);
    }

    /// [`ObjectStore::get`], but block up to `timeout` for the key to be
    /// inserted. Replaces the gather path's 500×1ms sleep poll for the
    /// local-producer race (our own executor finished the input but its
    /// insert hasn't landed yet — e.g. a stolen task raced the steal):
    /// the wait parks on the store condvar and wakes on the producer's
    /// insert, bounded by the same deadline discipline as remote fetches.
    /// Returns [`Lookup::Miss`] if the deadline expires first.
    pub fn wait_resident(&self, key: &DataKey, timeout: Duration) -> Lookup {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            inner.clock += 1;
            let clock = inner.clock;
            if let Some(e) = inner.entries.get_mut(key) {
                e.last_used = clock;
                return match &e.slot {
                    Slot::Resident(b) | Slot::Spilling(b) => Lookup::Hit(b.clone()),
                    Slot::Spilled(_) => Lookup::Spilled,
                };
            }
            let now = Instant::now();
            if now >= deadline {
                return Lookup::Miss;
            }
            // Poison carries the same meaning as the `.lock().unwrap()`
            // idiom elsewhere; recover the guard and keep waiting so a
            // panicked unrelated thread doesn't turn into a spurious miss.
            inner = match self.cv.wait_timeout(inner, deadline - now) {
                Ok((g, _)) => g,
                Err(p) => p.into_inner().0,
            };
        }
    }

    /// Record one consumption of `key` (a local gather or a serve to a
    /// peer). At zero remaining consumers the entry self-evicts; the
    /// return value is `true` exactly then, and the caller owes the
    /// server a `replica-dropped` so recovery never counts on the freed
    /// copy. Pinned entries and unknown keys are no-ops. The decrement
    /// saturates: a duplicate result re-fetched after recovery can serve
    /// more consumptions than the graph predicted.
    pub fn consume(&self, key: &DataKey) -> bool {
        let mut inner = self.inner.lock().unwrap();
        Self::consume_locked(&mut inner, key, &*self.backend)
    }

    /// [`ObjectStore::consume`] with an exactly-once guard per
    /// `(run, consumer, input)`: a task re-executed after recovery (its
    /// first result was lost with a dead worker, or its `task-finished`
    /// raced a disconnect) gathers the same inputs again, but only the
    /// first gather may decrement — the duplicate returns `false` without
    /// touching the count, so a sibling consumer's share of the input
    /// survives the re-run.
    pub fn consume_once(&self, key: &DataKey, consumer: TaskId) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if !inner.consumed.insert((key.0, consumer, key.1)) {
            return false;
        }
        Self::consume_locked(&mut inner, key, &*self.backend)
    }

    fn consume_locked(inner: &mut Inner, key: &DataKey, backend: &dyn SpillBackend) -> bool {
        let evict = match inner.entries.get_mut(key) {
            Some(e) => match e.consumers {
                Some(ref mut n) => {
                    *n = n.saturating_sub(1);
                    *n == 0
                }
                None => false,
            },
            None => false,
        };
        if evict {
            if let Some(e) = inner.entries.remove(key) {
                Inner::drop_entry(inner, e, backend);
            }
        }
        evict
    }

    /// Raise a live entry's remaining-consumer count by `delta` — the
    /// `pin-data` op: a graph extension added consumers of an output whose
    /// `compute-task` baked in a smaller count. Pinned entries stay pinned
    /// (they already outlive any consumer set), and an absent key returns
    /// `false` and is otherwise ignored: the server only pins outputs it
    /// believes resident, and the `fetch-failed` resurrection path
    /// backstops a copy that evaporated in flight.
    pub fn add_consumers(&self, key: &DataKey, delta: u32) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.entries.get_mut(key) {
            Some(e) => {
                if let Some(ref mut n) = e.consumers {
                    *n += delta;
                }
                true
            }
            None => false,
        }
    }

    /// Bring a spilled entry's bytes back to memory (cold path). Reads the
    /// backend under the store lock — that serializes concurrent restores
    /// of one key, so the slot is freed exactly once. Returns `None` when
    /// the key is gone or the backend read fails (caller treats it as a
    /// miss and falls back to the fetch/recompute path).
    pub fn restore(&self, key: &DataKey) -> Option<Arc<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        let slot_id = match inner.entries.get_mut(key) {
            Some(e) => {
                e.last_used = clock;
                match e.slot {
                    Slot::Resident(ref b) | Slot::Spilling(ref b) => {
                        return Some(b.clone()); // lint: clone-ok — Arc refcount bump
                    }
                    Slot::Spilled(id) => id,
                }
            }
            None => return None,
        };
        let bytes = match self.backend.read(slot_id) {
            Ok(b) => Arc::new(b),
            Err(_) => return None,
        };
        self.backend.free(slot_id);
        inner.restores += 1;
        let nbytes = match inner.entries.get_mut(key) {
            Some(e) => {
                e.slot = Slot::Resident(bytes.clone()); // lint: clone-ok — Arc refcount bump
                e.nbytes
            }
            // The lock is held across the read, so the entry cannot
            // vanish; defensive arm for completeness.
            None => return Some(bytes),
        };
        inner.resident_bytes += nbytes;
        Some(bytes)
    }

    /// Evict least-recently-used resident entries until resident bytes fit
    /// the budget (no-op without one). Cold path, called after inserts and
    /// restores. Backend writes happen with the store unlocked; the
    /// `Spilling` marker keeps the victim readable meanwhile and the
    /// commit step frees the slot if the entry vanished mid-write.
    pub fn maybe_spill(&self) {
        let limit = match self.limit {
            Some(l) => l,
            None => return,
        };
        // Victims already abandoned once this pass: a second pick commits
        // unconditionally, so a key that is touched on every write (hot
        // entry, or a test backend doing exactly that) cannot livelock
        // the evictor.
        let mut abandoned: std::collections::HashSet<DataKey> = std::collections::HashSet::new();
        loop {
            // Pick the LRU resident victim under the lock, remembering its
            // LRU stamp so the commit step can tell whether it was touched
            // while the bytes were being written outside the lock.
            let (key, bytes, stamp) = {
                let mut inner = self.inner.lock().unwrap();
                if inner.resident_bytes <= limit {
                    return;
                }
                let victim = inner
                    .entries
                    .iter()
                    .filter(|(_, e)| matches!(e.slot, Slot::Resident(_)))
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k);
                let key = match victim {
                    Some(k) => k,
                    // Everything is already spilling/spilled: another
                    // evictor owns the in-flight writes.
                    None => return,
                };
                let (bytes, stamp) = match inner.entries.get_mut(&key) {
                    Some(e) => match e.slot {
                        Slot::Resident(ref b) => {
                            let b = b.clone(); // lint: clone-ok — Arc refcount bump
                            e.slot = Slot::Spilling(b.clone()); // lint: clone-ok — Arc refcount bump
                            (b, e.last_used)
                        }
                        _ => continue,
                    },
                    None => continue,
                };
                (key, bytes, stamp)
            };

            // Write outside the lock; readers still hit the Spilling arc.
            let slot_id = match self.backend.write(&bytes) {
                Ok(id) => id,
                Err(_) => {
                    // Backend failure: revert to Resident and give up —
                    // better over-budget than losing the bytes.
                    let mut inner = self.inner.lock().unwrap();
                    if let Some(e) = inner.entries.get_mut(&key) {
                        if matches!(e.slot, Slot::Spilling(_)) {
                            e.slot = Slot::Resident(bytes);
                        }
                    }
                    return;
                }
            };

            // Commit: the entry may have been consumed or released
            // mid-write, or *touched* (its LRU stamp moved) — a touched
            // victim is hot again, so the spill is abandoned and the entry
            // stays resident. Either way the freshly written slot goes
            // straight back to the backend's free list: the entry never
            // learned the slot id, so nothing else can ever free it.
            let mut inner = self.inner.lock().unwrap();
            let committed = match inner.entries.get_mut(&key) {
                Some(e) if matches!(e.slot, Slot::Spilling(_)) => {
                    if e.last_used != stamp && abandoned.insert(key) {
                        e.slot = Slot::Resident(bytes);
                        None
                    } else {
                        e.slot = Slot::Spilled(slot_id);
                        Some(e.nbytes)
                    }
                }
                _ => None,
            };
            match committed {
                Some(nbytes) => {
                    inner.resident_bytes -= nbytes;
                    inner.spills += 1;
                }
                None => {
                    self.backend.free(slot_id);
                }
            }
        }
    }

    /// Retire a run: drop all its entries (freeing spill slots) and
    /// remember the run id so in-flight inserts land on the floor.
    pub fn release_run(&self, run: RunId) {
        let mut inner = self.inner.lock().unwrap();
        inner.released.insert(run);
        inner.consumed.retain(|m| m.0 != run);
        let keys: Vec<DataKey> =
            inner.entries.keys().filter(|k| k.0 == run).copied().collect();
        for k in keys {
            if let Some(e) = inner.entries.remove(&k) {
                Inner::drop_entry(&mut inner, e, &*self.backend);
            }
        }
    }

    /// Whether `run` was released (checked by executor threads before
    /// running a task popped just as the release landed).
    pub fn is_released(&self, run: RunId) -> bool {
        self.inner.lock().unwrap().released.contains(&run)
    }

    // ---- diagnostics (tests, stats line) ----

    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident_bytes
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.backend.spilled_bytes()
    }

    pub fn num_entries(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// (spill events, restore events).
    pub fn spill_stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.spills, inner.restores)
    }

    pub fn memory_limit(&self) -> Option<u64> {
        self.limit
    }

    /// Remaining consumer count of a live key (`Some(None)` = pinned).
    /// Test/oracle hook.
    pub fn refcount(&self, key: &DataKey) -> Option<Option<u32>> {
        self.inner.lock().unwrap().entries.get(key).map(|e| e.consumers)
    }

    /// Live exactly-once consumption marks (boundedness diagnostics —
    /// `release-run` must purge a run's marks with its entries).
    pub fn consumed_marks(&self) -> usize {
        self.inner.lock().unwrap().consumed.len()
    }
}

impl Inner {
    /// Free whatever a removed entry held. `Spilling` bytes stay counted
    /// out here (they are removed from resident accounting) while the
    /// in-flight evictor's commit step sees the entry gone and frees the
    /// freshly written slot itself.
    fn drop_entry(inner: &mut Inner, e: Entry, backend: &dyn SpillBackend) {
        match e.slot {
            Slot::Resident(_) | Slot::Spilling(_) => {
                inner.resident_bytes -= e.nbytes;
            }
            Slot::Spilled(slot) => {
                backend.free(slot);
            }
        }
    }
}

#[cfg(test)]
#[cfg(not(loom))]
mod tests {
    use super::*;
    use crate::worker::spill::MemSpill;

    fn key(run: u32, task: u32) -> DataKey {
        (RunId(run), TaskId(task))
    }

    fn store_with(limit: Option<u64>) -> (ObjectStore, Arc<MemSpill>) {
        let backend = Arc::new(MemSpill::new());
        (ObjectStore::new(limit, backend.clone()), backend)
    }

    fn bytes(n: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![0xAB; n])
    }

    fn assert_hit(s: &ObjectStore, k: &DataKey, len: usize) {
        match s.get(k) {
            Lookup::Hit(b) => assert_eq!(b.len(), len),
            Lookup::Spilled => panic!("expected hit, got spilled"),
            Lookup::Miss => panic!("expected hit, got miss"),
        }
    }

    #[test]
    fn refcounted_entry_self_evicts_at_zero() {
        let (s, _) = store_with(None);
        let k = key(1, 7);
        assert!(s.insert(k, bytes(10), 2));
        assert_hit(&s, &k, 10);
        assert!(!s.consume(&k), "one consumer left");
        assert_hit(&s, &k, 10);
        assert!(s.consume(&k), "last consumer drops the entry");
        assert!(matches!(s.get(&k), Lookup::Miss));
        assert_eq!(s.resident_bytes(), 0);
        assert!(!s.consume(&k), "consume of a gone key is a no-op");
    }

    #[test]
    fn pinned_entry_survives_consumption() {
        let (s, _) = store_with(None);
        let k = key(1, 7);
        assert!(s.insert(k, bytes(10), 0));
        for _ in 0..5 {
            assert!(!s.consume(&k));
        }
        assert_hit(&s, &k, 10);
        s.release_run(RunId(1));
        assert!(matches!(s.get(&k), Lookup::Miss));
    }

    #[test]
    fn duplicate_insert_is_rejected_and_harmless() {
        let (s, _) = store_with(None);
        let k = key(1, 7);
        assert!(s.insert(k, bytes(10), 1));
        assert!(!s.insert(k, bytes(99), 1), "duplicate (post-recovery rerun)");
        assert_hit(&s, &k, 10);
        assert_eq!(s.resident_bytes(), 10);
    }

    #[test]
    fn insert_after_release_lands_on_the_floor() {
        let (s, _) = store_with(None);
        s.release_run(RunId(3));
        assert!(!s.insert(key(3, 1), bytes(10), 1));
        assert!(matches!(s.get(&key(3, 1)), Lookup::Miss));
        assert!(s.is_released(RunId(3)));
        assert!(!s.is_released(RunId(4)));
        assert!(s.insert(key(4, 1), bytes(10), 1), "other runs unaffected");
    }

    #[test]
    fn lru_victim_spills_first_and_restores() {
        let (s, backend) = store_with(Some(25));
        let (ka, kb, kc) = (key(1, 1), key(1, 2), key(1, 3));
        s.insert(ka, bytes(10), 1);
        s.insert(kb, bytes(10), 1);
        // Touch `ka` so `kb` is LRU.
        assert_hit(&s, &ka, 10);
        s.insert(kc, bytes(10), 1);
        s.maybe_spill();
        assert!(s.resident_bytes() <= 25);
        assert!(matches!(s.get(&kb), Lookup::Spilled), "LRU entry spilled");
        assert_hit(&s, &ka, 10);
        assert_hit(&s, &kc, 10);
        assert_eq!(backend.spilled_bytes(), 10);

        let b = s.restore(&kb).expect("restore");
        assert_eq!(b.len(), 10);
        assert_eq!(backend.spilled_bytes(), 0, "slot freed on restore");
        assert_hit(&s, &kb, 10);
        let (spills, restores) = s.spill_stats();
        assert_eq!((spills, restores), (1, 1));
        assert_eq!(backend.misuse_count(), 0);
    }

    #[test]
    fn restore_of_resident_key_is_a_touch() {
        let (s, _) = store_with(None);
        let k = key(1, 1);
        s.insert(k, bytes(4), 1);
        assert_eq!(s.restore(&k).expect("resident restore").len(), 4);
        assert_eq!(s.spill_stats(), (0, 0));
    }

    #[test]
    fn consume_of_spilled_entry_frees_the_slot() {
        let (s, backend) = store_with(Some(5));
        let k = key(1, 1);
        s.insert(k, bytes(10), 1);
        s.maybe_spill();
        assert!(matches!(s.get(&k), Lookup::Spilled));
        assert_eq!(backend.spilled_bytes(), 10);
        assert!(s.consume(&k));
        assert_eq!(backend.spilled_bytes(), 0);
        assert_eq!(backend.misuse_count(), 0);
        assert!(matches!(s.get(&k), Lookup::Miss));
    }

    #[test]
    fn release_run_frees_spill_slots_of_that_run_only() {
        let (s, backend) = store_with(Some(0));
        s.insert(key(1, 1), bytes(8), 1);
        s.insert(key(2, 1), bytes(8), 1);
        s.maybe_spill();
        assert_eq!(backend.spilled_bytes(), 16);
        assert_eq!(s.resident_bytes(), 0);
        s.release_run(RunId(1));
        assert_eq!(backend.spilled_bytes(), 8);
        assert!(matches!(s.get(&key(1, 1)), Lookup::Miss));
        assert!(matches!(s.get(&key(2, 1)), Lookup::Spilled));
        assert_eq!(backend.misuse_count(), 0);
    }

    #[test]
    fn graph_larger_than_budget_stays_fully_readable() {
        // The spill-completion property in miniature: 10 live outputs,
        // budget fits only 3; every key must remain readable.
        let (s, backend) = store_with(Some(30));
        for t in 0..10u32 {
            s.insert(key(1, t), bytes(10), 0);
            s.maybe_spill();
            assert!(s.resident_bytes() <= 30);
        }
        for t in 0..10u32 {
            let k = key(1, t);
            let b = match s.get(&k) {
                Lookup::Hit(b) => b,
                Lookup::Spilled => s.restore(&k).expect("restore"),
                Lookup::Miss => panic!("live key {t} lost"),
            };
            assert_eq!(b.len(), 10);
            s.maybe_spill();
            assert!(s.resident_bytes() <= 30);
        }
        assert_eq!(backend.misuse_count(), 0);
        s.release_run(RunId(1));
        assert_eq!(backend.spilled_bytes(), 0);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn post_recovery_rerun_consumes_inputs_exactly_once() {
        // PR 9 bugfix regression: a task re-executed after recovery (the
        // server re-sends work whose first result was lost) gathers the
        // same input twice. Pre-fix, both gathers called `consume`,
        // double-decrementing and evicting the output while a sibling
        // consumer still needed it.
        let (s, _) = store_with(None);
        let input = key(1, 0);
        assert!(s.insert(input, bytes(10), 2), "two consumers: tasks 5 and 6");
        assert!(!s.consume_once(&input, TaskId(5)), "first gather decrements");
        assert!(!s.consume_once(&input, TaskId(5)), "re-run gather must not");
        assert_eq!(s.refcount(&input), Some(Some(1)), "sibling's share survives");
        assert_hit(&s, &input, 10);
        assert!(s.consume_once(&input, TaskId(6)), "sibling's gather is the true last");
        assert!(matches!(s.get(&input), Lookup::Miss));
    }

    #[test]
    fn release_run_purges_consumption_marks() {
        let (s, _) = store_with(None);
        let input = key(1, 0);
        s.insert(input, bytes(4), 1);
        assert!(s.consume_once(&input, TaskId(5)));
        assert_eq!(s.consumed_marks(), 1);
        s.release_run(RunId(1));
        assert_eq!(s.consumed_marks(), 0, "marks die with the run (boundedness)");
    }

    #[test]
    fn pin_data_raises_refcount_and_pinned_stays_pinned() {
        let (s, _) = store_with(None);
        let k = key(1, 7);
        s.insert(k, bytes(10), 1);
        assert!(s.add_consumers(&k, 2), "extension added two consumers");
        assert!(!s.consume(&k));
        assert!(!s.consume(&k));
        assert!(s.consume(&k), "1 + 2 consumptions total");
        assert!(!s.add_consumers(&k, 1), "absent key ignored");
        let p = key(1, 8);
        s.insert(p, bytes(10), 0);
        assert!(s.add_consumers(&p, 3));
        for _ in 0..10 {
            assert!(!s.consume(&p), "pinned stays pinned");
        }
        assert_hit(&s, &p, 10);
    }

    /// Backend wrapper that touches a store key from inside `write` —
    /// deterministically reproducing "victim touched while its bytes were
    /// being written outside the lock".
    struct TouchOnWrite {
        inner: MemSpill,
        store: Mutex<Option<Arc<ObjectStore>>>,
        touch_key: DataKey,
    }

    impl SpillBackend for TouchOnWrite {
        fn write(&self, bytes: &[u8]) -> std::io::Result<u64> {
            if let Some(s) = self.store.lock().unwrap().clone() {
                let _ = s.get(&self.touch_key);
            }
            self.inner.write(bytes)
        }
        fn read(&self, slot: u64) -> std::io::Result<Vec<u8>> {
            self.inner.read(slot)
        }
        fn free(&self, slot: u64) -> bool {
            self.inner.free(slot)
        }
        fn spilled_bytes(&self) -> u64 {
            self.inner.spilled_bytes()
        }
    }

    #[test]
    fn touched_victim_abandons_spill_without_leaking_the_slot() {
        // PR 9 bugfix regression: a victim touched mid-write abandons the
        // spill (it is hot again) — and the freshly written slot must go
        // back to the backend free list, not leak.
        let backend = Arc::new(TouchOnWrite {
            inner: MemSpill::new(),
            store: Mutex::new(None),
            touch_key: key(1, 1),
        });
        let s = Arc::new(ObjectStore::new(Some(15), backend.clone()));
        *backend.store.lock().unwrap() = Some(s.clone());
        s.insert(key(1, 1), bytes(10), 1);
        s.insert(key(1, 2), bytes(10), 1);
        // Over budget: the LRU victim is (1,1), which the backend touches
        // during the write → abandoned; the evictor then spills (1,2).
        s.maybe_spill();
        assert_hit(&s, &key(1, 1), 10);
        assert!(matches!(s.get(&key(1, 2)), Lookup::Spilled));
        assert_eq!(backend.inner.live_slots(), 1, "abandoned slot freed, not leaked");
        assert_eq!(backend.inner.misuse_count(), 0);
        assert!(s.resident_bytes() <= 15);
        // The abandoned entry restores nothing — it never left memory.
        assert_eq!(s.spill_stats().0, 1, "exactly one committed spill");
    }

    #[test]
    fn always_touched_victim_eventually_spills_instead_of_livelocking() {
        // Single over-budget entry whose every write is accompanied by a
        // touch: the second pick this pass commits unconditionally.
        let backend = Arc::new(TouchOnWrite {
            inner: MemSpill::new(),
            store: Mutex::new(None),
            touch_key: key(1, 1),
        });
        let s = Arc::new(ObjectStore::new(Some(5), backend.clone()));
        *backend.store.lock().unwrap() = Some(s.clone());
        s.insert(key(1, 1), bytes(10), 1);
        s.maybe_spill();
        assert!(matches!(s.get(&key(1, 1)), Lookup::Spilled));
        assert_eq!(backend.inner.live_slots(), 1, "one live slot, none leaked");
        assert_eq!(backend.inner.misuse_count(), 0);
    }

    #[test]
    fn unbounded_store_never_touches_the_backend() {
        let (s, backend) = store_with(None);
        for t in 0..50u32 {
            s.insert(key(1, t), bytes(100), 1);
            s.maybe_spill();
        }
        assert_eq!(s.resident_bytes(), 5000);
        assert_eq!(backend.spilled_bytes(), 0);
    }

    #[test]
    fn wait_resident_wakes_on_insert() {
        let (s, _) = store_with(None);
        let s = Arc::new(s);
        let k = key(1, 3);
        let waiter = {
            let s = s.clone();
            std::thread::spawn(move || s.wait_resident(&k, Duration::from_secs(10)))
        };
        // Give the waiter a moment to park, then insert: the wait must
        // return well before its 10s deadline.
        std::thread::sleep(Duration::from_millis(20));
        assert!(s.insert(k, bytes(4), 1));
        match waiter.join().unwrap() {
            Lookup::Hit(b) => assert_eq!(b.len(), 4),
            _ => panic!("expected hit after insert"),
        }
    }

    #[test]
    fn wait_resident_times_out_as_miss() {
        let (s, _) = store_with(None);
        let start = Instant::now();
        assert!(matches!(
            s.wait_resident(&key(9, 9), Duration::from_millis(30)),
            Lookup::Miss
        ));
        assert!(start.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn insert_hook_fires_outside_the_lock() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let (s, _) = store_with(None);
        let s = Arc::new(s);
        let fired = Arc::new(AtomicU32::new(0));
        {
            let fired = fired.clone();
            let probe = s.clone();
            s.set_insert_hook(Box::new(move || {
                // Re-entering the store from the hook must not deadlock —
                // proof the hook runs with the store lock released.
                let _ = probe.get(&key(1, 1));
                fired.fetch_add(1, Ordering::SeqCst);
            }));
        }
        assert!(s.insert(key(1, 1), bytes(1), 1));
        assert!(!s.insert(key(1, 1), bytes(1), 1), "duplicate must not refire");
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }
}
