//! Spill backends: where the object store parks bytes evicted from the
//! memory tier (see [`super::store`]).
//!
//! The store's LRU keeps *resident* bytes under `--memory-limit`; a victim
//! entry's payload is written to a backend **slot** and the entry keeps
//! only the slot id. Restores read the slot back and free it. The backend
//! owns nothing else — which entry holds which slot, and when a slot may
//! be freed, is entirely the store's bookkeeping (the loom model in
//! `tests/loom_models.rs` checks exactly that discipline: a slot is
//! written once, read-or-freed exactly once, never both).
//!
//! Two implementations:
//!
//! - [`FsSpill`] — production tier: one file per slot in a per-process
//!   temp directory, freed slot ids recycled through a free list so a
//!   long-lived worker's directory stays bounded by its *peak* spilled
//!   set, not its history.
//! - [`MemSpill`] — test tier: slots are in-memory buffers behind the
//!   model-checkable [`crate::sync::Mutex`], and misuse (double free,
//!   read-after-free) is *observable* (`Err` / `false` + a counter)
//!   instead of silently tolerated, so property tests and the loom model
//!   can assert the store never mismanages a slot.

use crate::sync::Mutex;
use std::io;
use std::path::PathBuf;

/// A tier that can hold evicted payloads. `&self` methods — backends
/// synchronize internally — so the store can write a spill victim *outside*
/// its own lock (a disk write under the store mutex would stall every
/// concurrent `get`).
pub trait SpillBackend: Send + Sync {
    /// Park `bytes`; returns the slot id that names them.
    fn write(&self, bytes: &[u8]) -> io::Result<u64>;
    /// Read a slot's bytes back (the slot stays live).
    fn read(&self, slot: u64) -> io::Result<Vec<u8>>;
    /// Release a slot for reuse. Returns whether the slot was live —
    /// `false` flags a double free (a store bug; tests assert on it).
    fn free(&self, slot: u64) -> bool;
    /// Bytes currently parked in the backend (diagnostics/tests).
    fn spilled_bytes(&self) -> u64;
}

// ---------------------------------------------------------------------
// Filesystem tier (production)
// ---------------------------------------------------------------------

#[derive(Debug, Default)]
struct FsState {
    next_slot: u64,
    free_list: Vec<u64>,
    /// Size of each live slot (slot id → bytes); also the liveness set.
    live: std::collections::HashMap<u64, u64>,
    total_bytes: u64,
}

/// One file per slot under a per-process temp directory
/// (`<tmp>/rsds-spill-<pid>-<seq>/slot-<id>`). The directory is removed on
/// drop; a crashed worker leaves it for the OS temp cleaner.
pub struct FsSpill {
    dir: PathBuf,
    state: Mutex<FsState>,
}

/// Distinguishes spill dirs of multiple workers in one process (tests run
/// whole clusters in-process).
static SPILL_DIR_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

impl FsSpill {
    /// Create the backing directory now so later writes can't fail on a
    /// missing parent.
    pub fn new() -> io::Result<FsSpill> {
        let seq = SPILL_DIR_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("rsds-spill-{}-{}", std::process::id(), seq));
        std::fs::create_dir_all(&dir)?;
        Ok(FsSpill { dir, state: Mutex::new(FsState::default()) })
    }

    fn slot_path(&self, slot: u64) -> PathBuf {
        self.dir.join(format!("slot-{slot}"))
    }
}

impl SpillBackend for FsSpill {
    fn write(&self, bytes: &[u8]) -> io::Result<u64> {
        let slot = {
            let mut s = self.state.lock().unwrap();
            s.free_list.pop().unwrap_or_else(|| {
                let id = s.next_slot;
                s.next_slot += 1;
                id
            })
        };
        if let Err(e) = std::fs::write(self.slot_path(slot), bytes) {
            self.state.lock().unwrap().free_list.push(slot);
            return Err(e);
        }
        let mut s = self.state.lock().unwrap();
        s.live.insert(slot, bytes.len() as u64);
        s.total_bytes += bytes.len() as u64;
        Ok(slot)
    }

    fn read(&self, slot: u64) -> io::Result<Vec<u8>> {
        if !self.state.lock().unwrap().live.contains_key(&slot) {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("spill slot {slot} is not live"),
            ));
        }
        std::fs::read(self.slot_path(slot))
    }

    fn free(&self, slot: u64) -> bool {
        let was_live = {
            let mut s = self.state.lock().unwrap();
            match s.live.remove(&slot) {
                Some(n) => {
                    s.total_bytes -= n;
                    s.free_list.push(slot);
                    true
                }
                None => false,
            }
        };
        if was_live {
            let _ = std::fs::remove_file(self.slot_path(slot));
        }
        was_live
    }

    fn spilled_bytes(&self) -> u64 {
        self.state.lock().unwrap().total_bytes
    }
}

impl Drop for FsSpill {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

// ---------------------------------------------------------------------
// In-memory tier (tests, property tests, loom models)
// ---------------------------------------------------------------------

#[derive(Debug, Default)]
struct MemState {
    slots: Vec<Option<Vec<u8>>>,
    free_list: Vec<u64>,
    total_bytes: u64,
    misuse: u32,
}

/// In-memory backend with observable misuse: a double `free` or a read of
/// a freed slot returns failure *and* bumps [`MemSpill::misuse_count`],
/// which the fault-injection and loom suites assert stays zero.
#[derive(Debug, Default)]
pub struct MemSpill {
    state: Mutex<MemState>,
}

impl MemSpill {
    pub fn new() -> MemSpill {
        MemSpill::default()
    }

    /// How many slot-discipline violations (double free, read-after-free)
    /// the backend has observed. Zero iff the store's slot bookkeeping is
    /// correct.
    pub fn misuse_count(&self) -> u32 {
        self.state.lock().unwrap().misuse
    }

    /// Number of live (written, not yet freed) slots.
    pub fn live_slots(&self) -> usize {
        self.state.lock().unwrap().slots.iter().flatten().count()
    }
}

impl SpillBackend for MemSpill {
    fn write(&self, bytes: &[u8]) -> io::Result<u64> {
        let mut s = self.state.lock().unwrap();
        s.total_bytes += bytes.len() as u64;
        match s.free_list.pop() {
            Some(slot) => {
                s.slots[slot as usize] = Some(bytes.to_vec());
                Ok(slot)
            }
            None => {
                s.slots.push(Some(bytes.to_vec()));
                Ok(s.slots.len() as u64 - 1)
            }
        }
    }

    fn read(&self, slot: u64) -> io::Result<Vec<u8>> {
        let mut s = self.state.lock().unwrap();
        match s.slots.get(slot as usize).and_then(|o| o.as_ref()) {
            Some(b) => Ok(b.clone()), // lint: clone-ok — handing bytes back out of the tier
            None => {
                s.misuse += 1;
                Err(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("read of dead spill slot {slot}"),
                ))
            }
        }
    }

    fn free(&self, slot: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        match s.slots.get_mut(slot as usize).and_then(Option::take) {
            Some(b) => {
                s.total_bytes -= b.len() as u64;
                s.free_list.push(slot);
                true
            }
            None => {
                s.misuse += 1;
                false
            }
        }
    }

    fn spilled_bytes(&self) -> u64 {
        self.state.lock().unwrap().total_bytes
    }
}

#[cfg(test)]
#[cfg(not(loom))]
mod tests {
    use super::*;

    fn exercise(backend: &dyn SpillBackend) {
        let a = backend.write(b"alpha").unwrap();
        let b = backend.write(b"bravo-bravo").unwrap();
        assert_ne!(a, b);
        assert_eq!(backend.spilled_bytes(), 16);
        assert_eq!(backend.read(a).unwrap(), b"alpha");
        assert_eq!(backend.read(a).unwrap(), b"alpha", "read does not consume");
        assert!(backend.free(a));
        assert_eq!(backend.spilled_bytes(), 11);
        assert!(backend.read(a).is_err(), "freed slot is dead");
        assert!(!backend.free(a), "double free reported");
        // Freed ids recycle.
        let c = backend.write(b"charlie").unwrap();
        assert_eq!(c, a, "slot id reused from the free list");
        assert_eq!(backend.read(b).unwrap(), b"bravo-bravo");
        assert!(backend.free(b));
        assert!(backend.free(c));
        assert_eq!(backend.spilled_bytes(), 0);
    }

    #[test]
    fn mem_spill_discipline() {
        let m = MemSpill::new();
        exercise(&m);
        assert_eq!(m.misuse_count(), 2, "the two deliberate misuses above");
        assert_eq!(m.live_slots(), 0);
    }

    #[test]
    fn fs_spill_discipline() {
        let f = FsSpill::new().unwrap();
        let dir = f.dir.clone();
        exercise(&f);
        assert!(dir.exists());
        drop(f);
        assert!(!dir.exists(), "spill dir removed on drop");
    }

    #[test]
    fn fs_spill_dirs_are_distinct() {
        let a = FsSpill::new().unwrap();
        let b = FsSpill::new().unwrap();
        assert_ne!(a.dir, b.dir);
    }
}
