//! The zero worker (paper §IV-D): "a minimal implementation of the DASK
//! worker ... Its purpose is to simulate a worker with infinite
//! computational speed, infinitely fast worker-to-worker transfers and zero
//! additional overhead."
//!
//! - Compute requests are answered with an immediate `task-finished`.
//! - A set of data objects that *would* live here is remembered; inputs not
//!   in the set are treated as instantly downloaded (no w2w traffic at all).
//! - Data fetches from the server are answered with a small mocked constant
//!   object.
//! - Steal requests always fail: "since the tasks are computed immediately,
//!   any potential attempts to steal a task from a worker will fail" (§VI-D).

use super::WorkerConfig;
use crate::protocol::{decode_msg, encode_msg, read_frame, write_frame, FrameError, Msg, RunId, TaskFinishedInfo};
use crate::taskgraph::TaskId;
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Mocked constant object returned for data fetches (§IV-D).
pub const MOCK_DATA: &[u8] = b"zero-worker-mock";

/// Handle to a running zero worker.
pub struct ZeroWorkerHandle {
    pub id: u32,
    stop: Arc<AtomicBool>,
    stream: Arc<Mutex<TcpStream>>,
}

impl ZeroWorkerHandle {
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let s = self.stream.lock().unwrap();
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
}

/// Start a zero worker; returns after registration.
pub fn run_zero_worker(cfg: WorkerConfig) -> Result<ZeroWorkerHandle> {
    let mut stream = TcpStream::connect(&cfg.server_addr)
        .with_context(|| format!("connect {}", cfg.server_addr))?;
    stream.set_nodelay(true).ok();
    write_frame(
        &mut stream,
        &encode_msg(&Msg::RegisterWorker {
            name: cfg.name.clone(),
            ncores: cfg.ncores,
            node: cfg.node,
            // Zero workers never serve peer fetches (no w2w communication).
            data_addr: String::new(),
        }),
    )?;
    let reply = decode_msg(&read_frame(&mut stream)?)?;
    let Msg::Welcome { id } = reply else {
        bail!("expected welcome, got {:?}", reply.op());
    };

    let stop = Arc::new(AtomicBool::new(false));
    let wstream = Arc::new(Mutex::new(stream.try_clone().context("clone")?));
    {
        let stop = stop.clone();
        let wstream = wstream.clone();
        std::thread::spawn(move || {
            // Data objects that would be placed on this worker (runs share
            // the connection, so keys carry the run).
            let mut would_have: HashSet<(RunId, TaskId)> = HashSet::new();
            let send = |msg: &Msg| -> Result<()> {
                let mut s = wstream.lock().unwrap();
                write_frame(&mut *s, &encode_msg(msg))?;
                Ok(())
            };
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let msg = match read_frame(&mut stream) {
                    Ok(bytes) => match decode_msg(&bytes) {
                        Ok(m) => m,
                        Err(_) => break,
                    },
                    Err(FrameError::Closed) => break,
                    Err(_) => break,
                };
                match msg {
                    Msg::ComputeTask { run, task, inputs, output_size, .. } => {
                        // Infinitely fast download of any missing input.
                        for loc in &inputs {
                            would_have.insert((run, loc.task));
                        }
                        would_have.insert((run, task));
                        // Immediate completion, zero duration.
                        if send(&Msg::TaskFinished(TaskFinishedInfo {
                            run,
                            task,
                            nbytes: output_size,
                            duration_us: 0,
                        }))
                        .is_err()
                        {
                            break;
                        }
                    }
                    Msg::StealRequest { run, task } => {
                        // Already "finished" — retraction always fails.
                        if send(&Msg::StealResponse { run, task, ok: false }).is_err() {
                            break;
                        }
                    }
                    Msg::FetchFromServer { run, task } => {
                        let _present = would_have.contains(&(run, task));
                        if send(&Msg::DataToServer { run, task, data: MOCK_DATA.to_vec() })
                            .is_err()
                        {
                            break;
                        }
                    }
                    Msg::ReleaseRun { run } => {
                        would_have.retain(|&(r, _)| r != run);
                    }
                    Msg::Shutdown => break,
                    Msg::Heartbeat | Msg::Welcome { .. } => {}
                    other => log::warn!("zero worker: unexpected {:?}", other.op()),
                }
            }
        });
    }
    Ok(ZeroWorkerHandle { id, stop, stream: wstream })
}
