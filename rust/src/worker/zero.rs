//! The zero worker (paper §IV-D): "a minimal implementation of the DASK
//! worker ... Its purpose is to simulate a worker with infinite
//! computational speed, infinitely fast worker-to-worker transfers and zero
//! additional overhead."
//!
//! - Compute requests are answered with an immediate `task-finished`.
//! - A set of data objects that *would* live here is remembered; inputs not
//!   in the set are treated as instantly downloaded (no w2w traffic at all).
//! - Data fetches from the server are answered with a small mocked constant
//!   object.
//! - Steal requests always fail: "since the tasks are computed immediately,
//!   any potential attempts to steal a task from a worker will fail" (§VI-D).

use super::WorkerConfig;
use crate::protocol::{
    decode_msg, peek_op, ComputeTaskView, FrameError, FrameReader, FrameWriter, Msg, RunId,
    TaskFinishedInfo,
};
use crate::taskgraph::TaskId;
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::net::TcpStream;
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Arc, Mutex};

/// Mocked constant object returned for data fetches (§IV-D).
pub const MOCK_DATA: &[u8] = b"zero-worker-mock";

/// Send half: stream plus reused frame buffer (the zero worker answers
/// every compute message, so its send path is as hot as the server's).
struct ZeroLink {
    stream: TcpStream,
    frames: FrameWriter,
}

/// Handle to a running zero worker.
pub struct ZeroWorkerHandle {
    pub id: u32,
    stop: Arc<AtomicBool>,
    link: Arc<Mutex<ZeroLink>>,
}

impl ZeroWorkerHandle {
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let link = self.link.lock().unwrap();
        let _ = link.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Start a zero worker; returns after registration.
pub fn run_zero_worker(cfg: WorkerConfig) -> Result<ZeroWorkerHandle> {
    let mut stream = crate::util::connect_with_retry(cfg.server_addr.as_str())
        .with_context(|| format!("connect {}", cfg.server_addr))?;
    stream.set_nodelay(true).ok();
    let mut register_frames = FrameWriter::new();
    register_frames.send(
        &mut stream,
        &Msg::RegisterWorker {
            name: cfg.name.clone(),
            ncores: cfg.ncores,
            node: cfg.node,
            // Zero workers never serve peer fetches (no w2w communication).
            data_addr: String::new(),
        },
    )?;
    let mut frames_in = FrameReader::new();
    let reply = decode_msg(frames_in.read(&mut stream)?)?;
    let Msg::Welcome { id } = reply else {
        bail!("expected welcome, got {:?}", reply.op());
    };

    let stop = Arc::new(AtomicBool::new(false));
    let link = Arc::new(Mutex::new(ZeroLink {
        stream: stream.try_clone().context("clone")?,
        frames: register_frames,
    }));
    {
        let stop = stop.clone();
        let link = link.clone();
        std::thread::spawn(move || {
            let mut frames_in = frames_in;
            // Data objects that would be placed on this worker (runs share
            // the connection, so keys carry the run).
            let mut would_have: HashSet<(RunId, TaskId)> = HashSet::new();
            let send = |msg: &Msg| -> Result<()> {
                let mut l = link.lock().unwrap();
                let ZeroLink { stream, frames } = &mut *l;
                frames.send(stream, msg)?;
                Ok(())
            };
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let bytes = match frames_in.read(&mut stream) {
                    Ok(bytes) => bytes,
                    Err(FrameError::Closed) => break,
                    Err(_) => break,
                };
                // The zero worker is the §VI-D message-throughput probe:
                // decode assignments through the borrowed view so its
                // per-task path is as allocation-free as the server's.
                if matches!(peek_op(bytes), Ok("compute-task")) {
                    let Ok(view) = ComputeTaskView::decode(bytes) else { break };
                    // Infinitely fast download of any missing input.
                    let mut bad_inputs = false;
                    for loc in view.inputs() {
                        match loc {
                            Ok(l) => {
                                would_have.insert((view.run, l.task));
                            }
                            Err(_) => {
                                bad_inputs = true;
                                break;
                            }
                        }
                    }
                    if bad_inputs {
                        break;
                    }
                    would_have.insert((view.run, view.task));
                    // Immediate completion, zero duration.
                    if send(&Msg::TaskFinished(TaskFinishedInfo {
                        run: view.run,
                        task: view.task,
                        nbytes: view.output_size,
                        duration_us: 0,
                    }))
                    .is_err()
                    {
                        break;
                    }
                    continue;
                }
                let msg = match decode_msg(bytes) {
                    Ok(m) => m,
                    Err(_) => break,
                };
                match msg {
                    Msg::StealRequest { run, task } => {
                        // Already "finished" — retraction always fails.
                        if send(&Msg::StealResponse { run, task, ok: false }).is_err() {
                            break;
                        }
                    }
                    Msg::FetchFromServer { run, task } => {
                        let _present = would_have.contains(&(run, task));
                        if send(&Msg::DataToServer { run, task, data: MOCK_DATA.to_vec() })
                            .is_err()
                        {
                            break;
                        }
                    }
                    Msg::CancelCompute { .. } => {
                        // Tasks finish instantly, so there is never a queued
                        // copy to drop — mirror of "steals always fail".
                    }
                    Msg::ReleaseRun { run } => {
                        would_have.retain(|&(r, _)| r != run);
                    }
                    Msg::Shutdown => break,
                    Msg::Heartbeat | Msg::Welcome { .. } => {}
                    other => log::warn!("zero worker: unexpected {:?}", other.op()),
                }
            }
        });
    }
    Ok(ZeroWorkerHandle { id, stop, link })
}
