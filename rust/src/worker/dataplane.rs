//! The worker↔worker data plane, client side: pooled peer links and the
//! pipelined input gather (PR 10).
//!
//! Through PR 9 the data plane was the most naive path left in the
//! worker: every input fetch opened a fresh TCP connection, the gather
//! loop fetched inputs strictly sequentially while an executor slot sat
//! idle, and a replica push cloned its whole payload to build an owned
//! message. This module replaces all of that:
//!
//! - **[`LinkPool`]** keeps one long-lived connection per peer data
//!   address (bounded, LRU-closed). Links are generation-tagged per
//!   address: a dead-link eviction bumps the address's generation, so a
//!   connection checked out before the eviction can never re-enter the
//!   pool afterwards (`tests/loom_models.rs` model-checks this race).
//!   Dead links feed the existing failover path — eviction plus a
//!   per-input replica walk — so a stale pooled connection degrades to
//!   exactly the recovery story a fresh connect failure has.
//! - **[`DataPlane::gather`]** resolves a popped task's inputs in
//!   phases: one pass classifies each input (local hit / remote / wait
//!   for a local producer), remote inputs are coalesced into one
//!   `fetch-data-many` request per peer and issued *up front* (bounded
//!   in-flight window per peer), the local-producer waits then park on
//!   the store condvar while the replies are already in flight, and
//!   only then are the replies drained in order. Any per-peer failure
//!   downgrades that peer's unreceived inputs to the per-input failover
//!   walk, so batching never weakens recovery.
//! - **Deadlines everywhere.** Connects, reads and writes all carry
//!   timeouts ([`DataPlaneConfig`]); a hung-but-not-dead peer surfaces
//!   as a recoverable `fetch-failed:` error instead of wedging an
//!   executor thread forever.
//! - **Zero-copy push.** [`DataPlane::push`] streams a `put-data` frame
//!   directly from the store's `Arc<Vec<u8>>` via the split
//!   [`encode_data_frame_head`]/[`encode_data_frame_tail`] encoders —
//!   the payload is never copied into an encode buffer.
//!
//! `pooled: false` preserves the pre-PR-10 behavior — sequential
//! connect-per-fetch — as the measured baseline of
//! `benches/fig_dataplane.rs`.

use super::queue::FetchPlan;
use super::store::{DataKey, Lookup, ObjectStore};
use crate::protocol::{
    decode_msg, encode_data_frame_head, encode_data_frame_tail, encode_fetch_many_into,
    encode_msg_into, DataFrameParts, FrameReader, Msg, RunId, FETCH_FAILED_PREFIX,
    MAX_FRAME_LEN,
};
use crate::sync::{Arc, Mutex};
use crate::taskgraph::TaskId;
use std::collections::HashMap;
use std::io::{self, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Tunables for the data plane. The defaults are what `run_worker` uses;
/// benches flip `pooled` off to measure the connect-per-fetch baseline.
#[derive(Debug, Clone)]
pub struct DataPlaneConfig {
    /// Use the persistent link pool and batched gather. `false` restores
    /// the pre-PR-10 behavior (fresh connection per fetch, sequential
    /// gather) as a measurable baseline.
    pub pooled: bool,
    /// Maximum idle links kept across all peers; the least-recently-used
    /// idle link is closed to admit a new one.
    pub pool_capacity: usize,
    /// Deadline for establishing a peer connection.
    pub connect_timeout_ms: u64,
    /// Deadline for each read/write on a peer link. A peer that accepts
    /// but never answers (hung, not dead) trips this and flows into the
    /// failover path.
    pub io_timeout_ms: u64,
    /// Objects per `fetch-data-many` request; the in-flight window per
    /// peer is two requests (double-buffered), bounding how far requests
    /// run ahead of reply draining.
    pub max_batch: usize,
    /// How long a gather waits for a *local* producer to land its insert
    /// (steal race) before declaring the input lost. Event-driven — the
    /// store condvar wakes the waiter on insert.
    pub local_wait_ms: u64,
    /// Server side: how long the data server parks a fetch for a key it
    /// does not hold yet before dropping the connection (the producer's
    /// local insert may trail the server's `who_has` advertisement).
    pub serve_park_ms: u64,
}

impl Default for DataPlaneConfig {
    fn default() -> DataPlaneConfig {
        DataPlaneConfig {
            pooled: true,
            pool_capacity: 32,
            connect_timeout_ms: 1_000,
            io_timeout_ms: 5_000,
            max_batch: 64,
            local_wait_ms: 500,
            serve_park_ms: 500,
        }
    }
}

// ---------- link pool ----------

struct Idle<T> {
    gen: u64,
    last_used: u64,
    link: T,
}

struct PoolInner<T> {
    idle: Vec<Idle<T>>,
    /// Per-address eviction generation. Bumped by [`LinkPool::evict`];
    /// a check-in whose generation snapshot predates the bump is
    /// rejected, so a link that was in flight across an eviction can
    /// never re-enter the pool.
    gens: HashMap<String, u64>,
    clock: u64,
}

/// Bounded pool of idle peer links, shared by every executor thread.
/// Generic over the link type so the checkout-vs-eviction race can be
/// model-checked without sockets; `addr_of` projects a link to the peer
/// address it is connected to.
pub struct LinkPool<T> {
    inner: Mutex<PoolInner<T>>,
    capacity: usize,
    addr_of: fn(&T) -> &str,
}

impl<T> LinkPool<T> {
    pub fn new(capacity: usize, addr_of: fn(&T) -> &str) -> LinkPool<T> {
        LinkPool {
            inner: Mutex::new(PoolInner { idle: Vec::new(), gens: HashMap::new(), clock: 0 }),
            capacity: capacity.max(1),
            addr_of,
        }
    }

    /// Take an idle link to `addr`, with its generation snapshot. Hot
    /// path (registered in `xtask/hotpath.txt`): a warm checkout is a
    /// linear scan under the pool lock, no allocation.
    pub fn checkout(&self, addr: &str) -> Option<(T, u64)> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let mut found = None;
        for i in 0..inner.idle.len() {
            if (self.addr_of)(&inner.idle[i].link) == addr {
                found = Some(i);
                break;
            }
        }
        let i = found?;
        let idle = inner.idle.swap_remove(i);
        Some((idle.link, idle.gen))
    }

    /// Current eviction generation of `addr` — the snapshot a freshly
    /// connected link must carry so a concurrent eviction invalidates it.
    pub fn generation(&self, addr: &str) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.gens.get(addr).copied().unwrap_or(0)
    }

    /// Return a link to the pool. Rejected (link dropped, returns
    /// `false`) when `gen` is stale — an eviction of this address
    /// happened while the link was out. Admitting over capacity closes
    /// the least-recently-used idle link.
    pub fn checkin(&self, gen: u64, link: T) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let current = {
            let addr = (self.addr_of)(&link);
            inner.gens.get(addr).copied().unwrap_or(0)
        };
        if gen != current {
            return false;
        }
        if inner.idle.len() >= self.capacity {
            let mut lru = 0;
            for i in 1..inner.idle.len() {
                if inner.idle[i].last_used < inner.idle[lru].last_used {
                    lru = i;
                }
            }
            inner.idle.swap_remove(lru);
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.idle.push(Idle { gen, last_used: stamp, link });
        true
    }

    /// Declare every link to `addr` dead: drop the idle ones and bump the
    /// generation so in-flight ones cannot come back.
    pub fn evict(&self, addr: &str) {
        let mut inner = self.inner.lock().unwrap();
        let addr_of = self.addr_of;
        inner.idle.retain(|l| addr_of(&l.link) != addr);
        *inner.gens.entry(addr.to_string()).or_insert(0) += 1;
    }

    /// Number of idle links currently pooled (tests/metrics).
    pub fn idle_len(&self) -> usize {
        self.inner.lock().unwrap().idle.len()
    }
}

// ---------- peer link ----------

/// One established connection to a peer's data server, with its reused
/// encode buffer and frame reader.
struct PeerLink {
    addr: String,
    stream: TcpStream,
    frames_in: FrameReader,
    wbuf: Vec<u8>,
}

fn link_addr(l: &PeerLink) -> &str {
    &l.addr
}

/// Back-patch the 8-byte length prefix at `buf[..8]`.
fn finish_frame(buf: &mut Vec<u8>, payload_extra: usize) -> io::Result<()> {
    let len = (buf.len() - 8 + payload_extra) as u64;
    if len > MAX_FRAME_LEN {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame exceeds MAX_FRAME_LEN"));
    }
    buf[..8].copy_from_slice(&len.to_le_bytes());
    Ok(())
}

impl PeerLink {
    fn connect(addr: &str, cfg: &DataPlaneConfig) -> io::Result<PeerLink> {
        let sockaddr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "unresolvable peer address"))?;
        let stream =
            TcpStream::connect_timeout(&sockaddr, Duration::from_millis(cfg.connect_timeout_ms))?;
        stream.set_nodelay(true).ok();
        let io_deadline = Some(Duration::from_millis(cfg.io_timeout_ms.max(1)));
        stream.set_read_timeout(io_deadline).ok();
        stream.set_write_timeout(io_deadline).ok();
        Ok(PeerLink {
            addr: addr.to_string(),
            stream,
            frames_in: FrameReader::new(),
            wbuf: Vec::new(),
        })
    }

    fn send_msg(&mut self, msg: &Msg) -> io::Result<()> {
        self.wbuf.clear();
        self.wbuf.extend_from_slice(&[0u8; 8]);
        encode_msg_into(msg, &mut self.wbuf);
        finish_frame(&mut self.wbuf, 0)?;
        self.stream.write_all(&self.wbuf)
    }

    /// One coalesced `fetch-data-many` request from a borrowed id slice —
    /// no owned message is built on the gather issue path.
    fn send_fetch_many(&mut self, run: RunId, tasks: &[TaskId]) -> io::Result<()> {
        self.wbuf.clear();
        self.wbuf.extend_from_slice(&[0u8; 8]);
        encode_fetch_many_into(run, tasks, &mut self.wbuf);
        finish_frame(&mut self.wbuf, 0)?;
        self.stream.write_all(&self.wbuf)
    }

    /// Stream a data-bearing frame whose payload is written straight from
    /// the caller's buffer (the store's `Arc<Vec<u8>>` on the push path):
    /// head and tail are encoded into the reused link buffer, the payload
    /// bytes never are.
    fn send_data_frame(
        &mut self,
        op: &'static str,
        run: RunId,
        task: TaskId,
        payload: &[u8],
    ) -> io::Result<()> {
        let parts = DataFrameParts { op, run, task, data_len: payload.len() };
        self.wbuf.clear();
        self.wbuf.extend_from_slice(&[0u8; 8]);
        encode_data_frame_head(&parts, &mut self.wbuf);
        let head_end = self.wbuf.len();
        encode_data_frame_tail(&parts, &mut self.wbuf);
        finish_frame(&mut self.wbuf, payload.len())?;
        self.stream.write_all(&self.wbuf[..head_end])?;
        self.stream.write_all(payload)?;
        self.stream.write_all(&self.wbuf[head_end..])
    }
}

// ---------- gather scratch ----------

/// Per-peer batch built during classification. `rep` is the
/// `(input index, replica index)` whose address names the peer; `idxs`
/// and `tasks` are the member inputs in plan order.
#[derive(Default)]
struct PeerGroup {
    rep: (usize, usize),
    idxs: Vec<usize>,
    tasks: Vec<TaskId>,
    link: Option<(PeerLink, u64)>,
    /// Objects requested so far (window bookkeeping).
    sent: usize,
    /// Objects received so far.
    received: usize,
}

/// Reusable per-executor gather state: retained buffers, so a warm
/// gather allocates only the payload `Arc`s themselves.
#[derive(Default)]
pub struct GatherScratch {
    /// Gathered inputs in plan order — valid after a successful
    /// [`DataPlane::gather`], consumed by the executor.
    pub inputs: Vec<Arc<Vec<u8>>>,
    /// Input tasks whose local copy self-evicted during this gather's
    /// `consume_once`; the caller owes the server one `replica-dropped`
    /// per entry.
    pub dropped: Vec<TaskId>,
    slots: Vec<Option<Arc<Vec<u8>>>>,
    groups: Vec<PeerGroup>,
    n_groups: usize,
    /// Inputs with no remote source: wait for the local producer.
    waits: Vec<usize>,
    /// Inputs downgraded to the per-input failover walk.
    retries: Vec<usize>,
}

fn resolve_addr<'p>(plan: &'p FetchPlan, rep: (usize, usize)) -> &'p str {
    if rep.1 == 0 {
        plan.input(rep.0).2
    } else {
        plan.input_alt(rep.0, rep.1 - 1)
    }
}

/// First usable replica of input `i`, in rotation order. The start index
/// rotates with the consuming task id so the many consumers of one hot
/// output spread across its copies (same discipline as the failover
/// walk). Empty addresses (local placement) are skipped.
fn first_candidate<'p>(
    plan: &'p FetchPlan,
    i: usize,
    consumer: TaskId,
) -> Option<(usize, &'p str)> {
    let n = 1 + plan.n_alts(i);
    let start = consumer.0 as usize % n;
    for j in 0..n {
        let idx = (start + j) % n;
        let addr = if idx == 0 { plan.input(i).2 } else { plan.input_alt(i, idx - 1) };
        if !addr.is_empty() {
            return Some((idx, addr));
        }
    }
    None
}

impl GatherScratch {
    pub fn new() -> GatherScratch {
        GatherScratch::default()
    }

    fn reset(&mut self, n_inputs: usize) {
        self.inputs.clear();
        self.dropped.clear();
        self.slots.clear();
        self.slots.resize(n_inputs, None);
        self.waits.clear();
        self.retries.clear();
        for g in &mut self.groups {
            // A link surviving here means the previous gather errored out
            // mid-flight; dropping it closes the socket.
            g.link = None;
        }
        self.n_groups = 0;
    }

    /// Index of the group whose peer address is `addr`, creating (or
    /// reusing a retained) group if none matches yet.
    fn group_for(&mut self, plan: &FetchPlan, rep: (usize, usize), addr: &str) -> usize {
        for k in 0..self.n_groups {
            if resolve_addr(plan, self.groups[k].rep) == addr {
                return k;
            }
        }
        if self.n_groups == self.groups.len() {
            self.groups.push(PeerGroup::default());
        }
        let k = self.n_groups;
        self.n_groups += 1;
        let g = &mut self.groups[k];
        g.rep = rep;
        g.idxs.clear();
        g.tasks.clear();
        g.link = None;
        g.sent = 0;
        g.received = 0;
        k
    }

    /// Downgrade a group's unreceived inputs to the failover walk and
    /// surrender its link (the caller evicts the address and drops it).
    fn fail_group(&mut self, k: usize) -> Option<PeerLink> {
        let g = &mut self.groups[k];
        for j in g.received..g.idxs.len() {
            self.retries.push(g.idxs[j]);
        }
        g.link.take().map(|(l, _)| l)
    }

    fn drop_links(&mut self) {
        for k in 0..self.n_groups {
            self.groups[k].link = None;
        }
    }
}

// ---------- data plane ----------

/// Store lookup that transparently restores a spilled entry (and
/// rebalances the budget afterwards). `None` = genuinely absent.
pub(crate) fn lookup_restoring(store: &ObjectStore, key: &DataKey) -> Option<Arc<Vec<u8>>> {
    match store.get(key) {
        Lookup::Hit(d) => Some(d),
        Lookup::Spilled => {
            let restored = store.restore(key);
            store.maybe_spill();
            restored
        }
        Lookup::Miss => None,
    }
}

/// The worker's data-plane client: the link pool plus the gather and
/// push entry points. One per worker, shared by all executor threads and
/// the replica pusher.
pub struct DataPlane {
    cfg: DataPlaneConfig,
    pool: LinkPool<PeerLink>,
}

impl DataPlane {
    pub fn new(cfg: DataPlaneConfig) -> DataPlane {
        let capacity = cfg.pool_capacity;
        DataPlane { pool: LinkPool::new(capacity, link_addr), cfg }
    }

    pub fn config(&self) -> &DataPlaneConfig {
        &self.cfg
    }

    fn acquire(&self, addr: &str) -> io::Result<(PeerLink, u64)> {
        if let Some(out) = self.pool.checkout(addr) {
            return Ok(out);
        }
        // Generation snapshot *before* the connect: an eviction racing
        // the connect invalidates this link conservatively.
        let gen = self.pool.generation(addr);
        let link = PeerLink::connect(addr, &self.cfg)?;
        Ok((link, gen))
    }

    /// Gather every input of `plan` into `scratch.inputs` (plan order),
    /// recording each input's exactly-once consumption against
    /// `consumer`. On success `scratch.dropped` lists the inputs whose
    /// local copy self-evicted (the caller owes `replica-dropped`s).
    /// Errors carry the recoverable `fetch-failed:` prefix where every
    /// source of some input was unreachable.
    pub fn gather(
        &self,
        store: &ObjectStore,
        run: RunId,
        consumer: TaskId,
        plan: &FetchPlan,
        scratch: &mut GatherScratch,
    ) -> Result<(), String> {
        scratch.reset(plan.n_inputs());
        self.classify(store, run, consumer, plan, scratch);
        if self.cfg.pooled {
            self.issue(run, plan, scratch);
        } else {
            // Baseline: every remote input walks the sequential
            // connect-per-fetch failover path.
            for k in 0..scratch.n_groups {
                let _ = scratch.fail_group(k);
            }
        }
        let result = self.gather_finish(store, run, consumer, plan, scratch);
        if result.is_err() {
            scratch.drop_links();
        }
        result
    }

    fn gather_finish(
        &self,
        store: &ObjectStore,
        run: RunId,
        consumer: TaskId,
        plan: &FetchPlan,
        scratch: &mut GatherScratch,
    ) -> Result<(), String> {
        // Local-producer waits overlap the in-flight remote replies: the
        // requests are already on the wire, so parking here costs the
        // remote path nothing.
        self.resolve_local_waits(store, run, plan, scratch)?;
        if self.cfg.pooled {
            self.read_replies(store, run, scratch);
        }
        self.retry_failover(store, run, consumer, plan, scratch)?;
        // Every input resolved: record the consumptions and hand the
        // payloads over in plan order.
        for i in 0..plan.n_inputs() {
            let (task, _nbytes, _addr) = plan.input(i);
            if store.consume_once(&(run, task), consumer) {
                scratch.dropped.push(task);
            }
            match scratch.slots[i].take() {
                Some(d) => scratch.inputs.push(d),
                None => {
                    return Err(format!(
                        "{FETCH_FAILED_PREFIX}input {} for {} missing after gather",
                        task,
                        plan.key()
                    ))
                }
            }
        }
        Ok(())
    }

    /// One pass over the plan: local hits fill their slot, remote inputs
    /// join their peer's batch, sourceless misses queue for the local
    /// producer wait. Hot path (registered in `xtask/hotpath.txt`): a
    /// warm all-local classify allocates nothing.
    fn classify(
        &self,
        store: &ObjectStore,
        run: RunId,
        consumer: TaskId,
        plan: &FetchPlan,
        scratch: &mut GatherScratch,
    ) {
        for i in 0..plan.n_inputs() {
            let (task, _nbytes, _addr) = plan.input(i);
            if let Some(d) = lookup_restoring(store, &(run, task)) {
                scratch.slots[i] = Some(d);
                continue;
            }
            match first_candidate(plan, i, consumer) {
                Some((rep_idx, addr)) => {
                    let k = scratch.group_for(plan, (i, rep_idx), addr);
                    let g = &mut scratch.groups[k];
                    g.idxs.push(i);
                    g.tasks.push(task);
                }
                None => scratch.waits.push(i),
            }
        }
    }

    /// Acquire one link per peer group and put the initial request
    /// window on the wire for *all* groups before any reply is read —
    /// every peer starts serving concurrently. Failures downgrade the
    /// group to the failover walk.
    fn issue(&self, run: RunId, plan: &FetchPlan, scratch: &mut GatherScratch) {
        for k in 0..scratch.n_groups {
            let rep = scratch.groups[k].rep;
            let addr = resolve_addr(plan, rep);
            match self.acquire(addr) {
                Ok((link, gen)) => {
                    let g = &mut scratch.groups[k];
                    g.link = Some((link, gen));
                    if Self::top_up(g, run, self.cfg.max_batch).is_err() {
                        if let Some(link) = scratch.fail_group(k) {
                            self.pool.evict(&link.addr);
                        }
                    }
                }
                Err(e) => {
                    log::debug!("worker: connect {addr} for batched fetch failed: {e}");
                    let _ = scratch.fail_group(k);
                }
            }
        }
    }

    /// Keep the peer's request window full: at most two
    /// `fetch-data-many` requests (2 × `max_batch` objects) ahead of the
    /// replies drained so far.
    fn top_up(g: &mut PeerGroup, run: RunId, max_batch: usize) -> io::Result<()> {
        let total = g.tasks.len();
        let batch = max_batch.max(1);
        let window = batch * 2;
        while g.sent < total && g.sent - g.received < window {
            let end = (g.sent + batch).min(total);
            match g.link.as_mut() {
                Some((link, _)) => link.send_fetch_many(run, &g.tasks[g.sent..end])?,
                None => return Ok(()),
            }
            g.sent = end;
        }
        Ok(())
    }

    /// Drain each group's replies in request order, topping up the
    /// window as objects land. A failure mid-group downgrades the
    /// *unreceived* remainder to the failover walk — objects already
    /// received stay gathered.
    fn read_replies(&self, store: &ObjectStore, run: RunId, scratch: &mut GatherScratch) {
        for k in 0..scratch.n_groups {
            if scratch.groups[k].link.is_none() {
                continue;
            }
            let mut failed = false;
            while scratch.groups[k].received < scratch.groups[k].idxs.len() {
                let step = {
                    let g = &mut scratch.groups[k];
                    if Self::top_up(g, run, self.cfg.max_batch).is_err() {
                        None
                    } else {
                        let expect = g.tasks[g.received];
                        let slot_idx = g.idxs[g.received];
                        match g.link.as_mut() {
                            Some((link, _)) => match Self::read_reply(link, run, expect) {
                                Ok(data) => {
                                    g.received += 1;
                                    Some((slot_idx, expect, data))
                                }
                                Err(e) => {
                                    log::debug!(
                                        "worker: batched fetch from {} failed: {e}",
                                        link.addr
                                    );
                                    None
                                }
                            },
                            None => None,
                        }
                    }
                };
                match step {
                    Some((slot_idx, task, data)) => {
                        let arc = Arc::new(data);
                        // Passive fetch cache: pinned (release-run
                        // reclaims it) and deliberately *not* advertised
                        // to the server — who_has only lists copies the
                        // server ordered or was told about, so recovery
                        // never counts on this one.
                        store.insert((run, task), arc.clone(), 0);
                        store.maybe_spill();
                        scratch.slots[slot_idx] = Some(arc);
                    }
                    None => {
                        failed = true;
                        break;
                    }
                }
            }
            if failed {
                if let Some(link) = scratch.fail_group(k) {
                    self.pool.evict(&link.addr);
                }
            } else if let Some((link, gen)) = scratch.groups[k].link.take() {
                let _ = self.pool.checkin(gen, link);
            }
        }
    }

    fn read_reply(link: &mut PeerLink, run: RunId, expect: TaskId) -> Result<Vec<u8>, String> {
        let bytes = link
            .frames_in
            .read(&mut link.stream)
            .map_err(|e| e.to_string())?;
        match decode_msg(bytes) {
            Ok(Msg::DataReply { run: r, task: t, data }) if r == run && t == expect => Ok(data),
            Ok(other) => Err(format!("unexpected data reply {:?}", other.op())),
            Err(e) => Err(e.to_string()),
        }
    }

    fn resolve_local_waits(
        &self,
        store: &ObjectStore,
        run: RunId,
        plan: &FetchPlan,
        scratch: &mut GatherScratch,
    ) -> Result<(), String> {
        for wi in 0..scratch.waits.len() {
            let i = scratch.waits[wi];
            let (task, _nbytes, _addr) = plan.input(i);
            let key = (run, task);
            let found =
                match store.wait_resident(&key, Duration::from_millis(self.cfg.local_wait_ms)) {
                    Lookup::Hit(d) => Some(d),
                    Lookup::Spilled => {
                        let restored = store.restore(&key);
                        store.maybe_spill();
                        restored
                    }
                    Lookup::Miss => None,
                };
            match found {
                Some(d) => scratch.slots[i] = Some(d),
                None => {
                    return Err(format!(
                        "{FETCH_FAILED_PREFIX}input {} for {} never arrived",
                        task,
                        plan.key()
                    ))
                }
            }
        }
        Ok(())
    }

    fn retry_failover(
        &self,
        store: &ObjectStore,
        run: RunId,
        consumer: TaskId,
        plan: &FetchPlan,
        scratch: &mut GatherScratch,
    ) -> Result<(), String> {
        for ri in 0..scratch.retries.len() {
            let i = scratch.retries[ri];
            if scratch.slots[i].is_some() {
                continue;
            }
            let (task, _nbytes, _addr) = plan.input(i);
            let data = self.fetch_with_failover(run, consumer, plan, i)?;
            let arc = Arc::new(data);
            store.insert((run, task), arc.clone(), 0);
            store.maybe_spill();
            scratch.slots[i] = Some(arc);
        }
        Ok(())
    }

    /// Fetch one input, walking the primary plus every known replica
    /// address before giving up with the recoverable `fetch-failed:`
    /// error. The starting replica rotates with the consuming task id.
    fn fetch_with_failover(
        &self,
        run: RunId,
        consumer: TaskId,
        plan: &FetchPlan,
        i: usize,
    ) -> Result<Vec<u8>, String> {
        let (task, _nbytes, primary) = plan.input(i);
        let n = 1 + plan.n_alts(i);
        let start = consumer.0 as usize % n;
        let mut last_err: Option<String> = None;
        for j in 0..n {
            let idx = (start + j) % n;
            let addr = if idx == 0 { primary } else { plan.input_alt(i, idx - 1) };
            if addr.is_empty() {
                continue;
            }
            match self.fetch_one(addr, run, task) {
                Ok(d) => return Ok(d),
                Err(e) => last_err = Some(e),
            }
        }
        let cause = last_err.unwrap_or_else(|| "no usable source address".to_string());
        Err(format!(
            "{FETCH_FAILED_PREFIX}{}/{} unreachable via {} source(s): {}",
            run, task, n, cause
        ))
    }

    /// Fetch one object from one peer. Pooled mode checks a link out of
    /// the pool (connecting if none is idle) and returns it on success;
    /// any failure evicts the address so the pool never resells a dead
    /// link.
    pub fn fetch_one(&self, addr: &str, run: RunId, task: TaskId) -> Result<Vec<u8>, String> {
        if !self.cfg.pooled {
            let mut link = PeerLink::connect(addr, &self.cfg).map_err(|e| e.to_string())?;
            return Self::fetch_on_link(&mut link, run, task);
        }
        let (mut link, gen) = self.acquire(addr).map_err(|e| e.to_string())?;
        match Self::fetch_on_link(&mut link, run, task) {
            Ok(d) => {
                let _ = self.pool.checkin(gen, link);
                Ok(d)
            }
            Err(e) => {
                self.pool.evict(addr);
                Err(e)
            }
        }
    }

    fn fetch_on_link(link: &mut PeerLink, run: RunId, task: TaskId) -> Result<Vec<u8>, String> {
        link.send_msg(&Msg::FetchData { run, task }).map_err(|e| e.to_string())?;
        Self::read_reply(link, run, task)
    }

    /// Push one stored object to a peer (`put-data`), streaming the
    /// payload zero-copy from its `Arc`. Best-effort like the rest of
    /// replication: the caller logs and skips unreachable targets.
    pub fn push(&self, addr: &str, run: RunId, task: TaskId, bytes: &Arc<Vec<u8>>) -> Result<(), String> {
        if !self.cfg.pooled {
            let mut link = PeerLink::connect(addr, &self.cfg).map_err(|e| e.to_string())?;
            return link
                .send_data_frame("put-data", run, task, bytes.as_slice())
                .map_err(|e| e.to_string());
        }
        let (mut link, gen) = self.acquire(addr).map_err(|e| e.to_string())?;
        match link.send_data_frame("put-data", run, task, bytes.as_slice()) {
            Ok(()) => {
                let _ = self.pool.checkin(gen, link);
                Ok(())
            }
            Err(e) => {
                self.pool.evict(addr);
                Err(e.to_string())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{encode_msg, ComputeTaskView, FrameWriter, TaskInputLoc};
    use crate::taskgraph::Payload;
    use crate::worker::queue::{PoppedTask, TaskQueue};
    use crate::worker::spill::MemSpill;
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;
    use std::time::Instant;

    fn store() -> ObjectStore {
        ObjectStore::new(None, Arc::new(MemSpill::new()))
    }

    fn key(run: u32, task: u32) -> DataKey {
        (RunId(run), TaskId(task))
    }

    fn reply_one(
        out: &mut FrameWriter,
        stream: &mut TcpStream,
        objects: &HashMap<DataKey, Vec<u8>>,
        run: RunId,
        task: TaskId,
    ) -> bool {
        match objects.get(&(run, task)) {
            Some(d) => out
                .send(stream, &Msg::DataReply { run, task, data: d.clone() })
                .is_ok(),
            None => false,
        }
    }

    fn serve_fake(mut stream: TcpStream, objects: HashMap<DataKey, Vec<u8>>) {
        let mut frames = FrameReader::new();
        let mut out = FrameWriter::new();
        loop {
            let msg = match frames.read(&mut stream) {
                Ok(bytes) => match decode_msg(bytes) {
                    Ok(m) => m,
                    Err(_) => return,
                },
                Err(_) => return,
            };
            match msg {
                Msg::FetchData { run, task } => {
                    if !reply_one(&mut out, &mut stream, &objects, run, task) {
                        return;
                    }
                }
                Msg::FetchDataMany { run, tasks } => {
                    for task in tasks {
                        if !reply_one(&mut out, &mut stream, &objects, run, task) {
                            return;
                        }
                    }
                }
                _ => return,
            }
        }
    }

    /// A minimal in-test data server: serves `fetch-data` and
    /// `fetch-data-many` from a fixed map, counts accepted connections.
    fn fake_peer(objects: HashMap<DataKey, Vec<u8>>) -> (String, Arc<AtomicUsize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let accepts = Arc::new(AtomicUsize::new(0));
        let counter = accepts.clone();
        thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { break };
                counter.fetch_add(1, Ordering::SeqCst);
                let objects = objects.clone();
                thread::spawn(move || serve_fake(stream, objects));
            }
        });
        (addr, accepts)
    }

    /// Build a real `FetchPlan` through the production enqueue/pop path.
    /// `inputs` = (input task id, primary addr, alt addrs); all run ids
    /// equal `run`, all sizes 4 bytes.
    fn pop_plan(
        run: u32,
        task: u32,
        inputs: Vec<(u32, &str, Vec<&str>)>,
    ) -> (PoppedTask, FetchPlan) {
        let bytes = encode_msg(&Msg::ComputeTask {
            run: RunId(run),
            task: TaskId(task),
            key: format!("k-{run}-{task}"),
            payload: Payload::BusyWait,
            duration_us: 1,
            output_size: 8,
            inputs: inputs
                .into_iter()
                .map(|(t, a, alts)| TaskInputLoc {
                    task: TaskId(t),
                    addr: a.into(),
                    alts: alts.into_iter().map(String::from).collect(),
                    nbytes: 4,
                })
                .collect(),
            priority: 0,
            consumers: 1,
            cores: 1,
        });
        let view = ComputeTaskView::decode(&bytes).unwrap();
        let mut q = TaskQueue::new();
        q.enqueue(&view).unwrap();
        let mut plan = FetchPlan::new();
        let t = q.pop_into(&mut plan).unwrap();
        (t, plan)
    }

    // ----- link pool (no sockets) -----

    fn static_addr(l: &&'static str) -> &str {
        l
    }

    #[test]
    fn pool_checkin_rejected_after_evict() {
        let pool: LinkPool<&'static str> = LinkPool::new(4, static_addr);
        let gen = pool.generation("p");
        assert!(pool.checkin(gen, "p"));
        assert_eq!(pool.idle_len(), 1);

        pool.evict("p");
        assert_eq!(pool.idle_len(), 0, "idle links to the address are dropped");
        assert!(pool.checkout("p").is_none());
        assert!(
            !pool.checkin(gen, "p"),
            "a generation snapshot taken before the eviction must be rejected"
        );
        assert_eq!(pool.generation("p"), gen + 1);
        // A link acquired after the eviction pools normally again.
        let fresh = pool.generation("p");
        assert!(pool.checkin(fresh, "p"));
        assert_eq!(pool.checkout("p").map(|(l, _)| l), Some("p"));
    }

    #[test]
    fn pool_closes_least_recently_used_idle_link_at_capacity() {
        let pool: LinkPool<&'static str> = LinkPool::new(2, static_addr);
        assert!(pool.checkin(0, "a"));
        assert!(pool.checkin(0, "b"));
        assert!(pool.checkin(0, "c"));
        assert_eq!(pool.idle_len(), 2);
        assert!(pool.checkout("a").is_none(), "oldest idle link was closed");
        assert!(pool.checkout("b").is_some());
        assert!(pool.checkout("c").is_some());
    }

    // ----- live-socket paths -----

    #[test]
    fn pooled_fetches_reuse_one_connection() {
        let mut objects = HashMap::new();
        for t in 0..5u32 {
            objects.insert(key(1, t), vec![t as u8; 16]);
        }
        let (addr, accepts) = fake_peer(objects);
        let dp = DataPlane::new(DataPlaneConfig::default());
        for t in 0..5u32 {
            let data = dp.fetch_one(&addr, RunId(1), TaskId(t)).unwrap();
            assert_eq!(data, vec![t as u8; 16]);
        }
        assert_eq!(accepts.load(Ordering::SeqCst), 1, "one pooled link served all fetches");
        assert_eq!(dp.pool.idle_len(), 1);
    }

    #[test]
    fn baseline_mode_connects_per_fetch() {
        let mut objects = HashMap::new();
        for t in 0..3u32 {
            objects.insert(key(1, t), vec![9u8; 4]);
        }
        let (addr, accepts) = fake_peer(objects);
        let dp = DataPlane::new(DataPlaneConfig { pooled: false, ..DataPlaneConfig::default() });
        for t in 0..3u32 {
            dp.fetch_one(&addr, RunId(1), TaskId(t)).unwrap();
        }
        assert_eq!(accepts.load(Ordering::SeqCst), 3);
        assert_eq!(dp.pool.idle_len(), 0);
    }

    #[test]
    fn gather_batches_per_peer_and_caches_passively() {
        let mut objects = HashMap::new();
        for t in 0..8u32 {
            objects.insert(key(3, t), vec![t as u8; 32]);
        }
        let (addr, accepts) = fake_peer(objects);
        // Small batches force several fetch-data-many requests through the
        // double-buffered window on one connection.
        let dp = DataPlane::new(DataPlaneConfig { max_batch: 2, ..DataPlaneConfig::default() });
        let inputs: Vec<(u32, &str, Vec<&str>)> =
            (0..8u32).map(|t| (t, addr.as_str(), vec![])).collect();
        let (t, plan) = pop_plan(3, 100, inputs);
        let st = store();
        let mut scratch = GatherScratch::new();
        dp.gather(&st, t.run, t.task, &plan, &mut scratch).unwrap();

        assert_eq!(scratch.inputs.len(), 8);
        for (i, got) in scratch.inputs.iter().enumerate() {
            assert_eq!(got.as_slice(), &vec![i as u8; 32][..], "plan order preserved");
        }
        assert_eq!(accepts.load(Ordering::SeqCst), 1, "all eight inputs over one link");
        assert!(scratch.dropped.is_empty());
        for t in 0..8u32 {
            match st.get(&key(3, t)) {
                Lookup::Hit(_) => {}
                _ => panic!("fetched input {t} not passively cached"),
            }
        }
    }

    #[test]
    fn hung_peer_trips_read_deadline_and_fails_over() {
        // Bound but never accepted: connects succeed via the kernel
        // backlog, reads hang forever — only the read deadline saves us.
        let hung = TcpListener::bind("127.0.0.1:0").unwrap();
        let hung_addr = hung.local_addr().unwrap().to_string();
        let mut objects = HashMap::new();
        objects.insert(key(2, 9), b"live".to_vec());
        let (live_addr, _) = fake_peer(objects);

        let dp = DataPlane::new(DataPlaneConfig {
            io_timeout_ms: 200,
            connect_timeout_ms: 500,
            ..DataPlaneConfig::default()
        });
        let t0 = Instant::now();
        let err = dp.fetch_one(&hung_addr, RunId(2), TaskId(9)).unwrap_err();
        let elapsed = t0.elapsed();
        assert!(!err.is_empty());
        assert!(
            elapsed >= Duration::from_millis(100) && elapsed < Duration::from_secs(3),
            "read deadline should fire at ~200ms, took {elapsed:?}"
        );

        // The same hung peer as an input's primary: gather downgrades the
        // batch to the failover walk and lands on the live replica.
        let (t, plan) = pop_plan(2, 40, vec![(9, hung_addr.as_str(), vec![live_addr.as_str()])]);
        let st = store();
        let mut scratch = GatherScratch::new();
        dp.gather(&st, t.run, t.task, &plan, &mut scratch).unwrap();
        assert_eq!(scratch.inputs.len(), 1);
        assert_eq!(scratch.inputs[0].as_slice(), b"live");
    }

    #[test]
    fn gather_fails_recoverably_when_every_source_is_dead() {
        // A closed port: connect is refused immediately.
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let dp = DataPlane::new(DataPlaneConfig {
            connect_timeout_ms: 300,
            io_timeout_ms: 300,
            ..DataPlaneConfig::default()
        });
        let (t, plan) = pop_plan(4, 7, vec![(1, dead_addr.as_str(), vec![])]);
        let st = store();
        let mut scratch = GatherScratch::new();
        let err = dp.gather(&st, t.run, t.task, &plan, &mut scratch).unwrap_err();
        assert!(
            err.starts_with(FETCH_FAILED_PREFIX),
            "error must be recoverable (fetch-failed:): {err}"
        );
    }

    #[test]
    fn gather_overlaps_local_producer_wait_with_remote_fetch() {
        let mut objects = HashMap::new();
        objects.insert(key(6, 2), b"remote".to_vec());
        let (addr, _) = fake_peer(objects);
        let st = Arc::new(store());

        // Input 1 has no source address: a local producer (the steal-race
        // case) inserts it shortly after the gather starts waiting.
        let producer = {
            let st = st.clone();
            thread::spawn(move || {
                thread::sleep(Duration::from_millis(50));
                assert!(st.insert(key(6, 1), Arc::new(b"local".to_vec()), 1));
            })
        };

        let dp = DataPlane::new(DataPlaneConfig::default());
        let (t, plan) = pop_plan(6, 11, vec![(1, "", vec![]), (2, addr.as_str(), vec![])]);
        let mut scratch = GatherScratch::new();
        dp.gather(&st, t.run, t.task, &plan, &mut scratch).unwrap();
        producer.join().unwrap();

        assert_eq!(scratch.inputs.len(), 2);
        assert_eq!(scratch.inputs[0].as_slice(), b"local");
        assert_eq!(scratch.inputs[1].as_slice(), b"remote");
        // The local input had one registered consumer: gathering it
        // consumed the last reference, so the caller owes a
        // replica-dropped for it.
        assert_eq!(scratch.dropped, vec![TaskId(1)]);
    }

    #[test]
    fn push_streams_put_data_byte_identically() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let reader = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut fr = FrameReader::new();
            let bytes = fr.read(&mut s).unwrap();
            match decode_msg(bytes).unwrap() {
                Msg::PutData { run, task, data } => (run, task, data),
                other => panic!("unexpected message {:?}", other.op()),
            }
        });

        let dp = DataPlane::new(DataPlaneConfig::default());
        let payload = Arc::new(vec![0xA7u8; 100_000]);
        dp.push(&addr, RunId(5), TaskId(6), &payload).unwrap();
        let (run, task, data) = reader.join().unwrap();
        assert_eq!(run, RunId(5));
        assert_eq!(task, TaskId(6));
        assert_eq!(data, *payload, "split-frame encoding decodes to the same payload");
    }
}
