//! Workers: the processes that execute tasks (paper §III-B), plus the
//! paper's *zero worker* (§IV-D) in [`zero`].
//!
//! A real worker:
//! - registers with the server (cores, node, data address),
//! - runs `ncores` executor threads pulling from a priority queue
//!   ("workers process their tasks in parallel, but they never execute more
//!   than one task per available core at once" — the paper's setting is
//!   one core per worker),
//! - fetches missing inputs directly from peer workers (worker↔worker data
//!   plane; the server is not on the data path),
//! - honours steal retraction: a queued task can be given back, a running
//!   one cannot (§IV-C),
//! - participates in lineage recovery: `cancel-compute` drops a queued
//!   task whose inputs evaporated with a dead peer, and a failed input
//!   fetch is reported with the recoverable `fetch-failed:` error prefix
//!   so the server re-runs the task instead of failing the run.
//!
//! The server is multi-graph: dense [`TaskId`]s recycle across runs, so the
//! queue, the steal-pending set and the data store are all keyed by
//! `(RunId, TaskId)` — two concurrent graphs can never alias each other's
//! outputs on a worker.
//!
//! Enqueue hot path (the worker half of the interned-key design): the
//! reader thread decodes `compute-task` through the borrowed
//! [`ComputeTaskView`] — never an owned [`Msg`] — and
//! [`queue::TaskQueue::enqueue`] interns the key and input addresses into
//! run-local arenas, so a warm `compute-task` → queue → execute cycle
//! performs zero heap allocations on the control path (asserted by the
//! `hotpath_micro` counting-allocator bench).

pub mod payload;
pub mod queue;
pub mod zero;

use crate::protocol::{
    decode_msg, peek_op, ComputeTaskView, FrameError, FrameReader, FrameWriter, Msg, RunId,
    TaskFinishedInfo, FETCH_FAILED_PREFIX,
};
use crate::taskgraph::TaskId;
use anyhow::{anyhow, bail, Context, Result};
use queue::{FetchPlan, PoppedTask, TaskQueue};
use std::collections::{HashMap, HashSet};
use std::net::{TcpListener, TcpStream};
// Model-checkable primitives: std in normal builds, the exhaustive
// explorer under `--cfg loom` (see `docs/verification.md`).
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Arc, Condvar, Mutex};

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub server_addr: String,
    pub name: String,
    pub ncores: u32,
    pub node: u32,
}

/// A task output's identity on this worker: which run, which task.
type DataKey = (RunId, TaskId);

/// The worker→server send half: stream plus its reused frame buffer, under
/// one lock so a warm send is one buffer fill and one syscall, no
/// allocation.
struct ServerLink {
    stream: TcpStream,
    frames: FrameWriter,
}

struct Shared {
    /// Priority queue + steal-pending set + run-local interned arenas,
    /// all behind one lock (they are always touched together).
    queue: Mutex<TaskQueue>,
    cv: Condvar,
    store: Mutex<HashMap<DataKey, Arc<Vec<u8>>>>,
    /// Runs the server has released. A task already mid-execution when its
    /// run's `ReleaseRun` arrives must not re-insert its output afterwards
    /// — no second release will ever come for that run. (RunIds are tiny
    /// and never reused, so this set costs 4 bytes per run served.)
    released: Mutex<HashSet<RunId>>,
    stop: AtomicBool,
    server_tx: Mutex<ServerLink>,
}

impl Shared {
    fn send(&self, msg: &Msg) -> Result<()> {
        let mut link = self.server_tx.lock().expect("server stream poisoned");
        let ServerLink { stream, frames } = &mut *link;
        frames.send(stream, msg)?;
        Ok(())
    }
}

/// Handle to a running worker (threads are detached; `shutdown` stops them).
pub struct WorkerHandle {
    pub id: u32,
    pub data_addr: String,
    shared: Arc<Shared>,
}

impl WorkerHandle {
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let link = self.shared.server_tx.lock().unwrap();
        let _ = link.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Start a real worker; returns after registration completes.
pub fn run_worker(cfg: WorkerConfig) -> Result<WorkerHandle> {
    // Data plane listener (peer fetches).
    let data_listener = TcpListener::bind("127.0.0.1:0").context("bind data listener")?;
    let data_addr = data_listener.local_addr()?.to_string();

    // Retrying connect: workers joining alongside a large client fleet can
    // hit transient backlog-overflow refusals (see `util::net`).
    let mut stream = crate::util::connect_with_retry(cfg.server_addr.as_str())
        .with_context(|| format!("connect {}", cfg.server_addr))?;
    stream.set_nodelay(true).ok();
    let mut register_frames = FrameWriter::new();
    register_frames.send(
        &mut stream,
        &Msg::RegisterWorker {
            name: cfg.name.clone(),
            ncores: cfg.ncores,
            node: cfg.node,
            data_addr: data_addr.clone(),
        },
    )?;
    let mut frames_in = FrameReader::new();
    let reply = decode_msg(frames_in.read(&mut stream)?)?;
    let Msg::Welcome { id } = reply else {
        bail!("expected welcome, got {:?}", reply.op());
    };

    let shared = Arc::new(Shared {
        queue: Mutex::new(TaskQueue::new()),
        cv: Condvar::new(),
        store: Mutex::new(HashMap::new()),
        released: Mutex::new(HashSet::new()),
        stop: AtomicBool::new(false),
        server_tx: Mutex::new(ServerLink {
            stream: stream.try_clone().context("clone server stream")?,
            frames: register_frames,
        }),
    });

    // Data server: serve peer fetch requests.
    {
        let shared = shared.clone();
        std::thread::spawn(move || {
            for conn in data_listener.incoming() {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(conn) = conn else { continue };
                let shared = shared.clone();
                std::thread::spawn(move || serve_data_conn(conn, &shared));
            }
        });
    }

    // Executor threads.
    for core in 0..cfg.ncores.max(1) {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name(format!("{}-exec{}", cfg.name, core))
            .spawn(move || executor_loop(&shared))
            .expect("spawn executor");
    }

    // Server reader (reuses one frame buffer for every inbound message).
    {
        let shared = shared.clone();
        std::thread::spawn(move || {
            let mut stream = stream;
            let mut frames_in = frames_in;
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let bytes = match frames_in.read(&mut stream) {
                    Ok(bytes) => bytes,
                    Err(FrameError::Closed) => break,
                    Err(e) => {
                        log::warn!("worker: server stream error: {e}");
                        break;
                    }
                };
                // Hot branch: compute-task decodes through the borrowed
                // view and interns straight into the run-local arenas —
                // no owned Msg (key String, input Vec, addr Strings) is
                // ever built on the enqueue path.
                if matches!(peek_op(bytes), Ok("compute-task")) {
                    let view = match ComputeTaskView::decode(bytes) {
                        Ok(v) => v,
                        Err(e) => {
                            log::warn!("worker: bad compute-task from server: {e}");
                            break;
                        }
                    };
                    // A compute for an already-released run would recreate
                    // the run's arenas for nothing; the server's FIFO makes
                    // this effectively unreachable, but stay defensive.
                    if !shared.released.lock().unwrap().contains(&view.run) {
                        let enqueued = shared.queue.lock().unwrap().enqueue(&view);
                        match enqueued {
                            Ok(()) => shared.cv.notify_one(),
                            Err(e) => {
                                log::warn!("worker: bad compute-task inputs: {e}");
                                break;
                            }
                        }
                    }
                    continue;
                }
                let msg = match decode_msg(bytes) {
                    Ok(m) => m,
                    Err(e) => {
                        log::warn!("worker: bad message from server: {e}");
                        break;
                    }
                };
                match msg {
                    Msg::StealRequest { run, task } => {
                        // Retract iff still queued (not started) — §IV-C.
                        let retracted = drop_queued(&shared, run, task);
                        let _ = shared.send(&Msg::StealResponse { run, task, ok: retracted });
                    }
                    Msg::CancelCompute { run, task } => {
                        // Recovery: an input of this task evaporated with a
                        // dead worker. Drop the queued copy — the server
                        // re-sends the task with fresh input locations once
                        // its inputs exist again. No response: unlike a
                        // steal there is nothing to negotiate, and a copy
                        // already running is handled by the server (its
                        // result is accepted or its fetch error retried).
                        drop_queued(&shared, run, task);
                    }
                    Msg::FetchFromServer { run, task } => {
                        let data = shared
                            .store
                            .lock()
                            .unwrap()
                            .get(&(run, task))
                            .map(|d| d.as_ref().clone())
                            .unwrap_or_default();
                        let _ = shared.send(&Msg::DataToServer { run, task, data });
                    }
                    Msg::ReleaseRun { run } => {
                        // Run retired: reclaim its queue entries, interned
                        // arenas and stored outputs so a long-lived worker
                        // stays bounded. The `released` mark lands first so
                        // an execution racing the purge cannot re-insert.
                        shared.released.lock().unwrap().insert(run);
                        shared.queue.lock().unwrap().release_run(run);
                        shared.store.lock().unwrap().retain(|&(r, _), _| r != run);
                    }
                    Msg::Shutdown => {
                        shared.stop.store(true, Ordering::SeqCst);
                        shared.cv.notify_all();
                        break;
                    }
                    Msg::Heartbeat | Msg::Welcome { .. } => {}
                    other => log::warn!("worker: unexpected {:?}", other.op()),
                }
            }
            shared.stop.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
        });
    }

    Ok(WorkerHandle { id, data_addr, shared })
}

/// Retract a task if still queued; returns whether a queued copy was
/// dropped (shared by steal retraction and `cancel-compute`).
fn drop_queued(shared: &Shared, run: RunId, task: TaskId) -> bool {
    shared.queue.lock().unwrap().drop_queued(run, task)
}

fn executor_loop(shared: &Shared) {
    // Reused scratch: each pop copies the task's key and input addresses
    // into these retained buffers under the queue lock, so nothing borrows
    // the run-local arenas outside it (warm pops allocate nothing).
    let mut plan = FetchPlan::new();
    loop {
        let next = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // pop_into also clears the pending mark — running tasks
                // are no longer stealable.
                if let Some(t) = q.pop_into(&mut plan) {
                    break t;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // Popped after its run was released (queue purge raced the pop):
        // drop it instead of doing dead work.
        if shared.released.lock().unwrap().contains(&next.run) {
            continue;
        }
        match run_task(shared, &next, &plan) {
            Ok(info) => {
                let _ = shared.send(&Msg::TaskFinished(info));
            }
            Err(e) => {
                let _ = shared.send(&Msg::TaskErred {
                    run: next.run,
                    task: next.task,
                    error: e.to_string(),
                });
            }
        }
    }
}

fn run_task(shared: &Shared, t: &PoppedTask, plan: &FetchPlan) -> Result<TaskFinishedInfo> {
    // Gather inputs: local store or remote peer. Input locations are
    // relative to the task's own run.
    let mut inputs: Vec<Arc<Vec<u8>>> = Vec::with_capacity(plan.n_inputs());
    for i in 0..plan.n_inputs() {
        let (input_task, _nbytes, addr) = plan.input(i);
        let key = (t.run, input_task);
        let local = shared.store.lock().unwrap().get(&key).cloned();
        let data = match local {
            Some(d) => d,
            None if !addr.is_empty() => {
                // The `fetch-failed:` prefix marks this error recoverable:
                // the peer died (or its address went stale mid-recovery),
                // so the server re-runs this task rather than failing the
                // whole run.
                let data = fetch_remote(addr, t.run, input_task).with_context(|| {
                    format!("{FETCH_FAILED_PREFIX}{}/{} from {}", t.run, input_task, addr)
                })?;
                let arc = Arc::new(data);
                {
                    // Check `released` while holding the store lock: the
                    // release handler marks the run released *before*
                    // purging, so either we see the mark and skip, or our
                    // insert lands before the purge and is swept by it.
                    let mut store = shared.store.lock().unwrap();
                    if !shared.released.lock().unwrap().contains(&t.run) {
                        store.insert(key, arc.clone());
                    }
                }
                arc
            }
            None => {
                // Local producer raced with us (steal); short bounded wait.
                let mut got = None;
                for _ in 0..500 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    if let Some(d) = shared.store.lock().unwrap().get(&key).cloned() {
                        got = Some(d);
                        break;
                    }
                }
                got.ok_or_else(|| {
                    anyhow!(
                        "{FETCH_FAILED_PREFIX}input {} for {} never arrived",
                        input_task,
                        plan.key()
                    )
                })?
            }
        };
        inputs.push(data);
    }
    let t0 = std::time::Instant::now();
    let output = payload::execute(&t.payload, t.duration_us, t.output_size, &inputs)?;
    let duration_us = t0.elapsed().as_micros() as u64;
    let nbytes = output.len() as u64;
    // A release that raced this execution already purged the store; don't
    // repopulate it — the server drops our TaskFinished anyway. The check
    // holds the store lock so a release can't slip between check and
    // insert (the handler marks `released` before it purges).
    {
        let mut store = shared.store.lock().unwrap();
        if !shared.released.lock().unwrap().contains(&t.run) {
            store.insert((t.run, t.task), Arc::new(output));
        }
    }
    Ok(TaskFinishedInfo { run: t.run, task: t.task, nbytes, duration_us })
}

fn fetch_remote(addr: &str, run: RunId, task: TaskId) -> Result<Vec<u8>> {
    let mut s = TcpStream::connect(addr)?;
    s.set_nodelay(true).ok();
    FrameWriter::new().send(&mut s, &Msg::FetchData { run, task })?;
    let mut frames_in = FrameReader::new();
    let reply = decode_msg(frames_in.read(&mut s)?)?;
    match reply {
        Msg::DataReply { run: r, task: t, data } if r == run && t == task => Ok(data),
        other => bail!("unexpected data reply {:?}", other.op()),
    }
}

fn serve_data_conn(mut conn: TcpStream, shared: &Shared) {
    conn.set_nodelay(true).ok();
    // Per-connection reused buffers: repeated fetches on one peer link
    // allocate nothing beyond the payload clones themselves.
    let mut frames_in = FrameReader::new();
    let mut frames_out = FrameWriter::new();
    loop {
        let msg = match frames_in.read(&mut conn) {
            Ok(bytes) => match decode_msg(bytes) {
                Ok(m) => m,
                Err(_) => break,
            },
            Err(_) => break,
        };
        match msg {
            Msg::FetchData { run, task } => {
                // The producer finished before the server advertised the
                // location, but the local insert may trail by a hair.
                let mut data = None;
                for _ in 0..500 {
                    if let Some(d) = shared.store.lock().unwrap().get(&(run, task)).cloned() {
                        data = Some(d);
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                let Some(data) = data else { break };
                let reply = Msg::DataReply { run, task, data: data.as_ref().clone() };
                if frames_out.send(&mut conn, &reply).is_err() {
                    break;
                }
            }
            _ => break,
        }
    }
}
