//! Workers: the processes that execute tasks (paper §III-B), plus the
//! paper's *zero worker* (§IV-D) in [`zero`].
//!
//! A real worker:
//! - registers with the server (cores, node, data address),
//! - runs `ncores` executor threads pulling from a priority queue
//!   ("workers process their tasks in parallel, but they never execute more
//!   than one task per available core at once" — the paper's setting is
//!   one core per worker),
//! - fetches missing inputs directly from peer workers over the pooled
//!   data plane ([`dataplane`]; the server is not on the data path): one
//!   persistent connection per peer, a task's missing inputs coalesced
//!   into `fetch-data-many` batches issued to every source peer before
//!   any reply is drained, failing over across the input's replica
//!   addresses before reporting `fetch-failed:`,
//! - keeps outputs in the reference-counted [`store::ObjectStore`] —
//!   fully-consumed outputs self-evict (the server is told via
//!   `replica-dropped`), and an optional `--memory-limit` budget spills
//!   least-recently-used entries to disk ([`spill::FsSpill`]) so graphs
//!   larger than cluster RAM complete,
//! - serves peer fetches and replica pushes from one poll-driven thread
//!   ([`serve`]): replies stream zero-copy from the store's `Arc`s, and a
//!   fetch arriving before its producer's local insert parks on the store's
//!   insert hook instead of sleep-polling,
//! - serves the replication data plane: a `replicate-data` order from the
//!   server pushes copies of a hot output to peer workers (`put-data`,
//!   streamed zero-copy over the same pooled links), and each receiving
//!   peer confirms with `replica-added`,
//! - honours steal retraction: a queued task can be given back, a running
//!   one cannot (§IV-C),
//! - participates in lineage recovery: `cancel-compute` drops a queued
//!   task whose inputs evaporated with a dead peer, and a failed input
//!   fetch is reported with the recoverable `fetch-failed:` error prefix
//!   so the server re-runs the task instead of failing the run.
//!
//! The server is multi-graph: dense [`TaskId`]s recycle across runs, so the
//! queue, the steal-pending set and the data store are all keyed by
//! `(RunId, TaskId)` — two concurrent graphs can never alias each other's
//! outputs on a worker.
//!
//! Enqueue hot path (the worker half of the interned-key design): the
//! reader thread decodes `compute-task` through the borrowed
//! [`ComputeTaskView`] — never an owned [`Msg`] — and
//! [`queue::TaskQueue::enqueue`] interns the key and input addresses into
//! run-local arenas, so a warm `compute-task` → queue → execute cycle
//! performs zero heap allocations on the control path (asserted by the
//! `hotpath_micro` counting-allocator bench).

pub mod dataplane;
pub mod payload;
pub mod queue;
mod serve;
pub mod spill;
pub mod store;
pub mod zero;

use crate::protocol::{
    decode_msg, peek_op, ComputeTaskView, FrameError, FrameReader, FrameWriter, Msg, RunId,
    TaskFinishedInfo,
};
use crate::server::poll::Waker;
use crate::taskgraph::TaskId;
use anyhow::{anyhow, bail, Context, Result};
use queue::{FetchPlan, PoppedTask, TaskQueue};
use spill::{FsSpill, MemSpill, SpillBackend};
use std::net::{TcpListener, TcpStream};
use store::{DataKey, ObjectStore};
// Model-checkable primitives: std in normal builds, the exhaustive
// explorer under `--cfg loom` (see `docs/verification.md`).
use crate::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use crate::sync::{Arc, Condvar, Mutex};

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub server_addr: String,
    pub name: String,
    pub ncores: u32,
    pub node: u32,
    /// Resident-byte budget for the object store (`--memory-limit`);
    /// `None` keeps everything in memory (no spill tier).
    pub memory_limit: Option<u64>,
    /// Worker↔worker data-plane tunables (link pooling, batch sizes,
    /// connect/IO deadlines). Benches flip `pooled` off to measure the
    /// connect-per-fetch baseline.
    pub data_plane: dataplane::DataPlaneConfig,
}

/// The worker→server send half: stream plus its reused frame buffer, under
/// one lock so a warm send is one buffer fill and one syscall, no
/// allocation.
struct ServerLink {
    stream: TcpStream,
    frames: FrameWriter,
}

struct Shared {
    /// Priority queue + steal-pending set + run-local interned arenas,
    /// all behind one lock (they are always touched together).
    queue: Mutex<TaskQueue>,
    cv: Condvar,
    /// Task outputs: reference-counted, LRU-spilled, release-aware (the
    /// released-run mark lives inside the store's lock, so an execution
    /// racing a `release-run` can never re-insert after the purge).
    store: ObjectStore,
    stop: AtomicBool,
    /// Executor threads currently inside a task (fault-injection tests use
    /// this to find an *idle* worker — one whose death should be a trivial
    /// who-has purge when its outputs are replicated).
    running: AtomicU32,
    server_tx: Mutex<ServerLink>,
    /// Client half of the worker↔worker data plane: pooled peer links,
    /// batched gather, zero-copy push.
    dataplane: dataplane::DataPlane,
    /// Wakes the poll-driven data server ([`serve`]): store inserts poke it
    /// so parked fetches are served event-driven, and shutdown pokes it so
    /// the serve loop observes the stop flag.
    data_waker: Arc<Waker>,
}

impl Shared {
    fn send(&self, msg: &Msg) -> Result<()> {
        let mut link = self.server_tx.lock().expect("server stream poisoned");
        let ServerLink { stream, frames } = &mut *link;
        frames.send(stream, msg)?;
        Ok(())
    }
}

/// Handle to a running worker (threads are detached; `shutdown` stops them).
pub struct WorkerHandle {
    pub id: u32,
    pub data_addr: String,
    shared: Arc<Shared>,
}

impl WorkerHandle {
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        self.shared.data_waker.wake();
        let link = self.shared.server_tx.lock().unwrap();
        let _ = link.stream.shutdown(std::net::Shutdown::Both);
    }

    /// (spill events, restore events) of this worker's store — lets tests
    /// and benches assert a budgeted run actually exercised the spill tier.
    pub fn spill_stats(&self) -> (u64, u64) {
        self.shared.store.spill_stats()
    }

    /// Whether any executor thread is currently inside a task.
    pub fn busy(&self) -> bool {
        self.shared.running.load(Ordering::SeqCst) > 0
    }
}

/// Start a real worker; returns after registration completes.
pub fn run_worker(cfg: WorkerConfig) -> Result<WorkerHandle> {
    // Data plane listener (peer fetches).
    let data_listener = TcpListener::bind("127.0.0.1:0").context("bind data listener")?;
    let data_addr = data_listener.local_addr()?.to_string();

    // Retrying connect: workers joining alongside a large client fleet can
    // hit transient backlog-overflow refusals (see `util::net`).
    let mut stream = crate::util::connect_with_retry(cfg.server_addr.as_str())
        .with_context(|| format!("connect {}", cfg.server_addr))?;
    stream.set_nodelay(true).ok();
    let mut register_frames = FrameWriter::new();
    register_frames.send(
        &mut stream,
        &Msg::RegisterWorker {
            name: cfg.name.clone(),
            ncores: cfg.ncores,
            node: cfg.node,
            data_addr: data_addr.clone(),
        },
    )?;
    let mut frames_in = FrameReader::new();
    let reply = decode_msg(frames_in.read(&mut stream)?)?;
    let Msg::Welcome { id } = reply else {
        bail!("expected welcome, got {:?}", reply.op());
    };

    // The spill tier only exists under a budget; without one the backend
    // is never written, so a cheap in-memory stub avoids creating a spill
    // directory per worker.
    let backend: Arc<dyn SpillBackend> = match cfg.memory_limit {
        Some(_) => Arc::new(FsSpill::new().context("create spill dir")?),
        None => Arc::new(MemSpill::new()),
    };

    let data_waker = Arc::new(Waker::new().context("create data-plane waker")?);
    let shared = Arc::new(Shared {
        queue: Mutex::new(TaskQueue::with_cores(cfg.ncores.max(1))),
        cv: Condvar::new(),
        store: ObjectStore::new(cfg.memory_limit, backend),
        stop: AtomicBool::new(false),
        running: AtomicU32::new(0),
        server_tx: Mutex::new(ServerLink {
            stream: stream.try_clone().context("clone server stream")?,
            frames: register_frames,
        }),
        dataplane: dataplane::DataPlane::new(cfg.data_plane.clone()),
        data_waker: data_waker.clone(),
    });

    // Every store insert pokes the data server's waker, so a peer fetch
    // parked on a not-yet-resident key is served the moment the producer's
    // insert lands (event-driven; no sleep-polling). Capturing only the
    // waker keeps the hook free of an Arc cycle through Shared.
    shared.store.set_insert_hook(Box::new(move || data_waker.wake()));

    // Data server: one poll-driven thread serves every peer link.
    {
        let shared = shared.clone();
        std::thread::spawn(move || serve::run_data_server(data_listener, shared));
    }

    // Executor threads.
    for core in 0..cfg.ncores.max(1) {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name(format!("{}-exec{}", cfg.name, core))
            .spawn(move || executor_loop(&shared))
            .expect("spawn executor");
    }

    // Server reader (reuses one frame buffer for every inbound message).
    {
        let shared = shared.clone();
        std::thread::spawn(move || {
            let mut stream = stream;
            let mut frames_in = frames_in;
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let bytes = match frames_in.read(&mut stream) {
                    Ok(bytes) => bytes,
                    Err(FrameError::Closed) => break,
                    Err(e) => {
                        log::warn!("worker: server stream error: {e}");
                        break;
                    }
                };
                // Hot branch: compute-task decodes through the borrowed
                // view and interns straight into the run-local arenas —
                // no owned Msg (key String, input Vec, addr Strings) is
                // ever built on the enqueue path.
                if matches!(peek_op(bytes), Ok("compute-task")) {
                    let view = match ComputeTaskView::decode(bytes) {
                        Ok(v) => v,
                        Err(e) => {
                            log::warn!("worker: bad compute-task from server: {e}");
                            break;
                        }
                    };
                    // A compute for an already-released run would recreate
                    // the run's arenas for nothing; the server's FIFO makes
                    // this effectively unreachable, but stay defensive.
                    if !shared.store.is_released(view.run) {
                        let enqueued = shared.queue.lock().unwrap().enqueue(&view);
                        match enqueued {
                            Ok(()) => shared.cv.notify_one(),
                            Err(e) => {
                                log::warn!("worker: bad compute-task inputs: {e}");
                                break;
                            }
                        }
                    }
                    continue;
                }
                let msg = match decode_msg(bytes) {
                    Ok(m) => m,
                    Err(e) => {
                        log::warn!("worker: bad message from server: {e}");
                        break;
                    }
                };
                match msg {
                    Msg::StealRequest { run, task } => {
                        // Retract iff still queued (not started) — §IV-C.
                        let retracted = drop_queued(&shared, run, task);
                        let _ = shared.send(&Msg::StealResponse { run, task, ok: retracted });
                    }
                    Msg::PinData { run, task, consumers } => {
                        // A graph extension added consumers of this stored
                        // output: raise its remaining reference count so it
                        // survives for the new gathers. A key we no longer
                        // hold is ignored — the server pins what it believes
                        // resident, and the resurrection path backstops a
                        // copy that evaporated in flight.
                        shared.store.add_consumers(&(run, task), consumers);
                    }
                    Msg::CancelCompute { run, task } => {
                        // Recovery: an input of this task evaporated with a
                        // dead worker. Drop the queued copy — the server
                        // re-sends the task with fresh input locations once
                        // its inputs exist again. No response: unlike a
                        // steal there is nothing to negotiate, and a copy
                        // already running is handled by the server (its
                        // result is accepted or its fetch error retried).
                        drop_queued(&shared, run, task);
                    }
                    Msg::ReplicateData { run, task, addrs } => {
                        // Replication order for one of our outputs. Pushing
                        // is blocking I/O to k−1 peers — keep it off the
                        // reader thread so control traffic keeps flowing.
                        let shared = shared.clone();
                        std::thread::spawn(move || push_replicas(&shared, run, task, &addrs));
                    }
                    Msg::FetchFromServer { run, task } => {
                        let data = lookup(&shared, &(run, task))
                            .map(|d| d.as_ref().clone())
                            .unwrap_or_default();
                        let _ = shared.send(&Msg::DataToServer { run, task, data });
                    }
                    Msg::ReleaseRun { run } => {
                        // Run retired: reclaim its queue entries, interned
                        // arenas and stored outputs (including spill slots)
                        // so a long-lived worker stays bounded. The store's
                        // internal released-mark lands atomically with its
                        // purge, so a racing execution cannot re-insert.
                        shared.store.release_run(run);
                        shared.queue.lock().unwrap().release_run(run);
                    }
                    Msg::Shutdown => {
                        shared.stop.store(true, Ordering::SeqCst);
                        shared.cv.notify_all();
                        break;
                    }
                    Msg::Heartbeat | Msg::Welcome { .. } => {}
                    other => log::warn!("worker: unexpected {:?}", other.op()),
                }
            }
            shared.stop.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            shared.data_waker.wake();
        });
    }

    Ok(WorkerHandle { id, data_addr, shared })
}

/// Retract a task if still queued; returns whether a queued copy was
/// dropped (shared by steal retraction and `cancel-compute`).
fn drop_queued(shared: &Shared, run: RunId, task: TaskId) -> bool {
    shared.queue.lock().unwrap().drop_queued(run, task)
}

/// Store lookup that transparently restores a spilled entry (and rebalances
/// the budget afterwards). `None` = genuinely absent.
fn lookup(shared: &Shared, key: &DataKey) -> Option<Arc<Vec<u8>>> {
    dataplane::lookup_restoring(&shared.store, key)
}

fn executor_loop(shared: &Shared) {
    // Reused scratch: each pop copies the task's key and input addresses
    // into these retained buffers under the queue lock, so nothing borrows
    // the run-local arenas outside it (warm pops allocate nothing). The
    // gather scratch likewise retains its slot/group buffers across tasks.
    let mut plan = FetchPlan::new();
    let mut scratch = dataplane::GatherScratch::new();
    loop {
        let next = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // pop_into also clears the pending mark — running tasks
                // are no longer stealable.
                if let Some(t) = q.pop_into(&mut plan) {
                    break t;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // Popped after its run was released (queue purge raced the pop):
        // drop it instead of doing dead work — returning its core slots,
        // or a wide task's ghost would gate the queue forever.
        if shared.store.is_released(next.run) {
            shared.queue.lock().unwrap().task_done(next.cores);
            shared.cv.notify_all();
            continue;
        }
        shared.running.fetch_add(1, Ordering::SeqCst);
        let outcome = run_task(shared, &next, &plan, &mut scratch);
        shared.running.fetch_sub(1, Ordering::SeqCst);
        match outcome {
            Ok(info) => {
                let _ = shared.send(&Msg::TaskFinished(info));
            }
            Err(e) => {
                let _ = shared.send(&Msg::TaskErred {
                    run: next.run,
                    task: next.task,
                    error: e.to_string(),
                });
            }
        }
        // Slots free only after the outcome is decided: the gate models
        // occupancy for the task's whole stay on the machine.
        shared.queue.lock().unwrap().task_done(next.cores);
        shared.cv.notify_all();
    }
}

fn run_task(
    shared: &Shared,
    t: &PoppedTask,
    plan: &FetchPlan,
    scratch: &mut dataplane::GatherScratch,
) -> Result<TaskFinishedInfo> {
    // Gather inputs — local store, local-producer wait, or batched fetch
    // over the pooled peer links (see `dataplane`). Input locations are
    // relative to the task's own run. The gather records one consumption
    // per input, exactly once per (run, consumer, input): a re-delivered
    // assignment (recovery re-send, steal re-assignment) gathers again but
    // never double-decrements, or it would prematurely evict an output a
    // sibling consumer still needs.
    shared
        .dataplane
        .gather(&shared.store, t.run, t.task, plan, scratch)
        .map_err(|e| anyhow!(e))?;
    // A refcounted local copy that hit zero during the gather self-evicted;
    // tell the server so recovery and future `who_has` answers never count
    // on the freed bytes.
    for task in scratch.dropped.drain(..) {
        let _ = shared.send(&Msg::ReplicaDropped { run: t.run, task });
    }
    let t0 = std::time::Instant::now();
    let output = payload::execute(&t.payload, t.duration_us, t.output_size, &scratch.inputs)?;
    let duration_us = t0.elapsed().as_micros() as u64;
    let nbytes = output.len() as u64;
    // The store refuses the insert if a release raced this execution (the
    // server drops our TaskFinished anyway). The wire consumer count seeds
    // the reference count: 0 pins (sink outputs survive for the client).
    shared.store.insert((t.run, t.task), Arc::new(output), t.consumers);
    shared.store.maybe_spill();
    Ok(TaskFinishedInfo { run: t.run, task: t.task, nbytes, duration_us })
}

/// Execute a `replicate-data` order: push our copy of `(run, task)` to each
/// peer data address, streamed zero-copy from the store's `Arc` over the
/// pooled links. Best-effort — a dead or unreachable target is simply
/// skipped, because the server only counts copies whose `replica-added`
/// confirmation arrives from the receiving peer.
fn push_replicas(shared: &Shared, run: RunId, task: TaskId, addrs: &[String]) {
    let Some(bytes) = lookup(shared, &(run, task)) else {
        // Already consumed away or the run was released: nothing to push.
        return;
    };
    for addr in addrs {
        if let Err(e) = shared.dataplane.push(addr, run, task, &bytes) {
            log::debug!("worker: replica push {run}/{task} to {addr} failed: {e}");
        }
    }
}
