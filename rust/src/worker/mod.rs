//! Workers: the processes that execute tasks (paper §III-B), plus the
//! paper's *zero worker* (§IV-D) in [`zero`].
//!
//! A real worker:
//! - registers with the server (cores, node, data address),
//! - runs `ncores` executor threads pulling from a priority queue
//!   ("workers process their tasks in parallel, but they never execute more
//!   than one task per available core at once" — the paper's setting is
//!   one core per worker),
//! - fetches missing inputs directly from peer workers (worker↔worker data
//!   plane; the server is not on the data path), failing over across the
//!   input's replica addresses before reporting `fetch-failed:`,
//! - keeps outputs in the reference-counted [`store::ObjectStore`] —
//!   fully-consumed outputs self-evict (the server is told via
//!   `replica-dropped`), and an optional `--memory-limit` budget spills
//!   least-recently-used entries to disk ([`spill::FsSpill`]) so graphs
//!   larger than cluster RAM complete,
//! - serves the replication data plane: a `replicate-data` order from the
//!   server pushes copies of a hot output to peer workers (`put-data`),
//!   and each receiving peer confirms with `replica-added`,
//! - honours steal retraction: a queued task can be given back, a running
//!   one cannot (§IV-C),
//! - participates in lineage recovery: `cancel-compute` drops a queued
//!   task whose inputs evaporated with a dead peer, and a failed input
//!   fetch is reported with the recoverable `fetch-failed:` error prefix
//!   so the server re-runs the task instead of failing the run.
//!
//! The server is multi-graph: dense [`TaskId`]s recycle across runs, so the
//! queue, the steal-pending set and the data store are all keyed by
//! `(RunId, TaskId)` — two concurrent graphs can never alias each other's
//! outputs on a worker.
//!
//! Enqueue hot path (the worker half of the interned-key design): the
//! reader thread decodes `compute-task` through the borrowed
//! [`ComputeTaskView`] — never an owned [`Msg`] — and
//! [`queue::TaskQueue::enqueue`] interns the key and input addresses into
//! run-local arenas, so a warm `compute-task` → queue → execute cycle
//! performs zero heap allocations on the control path (asserted by the
//! `hotpath_micro` counting-allocator bench).

pub mod payload;
pub mod queue;
pub mod spill;
pub mod store;
pub mod zero;

use crate::protocol::{
    decode_msg, peek_op, ComputeTaskView, FrameError, FrameReader, FrameWriter, Msg, RunId,
    TaskFinishedInfo, FETCH_FAILED_PREFIX,
};
use crate::taskgraph::TaskId;
use anyhow::{anyhow, bail, Context, Result};
use queue::{FetchPlan, PoppedTask, TaskQueue};
use spill::{FsSpill, MemSpill, SpillBackend};
use std::net::{TcpListener, TcpStream};
use store::{DataKey, Lookup, ObjectStore};
// Model-checkable primitives: std in normal builds, the exhaustive
// explorer under `--cfg loom` (see `docs/verification.md`).
use crate::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use crate::sync::{Arc, Condvar, Mutex};

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub server_addr: String,
    pub name: String,
    pub ncores: u32,
    pub node: u32,
    /// Resident-byte budget for the object store (`--memory-limit`);
    /// `None` keeps everything in memory (no spill tier).
    pub memory_limit: Option<u64>,
}

/// The worker→server send half: stream plus its reused frame buffer, under
/// one lock so a warm send is one buffer fill and one syscall, no
/// allocation.
struct ServerLink {
    stream: TcpStream,
    frames: FrameWriter,
}

struct Shared {
    /// Priority queue + steal-pending set + run-local interned arenas,
    /// all behind one lock (they are always touched together).
    queue: Mutex<TaskQueue>,
    cv: Condvar,
    /// Task outputs: reference-counted, LRU-spilled, release-aware (the
    /// released-run mark lives inside the store's lock, so an execution
    /// racing a `release-run` can never re-insert after the purge).
    store: ObjectStore,
    stop: AtomicBool,
    /// Executor threads currently inside a task (fault-injection tests use
    /// this to find an *idle* worker — one whose death should be a trivial
    /// who-has purge when its outputs are replicated).
    running: AtomicU32,
    server_tx: Mutex<ServerLink>,
}

impl Shared {
    fn send(&self, msg: &Msg) -> Result<()> {
        let mut link = self.server_tx.lock().expect("server stream poisoned");
        let ServerLink { stream, frames } = &mut *link;
        frames.send(stream, msg)?;
        Ok(())
    }
}

/// Handle to a running worker (threads are detached; `shutdown` stops them).
pub struct WorkerHandle {
    pub id: u32,
    pub data_addr: String,
    shared: Arc<Shared>,
}

impl WorkerHandle {
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let link = self.shared.server_tx.lock().unwrap();
        let _ = link.stream.shutdown(std::net::Shutdown::Both);
    }

    /// (spill events, restore events) of this worker's store — lets tests
    /// and benches assert a budgeted run actually exercised the spill tier.
    pub fn spill_stats(&self) -> (u64, u64) {
        self.shared.store.spill_stats()
    }

    /// Whether any executor thread is currently inside a task.
    pub fn busy(&self) -> bool {
        self.shared.running.load(Ordering::SeqCst) > 0
    }
}

/// Start a real worker; returns after registration completes.
pub fn run_worker(cfg: WorkerConfig) -> Result<WorkerHandle> {
    // Data plane listener (peer fetches).
    let data_listener = TcpListener::bind("127.0.0.1:0").context("bind data listener")?;
    let data_addr = data_listener.local_addr()?.to_string();

    // Retrying connect: workers joining alongside a large client fleet can
    // hit transient backlog-overflow refusals (see `util::net`).
    let mut stream = crate::util::connect_with_retry(cfg.server_addr.as_str())
        .with_context(|| format!("connect {}", cfg.server_addr))?;
    stream.set_nodelay(true).ok();
    let mut register_frames = FrameWriter::new();
    register_frames.send(
        &mut stream,
        &Msg::RegisterWorker {
            name: cfg.name.clone(),
            ncores: cfg.ncores,
            node: cfg.node,
            data_addr: data_addr.clone(),
        },
    )?;
    let mut frames_in = FrameReader::new();
    let reply = decode_msg(frames_in.read(&mut stream)?)?;
    let Msg::Welcome { id } = reply else {
        bail!("expected welcome, got {:?}", reply.op());
    };

    // The spill tier only exists under a budget; without one the backend
    // is never written, so a cheap in-memory stub avoids creating a spill
    // directory per worker.
    let backend: Arc<dyn SpillBackend> = match cfg.memory_limit {
        Some(_) => Arc::new(FsSpill::new().context("create spill dir")?),
        None => Arc::new(MemSpill::new()),
    };

    let shared = Arc::new(Shared {
        queue: Mutex::new(TaskQueue::with_cores(cfg.ncores.max(1))),
        cv: Condvar::new(),
        store: ObjectStore::new(cfg.memory_limit, backend),
        stop: AtomicBool::new(false),
        running: AtomicU32::new(0),
        server_tx: Mutex::new(ServerLink {
            stream: stream.try_clone().context("clone server stream")?,
            frames: register_frames,
        }),
    });

    // Data server: serve peer fetch requests.
    {
        let shared = shared.clone();
        std::thread::spawn(move || {
            for conn in data_listener.incoming() {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(conn) = conn else { continue };
                let shared = shared.clone();
                std::thread::spawn(move || serve_data_conn(conn, &shared));
            }
        });
    }

    // Executor threads.
    for core in 0..cfg.ncores.max(1) {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name(format!("{}-exec{}", cfg.name, core))
            .spawn(move || executor_loop(&shared))
            .expect("spawn executor");
    }

    // Server reader (reuses one frame buffer for every inbound message).
    {
        let shared = shared.clone();
        std::thread::spawn(move || {
            let mut stream = stream;
            let mut frames_in = frames_in;
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let bytes = match frames_in.read(&mut stream) {
                    Ok(bytes) => bytes,
                    Err(FrameError::Closed) => break,
                    Err(e) => {
                        log::warn!("worker: server stream error: {e}");
                        break;
                    }
                };
                // Hot branch: compute-task decodes through the borrowed
                // view and interns straight into the run-local arenas —
                // no owned Msg (key String, input Vec, addr Strings) is
                // ever built on the enqueue path.
                if matches!(peek_op(bytes), Ok("compute-task")) {
                    let view = match ComputeTaskView::decode(bytes) {
                        Ok(v) => v,
                        Err(e) => {
                            log::warn!("worker: bad compute-task from server: {e}");
                            break;
                        }
                    };
                    // A compute for an already-released run would recreate
                    // the run's arenas for nothing; the server's FIFO makes
                    // this effectively unreachable, but stay defensive.
                    if !shared.store.is_released(view.run) {
                        let enqueued = shared.queue.lock().unwrap().enqueue(&view);
                        match enqueued {
                            Ok(()) => shared.cv.notify_one(),
                            Err(e) => {
                                log::warn!("worker: bad compute-task inputs: {e}");
                                break;
                            }
                        }
                    }
                    continue;
                }
                let msg = match decode_msg(bytes) {
                    Ok(m) => m,
                    Err(e) => {
                        log::warn!("worker: bad message from server: {e}");
                        break;
                    }
                };
                match msg {
                    Msg::StealRequest { run, task } => {
                        // Retract iff still queued (not started) — §IV-C.
                        let retracted = drop_queued(&shared, run, task);
                        let _ = shared.send(&Msg::StealResponse { run, task, ok: retracted });
                    }
                    Msg::PinData { run, task, consumers } => {
                        // A graph extension added consumers of this stored
                        // output: raise its remaining reference count so it
                        // survives for the new gathers. A key we no longer
                        // hold is ignored — the server pins what it believes
                        // resident, and the resurrection path backstops a
                        // copy that evaporated in flight.
                        shared.store.add_consumers(&(run, task), consumers);
                    }
                    Msg::CancelCompute { run, task } => {
                        // Recovery: an input of this task evaporated with a
                        // dead worker. Drop the queued copy — the server
                        // re-sends the task with fresh input locations once
                        // its inputs exist again. No response: unlike a
                        // steal there is nothing to negotiate, and a copy
                        // already running is handled by the server (its
                        // result is accepted or its fetch error retried).
                        drop_queued(&shared, run, task);
                    }
                    Msg::ReplicateData { run, task, addrs } => {
                        // Replication order for one of our outputs. Pushing
                        // is blocking I/O to k−1 peers — keep it off the
                        // reader thread so control traffic keeps flowing.
                        let shared = shared.clone();
                        std::thread::spawn(move || push_replicas(&shared, run, task, &addrs));
                    }
                    Msg::FetchFromServer { run, task } => {
                        let data = lookup(&shared, &(run, task))
                            .map(|d| d.as_ref().clone())
                            .unwrap_or_default();
                        let _ = shared.send(&Msg::DataToServer { run, task, data });
                    }
                    Msg::ReleaseRun { run } => {
                        // Run retired: reclaim its queue entries, interned
                        // arenas and stored outputs (including spill slots)
                        // so a long-lived worker stays bounded. The store's
                        // internal released-mark lands atomically with its
                        // purge, so a racing execution cannot re-insert.
                        shared.store.release_run(run);
                        shared.queue.lock().unwrap().release_run(run);
                    }
                    Msg::Shutdown => {
                        shared.stop.store(true, Ordering::SeqCst);
                        shared.cv.notify_all();
                        break;
                    }
                    Msg::Heartbeat | Msg::Welcome { .. } => {}
                    other => log::warn!("worker: unexpected {:?}", other.op()),
                }
            }
            shared.stop.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
        });
    }

    Ok(WorkerHandle { id, data_addr, shared })
}

/// Retract a task if still queued; returns whether a queued copy was
/// dropped (shared by steal retraction and `cancel-compute`).
fn drop_queued(shared: &Shared, run: RunId, task: TaskId) -> bool {
    shared.queue.lock().unwrap().drop_queued(run, task)
}

/// Store lookup that transparently restores a spilled entry (and rebalances
/// the budget afterwards). `None` = genuinely absent.
fn lookup(shared: &Shared, key: &DataKey) -> Option<Arc<Vec<u8>>> {
    match shared.store.get(key) {
        Lookup::Hit(d) => Some(d),
        Lookup::Spilled => {
            let restored = shared.store.restore(key);
            shared.store.maybe_spill();
            restored
        }
        Lookup::Miss => None,
    }
}

fn executor_loop(shared: &Shared) {
    // Reused scratch: each pop copies the task's key and input addresses
    // into these retained buffers under the queue lock, so nothing borrows
    // the run-local arenas outside it (warm pops allocate nothing).
    let mut plan = FetchPlan::new();
    loop {
        let next = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // pop_into also clears the pending mark — running tasks
                // are no longer stealable.
                if let Some(t) = q.pop_into(&mut plan) {
                    break t;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // Popped after its run was released (queue purge raced the pop):
        // drop it instead of doing dead work — returning its core slots,
        // or a wide task's ghost would gate the queue forever.
        if shared.store.is_released(next.run) {
            shared.queue.lock().unwrap().task_done(next.cores);
            shared.cv.notify_all();
            continue;
        }
        shared.running.fetch_add(1, Ordering::SeqCst);
        let outcome = run_task(shared, &next, &plan);
        shared.running.fetch_sub(1, Ordering::SeqCst);
        match outcome {
            Ok(info) => {
                let _ = shared.send(&Msg::TaskFinished(info));
            }
            Err(e) => {
                let _ = shared.send(&Msg::TaskErred {
                    run: next.run,
                    task: next.task,
                    error: e.to_string(),
                });
            }
        }
        // Slots free only after the outcome is decided: the gate models
        // occupancy for the task's whole stay on the machine.
        shared.queue.lock().unwrap().task_done(next.cores);
        shared.cv.notify_all();
    }
}

fn run_task(shared: &Shared, t: &PoppedTask, plan: &FetchPlan) -> Result<TaskFinishedInfo> {
    // Gather inputs: local store or remote peer. Input locations are
    // relative to the task's own run.
    let mut inputs: Vec<Arc<Vec<u8>>> = Vec::with_capacity(plan.n_inputs());
    for i in 0..plan.n_inputs() {
        let (input_task, _nbytes, addr) = plan.input(i);
        let key = (t.run, input_task);
        let data = match lookup(shared, &key) {
            Some(d) => d,
            None if !addr.is_empty() || plan.n_alts(i) > 0 => {
                let data = fetch_with_failover(plan, i, t)?;
                let arc = Arc::new(data);
                // Passive fetch cache: pinned (release-run reclaims it) and
                // deliberately *not* advertised to the server — who_has
                // only lists copies the server ordered or was told about,
                // so recovery never counts on this one.
                shared.store.insert(key, arc.clone(), 0);
                shared.store.maybe_spill();
                arc
            }
            None => {
                // Local producer raced with us (steal); short bounded wait.
                let mut got = None;
                for _ in 0..500 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    if let Some(d) = lookup(shared, &key) {
                        got = Some(d);
                        break;
                    }
                }
                got.ok_or_else(|| {
                    anyhow!(
                        "{FETCH_FAILED_PREFIX}input {} for {} never arrived",
                        input_task,
                        plan.key()
                    )
                })?
            }
        };
        // One consumption of the input — exactly once per (run, consumer,
        // input): a re-delivered assignment (recovery re-send, steal
        // re-assignment) gathers again but must not double-decrement, or
        // it would prematurely evict an output a sibling consumer still
        // needs. A refcounted local copy that hits zero self-evicts; tell
        // the server so recovery and future `who_has` answers never count
        // on the freed bytes.
        if shared.store.consume_once(&key, t.task) {
            let _ = shared.send(&Msg::ReplicaDropped { run: t.run, task: input_task });
        }
        inputs.push(data);
    }
    let t0 = std::time::Instant::now();
    let output = payload::execute(&t.payload, t.duration_us, t.output_size, &inputs)?;
    let duration_us = t0.elapsed().as_micros() as u64;
    let nbytes = output.len() as u64;
    // The store refuses the insert if a release raced this execution (the
    // server drops our TaskFinished anyway). The wire consumer count seeds
    // the reference count: 0 pins (sink outputs survive for the client).
    shared.store.insert((t.run, t.task), Arc::new(output), t.consumers);
    shared.store.maybe_spill();
    Ok(TaskFinishedInfo { run: t.run, task: t.task, nbytes, duration_us })
}

/// Fetch one input, walking the primary plus every known replica address
/// before giving up with the recoverable `fetch-failed:` error. The
/// starting replica rotates with the consuming task id, so the many
/// consumers of one hot output spread their load across its copies.
fn fetch_with_failover(plan: &FetchPlan, i: usize, t: &PoppedTask) -> Result<Vec<u8>> {
    let (input_task, _nbytes, primary) = plan.input(i);
    let n = 1 + plan.n_alts(i);
    let start = t.task.0 as usize % n;
    let mut last_err: Option<anyhow::Error> = None;
    for j in 0..n {
        let idx = (start + j) % n;
        let addr = if idx == 0 { primary } else { plan.input_alt(i, idx - 1) };
        if addr.is_empty() {
            continue;
        }
        match fetch_remote(addr, t.run, input_task) {
            Ok(d) => return Ok(d),
            Err(e) => last_err = Some(e),
        }
    }
    // The `fetch-failed:` prefix marks this recoverable: every replica was
    // unreachable (or none was named), so the server re-runs this task —
    // resurrecting lost inputs if need be — rather than failing the run.
    let cause = last_err.unwrap_or_else(|| anyhow!("no usable source address"));
    Err(cause.context(format!(
        "{FETCH_FAILED_PREFIX}{}/{} unreachable via {} source(s)",
        t.run, input_task, n
    )))
}

fn fetch_remote(addr: &str, run: RunId, task: TaskId) -> Result<Vec<u8>> {
    let mut s = TcpStream::connect(addr)?;
    s.set_nodelay(true).ok();
    FrameWriter::new().send(&mut s, &Msg::FetchData { run, task })?;
    let mut frames_in = FrameReader::new();
    let reply = decode_msg(frames_in.read(&mut s)?)?;
    match reply {
        Msg::DataReply { run: r, task: t, data } if r == run && t == task => Ok(data),
        other => bail!("unexpected data reply {:?}", other.op()),
    }
}

/// Execute a `replicate-data` order: push our copy of `(run, task)` to each
/// peer data address. Best-effort — a dead or unreachable target is simply
/// skipped, because the server only counts copies whose `replica-added`
/// confirmation arrives from the receiving peer.
fn push_replicas(shared: &Shared, run: RunId, task: TaskId, addrs: &[String]) {
    let Some(bytes) = lookup(shared, &(run, task)) else {
        // Already consumed away or the run was released: nothing to push.
        return;
    };
    for addr in addrs {
        if let Err(e) = push_one(addr, run, task, &bytes) {
            log::debug!("worker: replica push {run}/{task} to {addr} failed: {e}");
        }
    }
}

fn push_one(addr: &str, run: RunId, task: TaskId, bytes: &Arc<Vec<u8>>) -> Result<()> {
    let mut s = TcpStream::connect(addr)?;
    s.set_nodelay(true).ok();
    FrameWriter::new().send(&mut s, &Msg::PutData { run, task, data: bytes.as_ref().clone() })?;
    Ok(())
}

fn serve_data_conn(mut conn: TcpStream, shared: &Shared) {
    conn.set_nodelay(true).ok();
    // Per-connection reused buffers: repeated fetches on one peer link
    // allocate nothing beyond the payload clones themselves.
    let mut frames_in = FrameReader::new();
    let mut frames_out = FrameWriter::new();
    loop {
        let msg = match frames_in.read(&mut conn) {
            Ok(bytes) => match decode_msg(bytes) {
                Ok(m) => m,
                Err(_) => break,
            },
            Err(_) => break,
        };
        match msg {
            Msg::FetchData { run, task } => {
                // The producer finished before the server advertised the
                // location, but the local insert may trail by a hair.
                let key = (run, task);
                let mut data = None;
                for _ in 0..500 {
                    if let Some(d) = lookup(shared, &key) {
                        data = Some(d);
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                let Some(data) = data else { break };
                let reply = Msg::DataReply { run, task, data: data.as_ref().clone() };
                if frames_out.send(&mut conn, &reply).is_err() {
                    break;
                }
                // Serving a peer is one consumption of the graph-wide
                // count; at zero the copy self-evicts and the server is
                // told (same contract as the local-gather decrement).
                if shared.store.consume(&key) {
                    let _ = shared.send(&Msg::ReplicaDropped { run, task });
                }
            }
            Msg::PutData { run, task, data } => {
                // Unsolicited replica push. Stored pinned — replicas never
                // self-evict; `release-run` or the spill tier manage them —
                // and confirmed to the server, which appends us to
                // `who_has`. A duplicate push or one for a released run is
                // dropped without confirmation.
                if shared.store.insert((run, task), Arc::new(data), 0) {
                    shared.store.maybe_spill();
                    let _ = shared.send(&Msg::ReplicaAdded { run, task });
                }
            }
            _ => break,
        }
    }
}
