//! Payload executors — what really runs when a worker receives a task.
//!
//! The array payloads execute the AOT-compiled JAX/Pallas kernels through
//! [`crate::runtime`]; `BusyWait` burns the task's nominal duration on the
//! CPU (the paper's benchmarks are compute-bound, §VI); `WordBag` is a real
//! Rust text pipeline standing in for the Wordbatch workload.

use crate::runtime::Runtime;
use crate::taskgraph::Payload;
use crate::util::rng::splitmix64;
use crate::util::timing::busy_wait_us;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Execute `payload`, producing exactly `output_size` bytes.
///
/// `inputs` are (already fetched) dependency outputs in dependency order.
pub fn execute(
    payload: &Payload,
    duration_us: u64,
    output_size: u64,
    inputs: &[Arc<Vec<u8>>],
) -> Result<Vec<u8>> {
    match payload {
        Payload::NoOp => Ok(filled(output_size, 0)),
        Payload::BusyWait => {
            busy_wait_us(duration_us);
            Ok(filled(output_size, 0x42))
        }
        Payload::MergeInputs => Ok(merge_inputs(inputs, output_size)),
        Payload::HloReduce { seed, .. } => {
            let out = with_runtime(|rt| rt.partition_reduce(*seed))?;
            Ok(pad_f32(&out, output_size))
        }
        Payload::HloTranspose { seed, .. } => {
            let out = with_runtime(|rt| rt.numpy_step(*seed))?;
            Ok(pad_f32(&out, output_size))
        }
        Payload::HloHash { seed, .. } => {
            let out = with_runtime(|rt| rt.feature_hash(*seed))?;
            Ok(pad_f32(&out, output_size))
        }
        Payload::WordBag { n_docs, seed } => Ok(wordbag(*n_docs, *seed, output_size)),
    }
}

fn with_runtime<T>(f: impl FnOnce(&mut Runtime) -> Result<T>) -> Result<T> {
    let rt = Runtime::global()?;
    let mut guard = rt.lock().expect("runtime poisoned");
    f(&mut guard)
}

fn filled(n: u64, byte: u8) -> Vec<u8> {
    vec![byte; n as usize]
}

/// Concatenate (and cycle) input bytes into an output of the given size —
/// a merge node's output really does depend on every input byte.
fn merge_inputs(inputs: &[Arc<Vec<u8>>], output_size: u64) -> Vec<u8> {
    let n = output_size as usize;
    let mut out = Vec::with_capacity(n);
    if inputs.iter().all(|i| i.is_empty()) {
        return vec![0; n];
    }
    // XOR-fold all inputs into the output so every byte matters.
    let mut acc: u8 = 0;
    'outer: loop {
        for input in inputs {
            for &b in input.iter() {
                acc = acc.wrapping_add(b ^ 0x5A);
                out.push(acc);
                if out.len() == n {
                    break 'outer;
                }
            }
        }
        if out.is_empty() {
            break;
        }
    }
    out.resize(n, acc);
    out
}

/// Pad f32 kernel results to the nominal output size (transfer realism).
fn pad_f32(values: &[f32], output_size: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(output_size as usize);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let pattern = if out.is_empty() { vec![0u8] } else { out.clone() };
    while out.len() < output_size as usize {
        let take = (output_size as usize - out.len()).min(pattern.len());
        out.extend_from_slice(&pattern[..take]);
    }
    out.truncate(output_size as usize);
    out
}

/// The wordbag pipeline: synthesize documents, normalize, "spell-correct",
/// count words, and emit a (count-sorted) feature block.
fn wordbag(n_docs: u32, seed: u64, output_size: u64) -> Vec<u8> {
    let mut state = seed.wrapping_add(0xC0FFEE);
    let mut counts: HashMap<String, u32> = HashMap::new();
    for _ in 0..n_docs.max(1) {
        // ~40 words per synthetic review.
        for _ in 0..40 {
            let w = splitmix64(&mut state);
            // Vocabulary of 5000 stems with zipf-ish skew.
            let stem = (w % 5000).min(w % 700);
            // normalize: lowercase letters only; spell-correct: canonical stem.
            let word = format!("w{stem}");
            *counts.entry(word).or_insert(0) += 1;
        }
    }
    let mut pairs: Vec<(String, u32)> = counts.into_iter().collect();
    pairs.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut out = Vec::with_capacity(output_size as usize);
    for (w, c) in &pairs {
        out.extend_from_slice(w.as_bytes());
        out.extend_from_slice(&c.to_le_bytes());
        if out.len() >= output_size as usize {
            break;
        }
    }
    out.resize(output_size as usize, 0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timing::time_us;

    #[test]
    fn noop_and_busywait_sizes() {
        let out = execute(&Payload::NoOp, 0, 100, &[]).unwrap();
        assert_eq!(out.len(), 100);
        let (out, us) = time_us(|| execute(&Payload::BusyWait, 2_000, 64, &[]).unwrap());
        assert_eq!(out.len(), 64);
        assert!(us >= 2_000.0, "busywait ran {us}µs");
    }

    #[test]
    fn merge_consumes_inputs() {
        let a = Arc::new(vec![1u8, 2, 3]);
        let b = Arc::new(vec![9u8; 10]);
        let out1 = execute(&Payload::MergeInputs, 0, 32, &[a.clone(), b.clone()]).unwrap();
        let out2 = execute(&Payload::MergeInputs, 0, 32, &[b, a]).unwrap();
        assert_eq!(out1.len(), 32);
        assert_ne!(out1, out2, "merge output depends on input order/content");
    }

    #[test]
    fn merge_empty_inputs() {
        let out = execute(&Payload::MergeInputs, 0, 16, &[]).unwrap();
        assert_eq!(out.len(), 16);
    }

    #[test]
    fn wordbag_deterministic_and_sized() {
        let a = execute(&Payload::WordBag { n_docs: 20, seed: 5 }, 0, 4096, &[]).unwrap();
        let b = execute(&Payload::WordBag { n_docs: 20, seed: 5 }, 0, 4096, &[]).unwrap();
        let c = execute(&Payload::WordBag { n_docs: 20, seed: 6 }, 0, 4096, &[]).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 4096);
    }

    #[test]
    fn pad_f32_cycles_pattern() {
        let out = pad_f32(&[1.0, 2.0], 20);
        assert_eq!(out.len(), 20);
        assert_eq!(&out[0..4], &1.0f32.to_le_bytes());
        assert_eq!(&out[8..12], &1.0f32.to_le_bytes(), "pattern repeats");
    }

    // HLO payloads are exercised in tests/runtime_hlo.rs (need artifacts).
}
