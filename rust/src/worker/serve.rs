//! The worker↔worker data plane, serve side: one poll-driven thread
//! replaces the thread-per-connection data server (PR 10).
//!
//! Peers now hold long-lived pooled links ([`super::dataplane`]), so the
//! old model — one OS thread parked per inbound connection — would pin a
//! thread per peer for the life of the worker. This loop serves every
//! peer link from a single thread on the PR 7 readiness core
//! ([`crate::server::poll`]): a level-triggered `Poller` over the
//! listener, an eventfd [`Waker`], and all accepted connections.
//!
//! Replies are **zero-copy by construction**: a `data-reply` frame is
//! queued as three segments — an owned head (length prefix + msgpack map
//! header + bin header), the store's payload `Arc` itself, and an owned
//! tail — encoded with the split [`encode_data_frame_head`] /
//! [`encode_data_frame_tail`] encoders whose concatenation is
//! byte-identical to the owned `Msg::DataReply` encoding (asserted in
//! `protocol::codec` tests). The payload bytes are never copied out of
//! the store; head/tail buffers are recycled per connection, so the warm
//! serve path allocates nothing (`benches/hotpath_micro.rs` asserts
//! this).
//!
//! A fetch for a key that is not resident yet parks in the connection's
//! FIFO — the producer's local insert may trail the server's `who_has`
//! advertisement. The store's insert hook pokes the [`Waker`], so parked
//! fetches are served event-driven rather than by sleep-polling. Replies
//! stay in request order per connection (that ordering *is* the
//! `fetch-data-many` reply protocol); a key still missing after the
//! grace window closes the connection, which the fetching side treats as
//! a recoverable failure and fails over to another replica.

use super::dataplane::lookup_restoring;
use super::Shared;
use crate::protocol::{
    decode_msg, encode_data_frame_head, encode_data_frame_tail, DataFrameParts,
    FrameAccumulator, Msg, NbRead, RunId, MAX_FRAME_LEN,
};
use crate::server::poll::{Events, Interest, Poller};
use crate::sync::atomic::Ordering;
use crate::sync::Arc;
use crate::taskgraph::TaskId;
use std::collections::{HashMap, VecDeque};
use std::io::{self, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::time::{Duration, Instant};

const LISTENER_TOKEN: u64 = 0;
const WAKER_TOKEN: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;
/// Recycled head/tail buffers kept per connection.
const SPARE_CAP: usize = 8;
/// Poll tick while any fetch is parked (bounds deadline detection).
const PARKED_TICK_MS: i32 = 25;

/// A fetch whose key was not resident when it arrived, parked until the
/// local producer's insert or the grace deadline.
struct Pending {
    run: RunId,
    task: TaskId,
    deadline: Instant,
}

/// Outbound reply queue: a FIFO of segments, where payloads are shared
/// store `Arc`s and only the small head/tail framing is owned (and
/// recycled).
enum Seg {
    Owned(Vec<u8>),
    Shared(Arc<Vec<u8>>),
}

#[derive(Default)]
struct OutQueue {
    segs: VecDeque<Seg>,
    /// Bytes of the front segment already written.
    head_off: usize,
    spare: Vec<Vec<u8>>,
}

impl OutQueue {
    /// Queue one `data-reply` frame: owned head, shared payload, owned
    /// tail. Hot path (registered in `xtask/hotpath.txt`): warm calls
    /// reuse recycled buffers and allocate nothing beyond queue slots.
    /// `false` = frame would exceed `MAX_FRAME_LEN` (caller closes).
    fn enqueue_reply(&mut self, run: RunId, task: TaskId, data: &Arc<Vec<u8>>) -> bool {
        let parts = DataFrameParts { op: "data-reply", run, task, data_len: data.len() };
        let mut head = self.spare.pop().unwrap_or_default();
        head.clear();
        head.extend_from_slice(&[0u8; 8]);
        encode_data_frame_head(&parts, &mut head);
        let mut tail = self.spare.pop().unwrap_or_default();
        tail.clear();
        encode_data_frame_tail(&parts, &mut tail);
        let body = (head.len() - 8 + data.len() + tail.len()) as u64;
        if body > MAX_FRAME_LEN {
            self.recycle(head);
            self.recycle(tail);
            return false;
        }
        head[..8].copy_from_slice(&body.to_le_bytes());
        self.segs.push_back(Seg::Owned(head));
        self.segs.push_back(Seg::Shared(data.clone())); // lint: clone-ok — Arc refcount bump, not a payload copy
        self.segs.push_back(Seg::Owned(tail));
        true
    }

    fn recycle(&mut self, mut buf: Vec<u8>) {
        if self.spare.len() < SPARE_CAP {
            buf.clear();
            self.spare.push(buf);
        }
    }

    /// Write as much queued data as the socket accepts.
    /// `Ok(true)` = queue drained, `Ok(false)` = socket is full (caller
    /// arms write interest), `Err` = connection is broken.
    fn flush(&mut self, stream: &mut TcpStream) -> io::Result<bool> {
        loop {
            let seg_len = match self.segs.front() {
                None => return Ok(true),
                Some(Seg::Owned(v)) => v.len(),
                Some(Seg::Shared(a)) => a.len(),
            };
            if self.head_off >= seg_len {
                if let Some(seg) = self.segs.pop_front() {
                    if let Seg::Owned(buf) = seg {
                        self.recycle(buf);
                    }
                }
                self.head_off = 0;
                continue;
            }
            let n = {
                let rest: &[u8] = match self.segs.front() {
                    Some(Seg::Owned(v)) => &v[self.head_off..],
                    Some(Seg::Shared(a)) => &a[self.head_off..],
                    None => return Ok(true),
                };
                match stream.write(rest) {
                    Ok(0) => {
                        return Err(io::Error::new(io::ErrorKind::WriteZero, "peer stopped reading"))
                    }
                    Ok(n) => n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            };
            self.head_off += n;
        }
    }
}

struct Conn {
    stream: TcpStream,
    acc: FrameAccumulator,
    waiting: VecDeque<Pending>,
    out: OutQueue,
    /// Whether the poller currently watches this fd for writability.
    write_interest: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            acc: FrameAccumulator::new(),
            waiting: VecDeque::new(),
            out: OutQueue::default(),
            write_interest: false,
        }
    }
}

/// Data-server thread entry point: run the poll loop until shutdown,
/// logging (not panicking on) a fatal loop error.
pub(super) fn run_data_server(listener: TcpListener, shared: Arc<Shared>) {
    if let Err(e) = serve_loop(listener, &shared) {
        if !shared.stop.load(Ordering::SeqCst) {
            log::error!("worker data server failed: {e}");
        }
    }
}

fn serve_loop(listener: TcpListener, shared: &Shared) -> io::Result<()> {
    listener.set_nonblocking(true)?;
    let poller = Poller::new()?;
    let mut events = Events::with_capacity(64);
    poller.register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)?;
    poller.register(shared.data_waker.fd(), WAKER_TOKEN, Interest::READ)?;
    let park = Duration::from_millis(shared.dataplane.config().serve_park_ms.max(1));
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = FIRST_CONN_TOKEN;
    let mut closed: Vec<u64> = Vec::new();

    loop {
        // Serve every connection's parked fetches before sleeping: the
        // insert hook wakes us on new residents, and this pass also
        // drains anything that landed while we were handling events.
        let mut any_parked = false;
        for (tok, conn) in conns.iter_mut() {
            match touch(shared, &poller, *tok, conn) {
                Ok(()) => any_parked |= !conn.waiting.is_empty(),
                Err(_) => closed.push(*tok),
            }
        }
        drop_closed(&poller, &mut conns, &mut closed);

        if shared.stop.load(Ordering::SeqCst) {
            return Ok(());
        }

        let timeout = if any_parked { Some(PARKED_TICK_MS) } else { None };
        poller.wait(&mut events, timeout)?;

        for ev in events.iter() {
            match ev.token {
                LISTENER_TOKEN => accept_all(&poller, &listener, &mut conns, &mut next_token),
                WAKER_TOKEN => shared.data_waker.drain(),
                tok => {
                    let Some(conn) = conns.get_mut(&tok) else { continue };
                    if ev.hangup && !ev.readable {
                        closed.push(tok);
                        continue;
                    }
                    let mut ok = true;
                    if ev.readable {
                        ok = read_frames(shared, conn, park).is_ok();
                    }
                    if ok {
                        ok = touch(shared, &poller, tok, conn).is_ok();
                    }
                    if !ok {
                        closed.push(tok);
                    }
                }
            }
        }
        drop_closed(&poller, &mut conns, &mut closed);
    }
}

fn accept_all(
    poller: &Poller,
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stream.set_nodelay(true).ok();
                let tok = *next_token;
                *next_token += 1;
                if poller.register(stream.as_raw_fd(), tok, Interest::READ).is_err() {
                    continue;
                }
                conns.insert(tok, Conn::new(stream));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                log::warn!("worker data server: accept failed: {e}");
                return;
            }
        }
    }
}

fn drop_closed(poller: &Poller, conns: &mut HashMap<u64, Conn>, closed: &mut Vec<u64>) {
    for tok in closed.drain(..) {
        if let Some(conn) = conns.remove(&tok) {
            let _ = poller.deregister(conn.stream.as_raw_fd());
        }
    }
}

/// Drain every complete inbound frame. `Err` = close this connection
/// (peer gone, undecodable bytes, or an op that does not belong on the
/// data plane).
fn read_frames(shared: &Shared, conn: &mut Conn, park: Duration) -> io::Result<()> {
    loop {
        let msg = match conn.acc.poll_frame(&mut conn.stream) {
            Ok(NbRead::Frame(bytes)) => match decode_msg(bytes) {
                Ok(m) => m,
                Err(_) => return Err(io::Error::new(io::ErrorKind::InvalidData, "bad data frame")),
            },
            Ok(NbRead::WouldBlock) => return Ok(()),
            Ok(NbRead::Closed) => {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed"))
            }
            Err(e) => return Err(io::Error::new(io::ErrorKind::InvalidData, e.to_string())),
        };
        match msg {
            Msg::FetchData { run, task } => {
                conn.waiting.push_back(Pending { run, task, deadline: Instant::now() + park });
            }
            Msg::FetchDataMany { run, tasks } => {
                let deadline = Instant::now() + park;
                for task in tasks {
                    conn.waiting.push_back(Pending { run, task, deadline });
                }
            }
            Msg::PutData { run, task, data } => {
                // Replica inserts are pinned (no consumer count): the
                // server tracks this copy and releases it with the run.
                if shared.store.insert((run, task), Arc::new(data), 0) {
                    shared.store.maybe_spill();
                    let _ = shared.send(&Msg::ReplicaAdded { run, task });
                }
            }
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "unexpected op on data plane",
                ))
            }
        }
    }
}

/// Serve the connection's parked fetches in order, flush the outbound
/// queue, and keep the poller's write interest in sync with whether
/// anything is left to write.
fn touch(shared: &Shared, poller: &Poller, tok: u64, conn: &mut Conn) -> io::Result<()> {
    let now = Instant::now();
    loop {
        let (run, task, deadline) = match conn.waiting.front() {
            None => break,
            Some(p) => (p.run, p.task, p.deadline),
        };
        let key = (run, task);
        match lookup_restoring(&shared.store, &key) {
            Some(data) => {
                conn.waiting.pop_front();
                if !conn.out.enqueue_reply(run, task, &data) {
                    return Err(io::Error::new(io::ErrorKind::InvalidData, "oversized object"));
                }
                if shared.store.consume(&key) {
                    let _ = shared.send(&Msg::ReplicaDropped { run, task });
                }
            }
            None => {
                if now >= deadline {
                    // Still absent after the grace window: drop the
                    // connection; the fetching side fails over.
                    return Err(io::Error::new(io::ErrorKind::NotFound, "object never arrived"));
                }
                // Head-of-line wait is deliberate: per-connection reply
                // order is the fetch-data-many contract.
                break;
            }
        }
    }
    let drained = conn.out.flush(&mut conn.stream)?;
    let want_write = !drained;
    if want_write != conn.write_interest {
        let interest = if want_write { Interest::READ_WRITE } else { Interest::READ };
        poller.rearm(conn.stream.as_raw_fd(), tok, interest)?;
        conn.write_interest = want_write;
    }
    Ok(())
}
