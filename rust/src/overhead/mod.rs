//! Runtime-overhead profiles — the paper's central object of study.
//!
//! The paper attributes Dask's performance gap to "the ubiquitous overhead
//! of reference counting and indirection present in Python" (§IV): a
//! per-event CPU cost paid by the server for every task state transition,
//! every protocol message and every scheduling decision. A
//! [`RuntimeProfile`] makes that cost explicit and calibratable.
//!
//! Two calibrations ship:
//! - [`RuntimeProfile::rust`] — the RSDS server (this codebase's measured
//!   magnitudes; cross-checked by the `hotpath_micro` bench),
//! - [`RuntimeProfile::python`] — the CPython Dask server, calibrated so the
//!   zero-worker AOT of the merge benchmark lands in the 0.2–1 ms/task range
//!   the paper reports (Fig 7/8, and the Dask manual's "about 1 ms of
//!   overhead per task").
//!
//! The same profile drives both execution backends: the discrete-event
//! simulator charges these costs in virtual time, and the real server can
//! busy-wait them on its hot path (`--emulate-python`) to produce a
//! Dask-baseline measurement on real sockets. Constants are calibrated once
//! (DESIGN.md §4) and then held fixed across every experiment.

/// Which scheduling algorithm a decision cost is charged for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    /// Work-stealing (Dask's or RSDS's — the *implementation* cost differs
    /// via the profile, the *algorithmic* worker scan differs via
    /// `per_worker` below).
    WorkStealing,
    /// Uniform random assignment — O(1) per task (§III-E).
    Random,
}

/// Per-event CPU costs of a task-framework server runtime, in microseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeProfile {
    pub name: &'static str,
    /// Cost per task state transition in the server bookkeeping
    /// (ready→assigned, assigned→finished, …).
    pub task_transition_us: f64,
    /// Fixed cost to encode or decode one protocol message.
    pub msg_fixed_us: f64,
    /// Additional per-KiB cost of message (de)serialization.
    pub msg_per_kib_us: f64,
    /// Work-stealing decision: fixed part.
    pub ws_decision_base_us: f64,
    /// Work-stealing decision: per-worker-considered part (Dask's
    /// estimated-start-time heuristic scans workers; §VI-A explains why its
    /// cost grows with the cluster).
    pub ws_decision_per_worker_us: f64,
    /// Random decision cost — constant (§VI-A: "a fixed computation cost per
    /// task independent of the worker count").
    pub random_decision_us: f64,
    /// Cost of one steal/balance cycle on the server (scan + bookkeeping),
    /// excluding the steal messages themselves.
    pub steal_cycle_us: f64,
    /// Whether the reactor and the scheduler share one execution resource
    /// (CPython GIL). RSDS runs the scheduler on its own thread (§IV-A).
    pub gil: bool,
    /// Worker-side per-task overhead (deserialize, spawn, collect). The
    /// paper uses the *Dask worker* for both servers in §VI-A/B/C, so this
    /// is profile-independent there; the zero worker sets it to ~0.
    pub worker_task_overhead_us: f64,
}

impl RuntimeProfile {
    /// The RSDS (Rust) server profile.
    ///
    /// Calibration anchors (DESIGN.md §4): the zero-worker floor sits
    /// ~3.5× under the Dask profile's (the paper's Fig 6 shows RSDS
    /// 1.1–6× faster under the zero worker, i.e. NOT the naive Rust/Python
    /// per-op ratio — RSDS still pays real sockets and real bookkeeping),
    /// and a merge-100K scheduler-thread saturation near the paper's
    /// 15-node plateau (Fig 5) — the balance pass scans all workers, so
    /// its cost grows with the cluster.
    pub fn rust() -> RuntimeProfile {
        RuntimeProfile {
            name: "rsds",
            task_transition_us: 12.0,
            msg_fixed_us: 6.0,
            msg_per_kib_us: 0.008,
            ws_decision_base_us: 6.0,
            ws_decision_per_worker_us: 0.02,
            random_decision_us: 2.0,
            steal_cycle_us: 4.0,
            gil: false,
            worker_task_overhead_us: 5_000.0,
        }
    }

    /// The CPython Dask server profile.
    ///
    /// Calibration anchors (DESIGN.md §4): merge-N under the zero worker
    /// shows ≈0.2–1 ms AOT (Fig 7/8; a finished task ≈ 2 transitions +
    /// 2 messages + 1 decision ⇒ ~0.21 ms), the GIL serializes reactor and
    /// scheduler, and `worker_task_overhead_us` reflects the *Dask worker*
    /// (used with both servers in §VI-A/B/C) — ~2 ms of deserialize/spawn/
    /// collect per task, which is what lets Dask stay within 2× of RSDS on
    /// one node (Fig 5) before the server saturates.
    pub fn python() -> RuntimeProfile {
        RuntimeProfile {
            name: "dask",
            task_transition_us: 45.0,
            msg_fixed_us: 20.0,
            msg_per_kib_us: 0.8,
            ws_decision_base_us: 20.0,
            ws_decision_per_worker_us: 0.05,
            random_decision_us: 12.0,
            steal_cycle_us: 25.0,
            gil: true,
            worker_task_overhead_us: 5_000.0,
        }
    }

    /// Look up a profile by name (CLI surface).
    pub fn by_name(name: &str) -> Option<RuntimeProfile> {
        match name {
            "rsds" | "rust" => Some(Self::rust()),
            "dask" | "python" => Some(Self::python()),
            _ => None,
        }
    }

    /// Cost of one scheduling decision for one task.
    pub fn decision_cost_us(&self, kind: SchedKind, workers_considered: usize) -> f64 {
        match kind {
            SchedKind::Random => self.random_decision_us,
            SchedKind::WorkStealing => {
                self.ws_decision_base_us + self.ws_decision_per_worker_us * workers_considered as f64
            }
        }
    }

    /// Cost of encoding or decoding one message of `bytes` length.
    pub fn msg_cost_us(&self, bytes: usize) -> f64 {
        self.msg_fixed_us + self.msg_per_kib_us * (bytes as f64 / 1024.0)
    }

    /// Server-side cost of fully processing one finished task in steady
    /// state: status message in, bookkeeping, decision for a successor,
    /// assignment message out. This is the analytic per-task floor the
    /// paper's AOT measures; used for sanity checks and reports.
    pub fn per_task_floor_us(&self, kind: SchedKind, n_workers: usize, msg_bytes: usize) -> f64 {
        2.0 * self.task_transition_us
            + 2.0 * self.msg_cost_us(msg_bytes)
            + self.decision_cost_us(kind, n_workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn python_floor_matches_paper_aot_band() {
        // Fig 7/8 / Dask manual: Dask ≈ "about 1ms of overhead" per task,
        // measured AOT mostly 0.15–1 ms under the zero worker.
        let p = RuntimeProfile::python();
        for workers in [24, 168] {
            let floor = p.per_task_floor_us(SchedKind::WorkStealing, workers, 256);
            assert!(
                (120.0..=1_000.0).contains(&floor),
                "dask ws floor at {workers}w = {floor}µs"
            );
        }
    }

    #[test]
    fn rust_floor_matches_paper_aot_band() {
        // Fig 6/7/8: RSDS AOT sits 1.1–6× under Dask's (which is
        // 0.15–1 ms), i.e. in the tens-of-µs to ~150 µs range.
        let p = RuntimeProfile::rust();
        for workers in [24, 168, 1512] {
            let floor = p.per_task_floor_us(SchedKind::WorkStealing, workers, 256);
            assert!(
                (30.0..=150.0).contains(&floor),
                "rsds ws floor at {workers}w = {floor}µs"
            );
        }
    }

    #[test]
    fn ws_cost_grows_with_workers_random_does_not() {
        let p = RuntimeProfile::python();
        let ws24 = p.decision_cost_us(SchedKind::WorkStealing, 24);
        let ws1512 = p.decision_cost_us(SchedKind::WorkStealing, 1512);
        assert!(ws1512 > ws24 * 3.0, "{ws1512} vs {ws24}");
        let r24 = p.decision_cost_us(SchedKind::Random, 24);
        let r1512 = p.decision_cost_us(SchedKind::Random, 1512);
        assert_eq!(r24, r1512);
    }

    #[test]
    fn rust_floor_ratio_in_fig6_band() {
        // Fig 6: zero-worker speedup of RSDS over Dask is 1.1–6×.
        let r = RuntimeProfile::rust().per_task_floor_us(SchedKind::WorkStealing, 24, 256);
        let p = RuntimeProfile::python().per_task_floor_us(SchedKind::WorkStealing, 24, 256);
        let ratio = p / r;
        assert!((1.1..=6.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(RuntimeProfile::by_name("rsds").unwrap().name, "rsds");
        assert_eq!(RuntimeProfile::by_name("python").unwrap().name, "dask");
        assert!(RuntimeProfile::by_name("julia").is_none());
    }

    #[test]
    fn msg_cost_scales_with_size() {
        let p = RuntimeProfile::python();
        let small = p.msg_cost_us(100);
        let big = p.msg_cost_us(1024 * 1024);
        assert!(big > small + 700.0, "1 MiB message should cost ≫ fixed part");
    }
}
