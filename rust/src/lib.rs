//! # rsds — reproduction of "Runtime vs Scheduler: Analyzing Dask's Overheads"
//!
//! A Dask-like distributed task framework built around a Rust central server
//! (the paper's RSDS), with:
//!
//! - a MessagePack wire protocol ([`msgpack`], [`protocol`]) mirroring the
//!   Dask protocol the paper adapts in §IV-B,
//! - a reactor/scheduler-separated central server ([`server`], §IV-A),
//! - pluggable schedulers ([`scheduler`]): random, RSDS work-stealing and an
//!   emulation of Dask's work-stealing heuristic,
//! - real workers executing real payloads — including AOT-compiled JAX/Pallas
//!   kernels via PJRT ([`worker`], [`runtime`]) — and the paper's *zero
//!   worker* (§IV-D),
//! - calibrated runtime-overhead profiles modelling the CPython (Dask) server
//!   vs the Rust server ([`overhead`]),
//! - a discrete-event simulator ([`sim`]) that scales the experiments to the
//!   paper's 1512-worker clusters,
//! - generators for every benchmark task graph of §V / Table I ([`graphgen`]),
//! - and a benchmark harness ([`bench`]) regenerating every table and figure.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bench;
pub mod client;
pub mod graphgen;
pub mod intern;
pub mod metrics;
pub mod modelcheck;
pub mod msgpack;
pub mod overhead;
pub mod protocol;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod sync;
pub mod taskgraph;
pub mod testing;
pub mod util;
pub mod worker;
