//! Client: submits task graphs to the server and waits for results
//! (paper §III-B: "connects to a DASK cluster, submits task graphs to the
//! server and gathers the results").
//!
//! The server is multi-graph: every submission is acknowledged with a
//! server-assigned [`RunId`] (`graph-submitted`), and all later messages
//! about that graph carry it. A client may therefore *pipeline* — submit
//! several graphs back-to-back with [`Client::submit`] and collect each
//! result with [`Client::wait`] in any order. [`Client::run_graph`] keeps
//! the old one-shot submit-and-block behavior, and
//! [`Client::submit_with`]/[`Client::run_graph_with`] let a submission name
//! the scheduler that should serve it (per-run scheduler choice).
//!
//! Admission control: a server caps concurrently executing runs per
//! client; a submission past the cap is acked with `run-queued` and parks
//! until earlier runs retire. [`Client::submit`] still returns
//! immediately with the run id, [`Client::wait`] spans the queued phase
//! transparently, and [`Client::is_queued`] exposes the phase.
//!
//! Exhausted-budget retry (opt-in): a run that fails because the server's
//! worker-disconnect recovery budget ran out is a *capacity* failure, not
//! a graph failure — [`Client::with_retry_exhausted`] resubmits it (up to
//! a bounded number of attempts) and [`Client::wait`] follows the
//! replacement under the original run id.
//!
//! I/O reuses one [`FrameWriter`] and one [`FrameReader`] per connection:
//! a warm send/receive allocates nothing beyond the decoded message's own
//! fields.

use crate::protocol::{
    decode_msg, FrameReader, FrameWriter, Msg, RunId, RECOVERY_EXHAUSTED_REASON,
};
use crate::taskgraph::{TaskGraph, TaskSpec};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::net::TcpStream;
use std::time::Instant;

/// Result of one graph execution as observed by the client — the paper's
/// *makespan* is "the duration between the initial task graph submission to
/// the server and the processing of the final output task" (§VI).
#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    pub run: RunId,
    pub graph_name: String,
    pub n_tasks: u64,
    /// Server-measured makespan.
    pub makespan_us: u64,
    /// Client-observed wall time submit → done (includes client RTT).
    pub wall_us: u64,
}

struct PendingRun {
    graph_name: String,
    submitted_at: Instant,
    /// Parked in the server's admission queue (acked with `run-queued`);
    /// cleared when the activation `graph-submitted` arrives.
    queued: bool,
    /// The submitted graph, retained only when exhausted-budget retry is
    /// enabled ([`Client::with_retry_exhausted`]) — a resubmission needs
    /// it after the server already dropped the failed run's state.
    graph: Option<TaskGraph>,
    /// Scheduler override to replay on a resubmission.
    scheduler: Option<String>,
    /// Resubmissions this run may still consume.
    retries_left: u32,
    /// Submitted with `open: true` and not yet closed by a `last: true`
    /// extension — [`Client::extend`] may still graft task batches on.
    open: bool,
}

/// A resubmission sent after an exhausted-budget failure, awaiting its
/// server ack. FIFO: one connection acks submissions in send order, so the
/// next ack for an unknown run belongs to the front entry.
struct RetryResub {
    /// The run whose failure triggered this resubmission; `redirects`
    /// points it at the replacement once the ack names the new run.
    failed_run: RunId,
    pending: PendingRun,
}

/// A connected client.
pub struct Client {
    stream: TcpStream,
    frames_out: FrameWriter,
    frames_in: FrameReader,
    pub id: u32,
    /// Submitted but not yet completed runs.
    in_flight: HashMap<RunId, PendingRun>,
    /// Completed (or failed) runs not yet claimed by `wait`.
    completed: HashMap<RunId, Result<RunResult>>,
    /// Resubmission budget per run for exhausted-recovery failures
    /// (0 = disabled, the default).
    retry_exhausted: u32,
    /// Resubmissions performed so far (tests / diagnostics).
    retries_used: u64,
    /// failed run → the run resubmitted in its place; `wait` follows the
    /// chain so callers keep using the original id.
    redirects: HashMap<RunId, RunId>,
    /// Resubmissions decided on but not yet sent. Sending is deferred to
    /// the safe points ([`Client::flush_resubs`]) so submission acks stay
    /// strictly FIFO with `submit_with`'s own pending ack.
    pending_resubs: VecDeque<RetryResub>,
    /// Resubmissions sent to the server, awaiting their acks.
    awaiting_retry_ack: VecDeque<RetryResub>,
}

impl Client {
    /// Connect and register.
    pub fn connect(addr: &str, name: &str) -> Result<Client> {
        // Retrying connect: a client fleet larger than the listen backlog
        // (fig. 9 runs 1024 at once) sees transient refusals on loopback.
        let mut stream =
            crate::util::connect_with_retry(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        let mut frames_out = FrameWriter::new();
        let mut frames_in = FrameReader::new();
        frames_out.send(&mut stream, &Msg::RegisterClient { name: name.into() })?;
        let reply = decode_msg(frames_in.read(&mut stream)?)?;
        let Msg::Welcome { id } = reply else {
            bail!("expected welcome, got {:?}", reply.op());
        };
        Ok(Client {
            stream,
            frames_out,
            frames_in,
            id,
            in_flight: HashMap::new(),
            completed: HashMap::new(),
            retry_exhausted: 0,
            retries_used: 0,
            redirects: HashMap::new(),
            pending_resubs: VecDeque::new(),
            awaiting_retry_ack: VecDeque::new(),
        })
    }

    /// Send every decided-but-unsent resubmission. Called only at points
    /// where no user submission awaits its ack (start of `submit_with`,
    /// top of `wait`'s loop), so acks keep arriving in a known order:
    /// already-sent resubmissions first, then the user's submission.
    fn flush_resubs(&mut self) -> Result<()> {
        while let Some(resub) = self.pending_resubs.pop_front() {
            let graph = resub.pending.graph.clone().expect("retry retains the graph");
            self.frames_out.send(
                &mut self.stream,
                // A retried run resubmits closed: open runs are excluded
                // from retry until their last extension landed, so the
                // retained graph is always the complete one.
                &Msg::SubmitGraph {
                    graph,
                    scheduler: resub.pending.scheduler.clone(),
                    open: false,
                },
            )?;
            self.retries_used += 1;
            self.awaiting_retry_ack.push_back(resub);
        }
        Ok(())
    }

    /// Opt in to resubmitting runs that fail with an exhausted
    /// worker-disconnect recovery budget: up to `attempts` resubmissions
    /// per run. The failure means the *cluster lost capacity mid-run*, not
    /// that the graph is bad, so a resubmission onto the surviving workers
    /// usually succeeds. [`Client::wait`] follows the replacement
    /// transparently (same run id from the caller's point of view), and
    /// `wall_us` keeps counting from the original submission. Costs one
    /// retained graph clone per in-flight run while enabled.
    pub fn with_retry_exhausted(mut self, attempts: u32) -> Client {
        self.retry_exhausted = attempts;
        self
    }

    /// Resubmissions performed so far under [`Client::with_retry_exhausted`].
    pub fn retries_used(&self) -> u64 {
        self.retries_used
    }

    /// Follow the resubmission chain from a (possibly failed-and-replaced)
    /// run to the run currently carrying its work.
    fn resolve(&self, mut run: RunId) -> RunId {
        while let Some(&next) = self.redirects.get(&run) {
            run = next;
        }
        run
    }

    /// Read and decode the next server message.
    fn read_msg(&mut self) -> Result<Msg> {
        Ok(decode_msg(self.frames_in.read(&mut self.stream)?)?)
    }

    /// Submit a graph without waiting for its completion; returns the
    /// server-assigned run id once the submission is acknowledged. Several
    /// submissions may be in flight at once.
    pub fn submit(&mut self, graph: &TaskGraph) -> Result<RunId> {
        self.submit_with(graph, None)
    }

    /// Like [`Client::submit`], but names the scheduler that should serve
    /// this run (`random` | `ws` | …). `None` uses the server default; an
    /// unknown name fails the run (surfaced by [`Client::wait`]).
    ///
    /// A server at this client's live-run cap acks with `run-queued`
    /// instead of `graph-submitted`: the run is parked in the admission
    /// queue and activates as earlier runs retire. `submit` returns its
    /// run id either way, and [`Client::wait`] spans the queued phase
    /// transparently; [`Client::is_queued`] tells the phases apart.
    pub fn submit_with(&mut self, graph: &TaskGraph, scheduler: Option<&str>) -> Result<RunId> {
        self.submit_inner(graph, scheduler, false)
    }

    /// Submit an *open* graph: the base batch starts executing immediately,
    /// and the caller streams further task batches in with
    /// [`Client::extend`] — the run only completes once a `last: true`
    /// extension closed it and every task finished. New tasks may depend on
    /// any earlier task, including ones that already ran.
    pub fn submit_open(&mut self, graph: &TaskGraph, scheduler: Option<&str>) -> Result<RunId> {
        self.submit_inner(graph, scheduler, true)
    }

    fn submit_inner(
        &mut self,
        graph: &TaskGraph,
        scheduler: Option<&str>,
        open: bool,
    ) -> Result<RunId> {
        // Any retry resubmissions decided during an earlier read loop go
        // out first, keeping submission acks strictly FIFO.
        self.flush_resubs()?;
        let name = graph.name.clone();
        let submitted_at = Instant::now();
        let msg = Msg::SubmitGraph {
            graph: graph.clone(),
            scheduler: scheduler.map(str::to_string),
            open,
        };
        self.frames_out.send(&mut self.stream, &msg)?;
        // Read until the ack for *this* submission arrives. Completions of
        // earlier pipelined runs may interleave — as may activation
        // notices (`graph-submitted` for a run already known as queued)
        // and acks for retry resubmissions; those are filed by
        // `handle_completion`. Acks arrive in send order, so while retry
        // resubmissions await theirs, an unknown ack is *not* ours.
        loop {
            let msg = self.read_msg()?;
            match msg {
                Msg::GraphSubmitted { run, .. }
                    if self.awaiting_retry_ack.is_empty()
                        && !self.in_flight.contains_key(&run) =>
                {
                    self.in_flight.insert(
                        run,
                        PendingRun {
                            graph_name: name,
                            submitted_at,
                            queued: false,
                            graph: (self.retry_exhausted > 0).then(|| graph.clone()),
                            scheduler: scheduler.map(str::to_string),
                            retries_left: self.retry_exhausted,
                            open,
                        },
                    );
                    return Ok(run);
                }
                Msg::RunQueued { run, .. }
                    if self.awaiting_retry_ack.is_empty()
                        && !self.in_flight.contains_key(&run) =>
                {
                    self.in_flight.insert(
                        run,
                        PendingRun {
                            graph_name: name,
                            submitted_at,
                            queued: true,
                            graph: (self.retry_exhausted > 0).then(|| graph.clone()),
                            scheduler: scheduler.map(str::to_string),
                            retries_left: self.retry_exhausted,
                            open,
                        },
                    );
                    return Ok(run);
                }
                other => self.handle_completion(other)?,
            }
        }
    }

    /// Stream a task batch into an open run (see [`Client::submit_open`]).
    /// New tasks may depend on any task already in the run — even finished
    /// ones whose outputs self-evicted; the server re-pins or resurrects
    /// those. `last: true` closes the run (an empty `tasks` with
    /// `last: true` is a pure close). Blocks until the server acknowledges
    /// the extension; completions of other pipelined runs arriving in the
    /// meantime are filed as usual.
    pub fn extend(&mut self, run: RunId, tasks: Vec<TaskSpec>, last: bool) -> Result<()> {
        self.flush_resubs()?;
        let cur = self.resolve(run);
        {
            let Some(pending) = self.in_flight.get_mut(&cur) else {
                bail!("run {run} is not in flight on this client");
            };
            if !pending.open {
                bail!("run {run} was not submitted open (or is already closed)");
            }
            // Keep the retry-retained graph in step so a post-close
            // resubmission replays the *extended* graph.
            if let Some(g) = pending.graph.as_mut() {
                if !tasks.is_empty() {
                    g.extend(tasks.clone()).map_err(|e| anyhow!("bad extension: {e}"))?;
                }
            }
            if last {
                pending.open = false;
            }
        }
        self.frames_out
            .send(&mut self.stream, &Msg::SubmitExtend { run: cur, tasks, last })?;
        // Read until the ack (`graph-submitted` re-quoting this run with
        // its new task total). A queued-run activation notice is
        // indistinguishable and may be consumed instead — harmless, the
        // real ack then lands in `handle_completion` as a phase note.
        loop {
            let msg = self.read_msg()?;
            match msg {
                Msg::GraphSubmitted { run: r, .. } if r == cur => {
                    if let Some(p) = self.in_flight.get_mut(&cur) {
                        p.queued = false;
                    }
                    return Ok(());
                }
                Msg::GraphFailed { run: r, reason } if r == cur => {
                    self.in_flight.remove(&cur);
                    bail!("extension rejected: {reason}");
                }
                other => self.handle_completion(other)?,
            }
        }
    }

    /// Block until `run` (a value returned by [`Client::submit`]) finishes;
    /// returns its result or the server-reported failure. If the run was
    /// replaced by a retry resubmission, this follows the chain and
    /// returns the replacement's result under the original id.
    pub fn wait(&mut self, run: RunId) -> Result<RunResult> {
        loop {
            self.flush_resubs()?;
            let cur = self.resolve(run);
            if let Some(res) = self.completed.remove(&cur) {
                return res;
            }
            if !self.in_flight.contains_key(&cur)
                && !self.awaiting_retry_ack.iter().any(|r| r.failed_run == cur)
                && !self.pending_resubs.iter().any(|r| r.failed_run == cur)
            {
                bail!("run {run} was never submitted on this client");
            }
            let msg = self.read_msg()?;
            self.handle_completion(msg)?;
        }
    }

    /// Number of submitted-but-unfinished runs.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Whether `run` is (as far as this client has heard) still parked in
    /// the server's admission queue rather than executing. False once the
    /// activation notice arrived, or for unknown/completed runs. Reads
    /// only buffered state — call [`Client::wait`] (or submit more work)
    /// to make progress on the socket.
    pub fn is_queued(&self, run: RunId) -> bool {
        self.in_flight.get(&self.resolve(run)).map(|p| p.queued).unwrap_or(false)
    }

    /// Submit a graph and block until it completes or fails.
    pub fn run_graph(&mut self, graph: &TaskGraph) -> Result<RunResult> {
        self.run_graph_with(graph, None)
    }

    /// Submit a graph under a named scheduler and block for the result.
    pub fn run_graph_with(
        &mut self,
        graph: &TaskGraph,
        scheduler: Option<&str>,
    ) -> Result<RunResult> {
        let run = self.submit_with(graph, scheduler)?;
        self.wait(run)
    }

    /// File a graph-done / graph-failed under its run; track admission
    /// phase changes; file retry-resubmission acks; ignore heartbeats.
    fn handle_completion(&mut self, msg: Msg) -> Result<()> {
        match msg {
            Msg::GraphSubmitted { run, .. } => {
                if let Some(pending) = self.in_flight.get_mut(&run) {
                    // Activation notice for a run previously acked as
                    // queued (a fresh submission's ack is consumed by
                    // `submit_with`).
                    pending.queued = false;
                } else if let Some(resub) = self.awaiting_retry_ack.pop_front() {
                    // Ack for a retry resubmission: acks arrive in send
                    // order, so the front entry owns it. The failed run
                    // now redirects to its replacement.
                    self.redirects.insert(resub.failed_run, run);
                    self.in_flight.insert(run, resub.pending);
                } else {
                    bail!("graph-submitted for unknown run {run}");
                }
            }
            Msg::RunQueued { run, .. } => {
                if self.in_flight.contains_key(&run) {
                    bail!("run-queued for already-acked run {run}");
                }
                // A retry resubmission can itself be parked by admission
                // control; `wait` spans that phase like any other.
                let Some(mut resub) = self.awaiting_retry_ack.pop_front() else {
                    bail!("run-queued for unknown run {run}");
                };
                resub.pending.queued = true;
                self.redirects.insert(resub.failed_run, run);
                self.in_flight.insert(run, resub.pending);
            }
            Msg::GraphDone { run, makespan_us, n_tasks } => {
                let Some(pending) = self.in_flight.remove(&run) else {
                    bail!("graph-done for unknown run {run}");
                };
                self.completed.insert(
                    run,
                    Ok(RunResult {
                        run,
                        graph_name: pending.graph_name,
                        n_tasks,
                        makespan_us,
                        // Spans the full chain for a retried run: the
                        // latency the caller actually observed.
                        wall_us: pending.submitted_at.elapsed().as_micros() as u64,
                    }),
                );
            }
            Msg::GraphFailed { run, reason } => {
                // Symmetric with GraphDone: a failure for a run this client
                // never submitted is a protocol violation, not something to
                // file away unclaimably.
                let Some(pending) = self.in_flight.remove(&run) else {
                    bail!("graph-failed for unknown run {run}: {reason}");
                };
                // Opt-in resubmission: the run died because the cluster
                // lost capacity mid-run (recovery budget exhausted), not
                // because of the graph. Resubmit onto the survivors.
                if pending.retries_left > 0
                    && pending.graph.is_some()
                    // A still-open run can't be replayed faithfully — the
                    // retained graph only matches once the close landed.
                    && !pending.open
                    && reason.contains(RECOVERY_EXHAUSTED_REASON)
                {
                    // Deferred: the actual send happens at the next safe
                    // point (`flush_resubs`), never from inside a read
                    // loop that may itself be awaiting a submission ack.
                    self.pending_resubs.push_back(RetryResub {
                        failed_run: run,
                        pending: PendingRun {
                            queued: false,
                            retries_left: pending.retries_left - 1,
                            ..pending
                        },
                    });
                } else {
                    self.completed.insert(run, Err(anyhow!("graph failed: {reason}")));
                }
            }
            Msg::Heartbeat => {}
            other => bail!("unexpected message {:?}", other.op()),
        }
        Ok(())
    }
}
