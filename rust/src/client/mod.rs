//! Client: submits task graphs to the server and waits for results
//! (paper §III-B: "connects to a DASK cluster, submits task graphs to the
//! server and gathers the results").

use crate::protocol::{decode_msg, encode_msg, read_frame, write_frame, Msg};
use crate::taskgraph::TaskGraph;
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpStream;

/// Result of one graph execution as observed by the client — the paper's
/// *makespan* is "the duration between the initial task graph submission to
/// the server and the processing of the final output task" (§VI).
#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    pub graph_name: String,
    pub n_tasks: u64,
    /// Server-measured makespan.
    pub makespan_us: u64,
    /// Client-observed wall time submit → done (includes client RTT).
    pub wall_us: u64,
}

/// A connected client.
pub struct Client {
    stream: TcpStream,
    pub id: u32,
}

impl Client {
    /// Connect and register.
    pub fn connect(addr: &str, name: &str) -> Result<Client> {
        let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        write_frame(&mut stream, &encode_msg(&Msg::RegisterClient { name: name.into() }))?;
        let reply = decode_msg(&read_frame(&mut stream)?)?;
        let Msg::Welcome { id } = reply else {
            bail!("expected welcome, got {:?}", reply.op());
        };
        Ok(Client { stream, id })
    }

    /// Submit a graph and block until it completes or fails.
    pub fn run_graph(&mut self, graph: &TaskGraph) -> Result<RunResult> {
        let name = graph.name.clone();
        let t0 = std::time::Instant::now();
        write_frame(&mut self.stream, &encode_msg(&Msg::SubmitGraph { graph: graph.clone() }))?;
        loop {
            let msg = decode_msg(&read_frame(&mut self.stream)?)?;
            match msg {
                Msg::GraphDone { makespan_us, n_tasks } => {
                    return Ok(RunResult {
                        graph_name: name,
                        n_tasks,
                        makespan_us,
                        wall_us: t0.elapsed().as_micros() as u64,
                    });
                }
                Msg::GraphFailed { reason } => return Err(anyhow!("graph failed: {reason}")),
                Msg::Heartbeat => continue,
                other => bail!("unexpected message {:?}", other.op()),
            }
        }
    }
}
