//! Client: submits task graphs to the server and waits for results
//! (paper §III-B: "connects to a DASK cluster, submits task graphs to the
//! server and gathers the results").
//!
//! The server is multi-graph: every submission is acknowledged with a
//! server-assigned [`RunId`] (`graph-submitted`), and all later messages
//! about that graph carry it. A client may therefore *pipeline* — submit
//! several graphs back-to-back with [`Client::submit`] and collect each
//! result with [`Client::wait`] in any order. [`Client::run_graph`] keeps
//! the old one-shot submit-and-block behavior, and
//! [`Client::submit_with`]/[`Client::run_graph_with`] let a submission name
//! the scheduler that should serve it (per-run scheduler choice).
//!
//! Admission control: a server caps concurrently executing runs per
//! client; a submission past the cap is acked with `run-queued` and parks
//! until earlier runs retire. [`Client::submit`] still returns
//! immediately with the run id, [`Client::wait`] spans the queued phase
//! transparently, and [`Client::is_queued`] exposes the phase.
//!
//! I/O reuses one [`FrameWriter`] and one [`FrameReader`] per connection:
//! a warm send/receive allocates nothing beyond the decoded message's own
//! fields.

use crate::protocol::{decode_msg, FrameReader, FrameWriter, Msg, RunId};
use crate::taskgraph::TaskGraph;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::time::Instant;

/// Result of one graph execution as observed by the client — the paper's
/// *makespan* is "the duration between the initial task graph submission to
/// the server and the processing of the final output task" (§VI).
#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    pub run: RunId,
    pub graph_name: String,
    pub n_tasks: u64,
    /// Server-measured makespan.
    pub makespan_us: u64,
    /// Client-observed wall time submit → done (includes client RTT).
    pub wall_us: u64,
}

struct PendingRun {
    graph_name: String,
    submitted_at: Instant,
    /// Parked in the server's admission queue (acked with `run-queued`);
    /// cleared when the activation `graph-submitted` arrives.
    queued: bool,
}

/// A connected client.
pub struct Client {
    stream: TcpStream,
    frames_out: FrameWriter,
    frames_in: FrameReader,
    pub id: u32,
    /// Submitted but not yet completed runs.
    in_flight: HashMap<RunId, PendingRun>,
    /// Completed (or failed) runs not yet claimed by `wait`.
    completed: HashMap<RunId, Result<RunResult>>,
}

impl Client {
    /// Connect and register.
    pub fn connect(addr: &str, name: &str) -> Result<Client> {
        let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        let mut frames_out = FrameWriter::new();
        let mut frames_in = FrameReader::new();
        frames_out.send(&mut stream, &Msg::RegisterClient { name: name.into() })?;
        let reply = decode_msg(frames_in.read(&mut stream)?)?;
        let Msg::Welcome { id } = reply else {
            bail!("expected welcome, got {:?}", reply.op());
        };
        Ok(Client {
            stream,
            frames_out,
            frames_in,
            id,
            in_flight: HashMap::new(),
            completed: HashMap::new(),
        })
    }

    /// Read and decode the next server message.
    fn read_msg(&mut self) -> Result<Msg> {
        Ok(decode_msg(self.frames_in.read(&mut self.stream)?)?)
    }

    /// Submit a graph without waiting for its completion; returns the
    /// server-assigned run id once the submission is acknowledged. Several
    /// submissions may be in flight at once.
    pub fn submit(&mut self, graph: &TaskGraph) -> Result<RunId> {
        self.submit_with(graph, None)
    }

    /// Like [`Client::submit`], but names the scheduler that should serve
    /// this run (`random` | `ws` | …). `None` uses the server default; an
    /// unknown name fails the run (surfaced by [`Client::wait`]).
    ///
    /// A server at this client's live-run cap acks with `run-queued`
    /// instead of `graph-submitted`: the run is parked in the admission
    /// queue and activates as earlier runs retire. `submit` returns its
    /// run id either way, and [`Client::wait`] spans the queued phase
    /// transparently; [`Client::is_queued`] tells the phases apart.
    pub fn submit_with(&mut self, graph: &TaskGraph, scheduler: Option<&str>) -> Result<RunId> {
        let name = graph.name.clone();
        let submitted_at = Instant::now();
        let msg = Msg::SubmitGraph {
            graph: graph.clone(),
            scheduler: scheduler.map(str::to_string),
        };
        self.frames_out.send(&mut self.stream, &msg)?;
        // Read until the ack for *this* submission arrives. Completions of
        // earlier pipelined runs may interleave — as may activation
        // notices (`graph-submitted` for a run already known as queued);
        // both are filed by `handle_completion`.
        loop {
            let msg = self.read_msg()?;
            match msg {
                Msg::GraphSubmitted { run, .. } if !self.in_flight.contains_key(&run) => {
                    self.in_flight.insert(
                        run,
                        PendingRun { graph_name: name, submitted_at, queued: false },
                    );
                    return Ok(run);
                }
                Msg::RunQueued { run, .. } if !self.in_flight.contains_key(&run) => {
                    self.in_flight.insert(
                        run,
                        PendingRun { graph_name: name, submitted_at, queued: true },
                    );
                    return Ok(run);
                }
                other => self.handle_completion(other)?,
            }
        }
    }

    /// Block until `run` (a value returned by [`Client::submit`]) finishes;
    /// returns its result or the server-reported failure.
    pub fn wait(&mut self, run: RunId) -> Result<RunResult> {
        loop {
            if let Some(res) = self.completed.remove(&run) {
                return res;
            }
            if !self.in_flight.contains_key(&run) {
                bail!("run {run} was never submitted on this client");
            }
            let msg = self.read_msg()?;
            self.handle_completion(msg)?;
        }
    }

    /// Number of submitted-but-unfinished runs.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Whether `run` is (as far as this client has heard) still parked in
    /// the server's admission queue rather than executing. False once the
    /// activation notice arrived, or for unknown/completed runs. Reads
    /// only buffered state — call [`Client::wait`] (or submit more work)
    /// to make progress on the socket.
    pub fn is_queued(&self, run: RunId) -> bool {
        self.in_flight.get(&run).map(|p| p.queued).unwrap_or(false)
    }

    /// Submit a graph and block until it completes or fails.
    pub fn run_graph(&mut self, graph: &TaskGraph) -> Result<RunResult> {
        self.run_graph_with(graph, None)
    }

    /// Submit a graph under a named scheduler and block for the result.
    pub fn run_graph_with(
        &mut self,
        graph: &TaskGraph,
        scheduler: Option<&str>,
    ) -> Result<RunResult> {
        let run = self.submit_with(graph, scheduler)?;
        self.wait(run)
    }

    /// File a graph-done / graph-failed under its run; track admission
    /// phase changes; ignore heartbeats.
    fn handle_completion(&mut self, msg: Msg) -> Result<()> {
        match msg {
            Msg::GraphSubmitted { run, .. } => {
                // Activation notice for a run previously acked as queued
                // (a fresh submission's ack is consumed by `submit_with`).
                let Some(pending) = self.in_flight.get_mut(&run) else {
                    bail!("graph-submitted for unknown run {run}");
                };
                pending.queued = false;
            }
            Msg::RunQueued { run, .. } => {
                bail!("run-queued for already-acked run {run}");
            }
            Msg::GraphDone { run, makespan_us, n_tasks } => {
                let Some(pending) = self.in_flight.remove(&run) else {
                    bail!("graph-done for unknown run {run}");
                };
                self.completed.insert(
                    run,
                    Ok(RunResult {
                        run,
                        graph_name: pending.graph_name,
                        n_tasks,
                        makespan_us,
                        wall_us: pending.submitted_at.elapsed().as_micros() as u64,
                    }),
                );
            }
            Msg::GraphFailed { run, reason } => {
                // Symmetric with GraphDone: a failure for a run this client
                // never submitted is a protocol violation, not something to
                // file away unclaimably.
                if self.in_flight.remove(&run).is_none() {
                    bail!("graph-failed for unknown run {run}: {reason}");
                }
                self.completed.insert(run, Err(anyhow!("graph failed: {reason}")));
            }
            Msg::Heartbeat => {}
            other => bail!("unexpected message {:?}", other.op()),
        }
        Ok(())
    }
}
