//! MessagePack decoder over a flat byte slice with strict bounds checking.
//!
//! Defensive by construction: declared lengths are validated against the
//! remaining input *before* allocation, so a malicious 4 GiB length prefix
//! on a 40-byte frame is rejected instead of causing an OOM — this is the
//! failure-injection surface tested in `protocol`.

use super::Value;
use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum DecodeError {
    #[error("unexpected end of input at offset {0}")]
    Eof(usize),
    #[error("declared length {len} exceeds remaining input {remaining} at offset {offset}")]
    LengthOverrun { offset: usize, len: usize, remaining: usize },
    #[error("invalid utf-8 in str at offset {0}")]
    Utf8(usize),
    #[error("map key at offset {0} is not a string")]
    NonStringKey(usize),
    #[error("reserved/unsupported format byte 0x{0:02x} at offset {1}")]
    BadFormat(u8, usize),
    #[error("trailing garbage: {0} bytes after value")]
    Trailing(usize),
    #[error("nesting depth exceeds {0}")]
    TooDeep(usize),
    /// A typed streaming read ([`super::Reader`]) met a value of a different
    /// type: expected kind, offset.
    #[error("expected {0} at offset {1}")]
    Unexpected(&'static str, usize),
}

const MAX_DEPTH: usize = 64;

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Result<u8, DecodeError> {
        let b = *self.buf.get(self.pos).ok_or(DecodeError::Eof(self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        let remaining = self.buf.len() - self.pos;
        if n > remaining {
            return Err(DecodeError::LengthOverrun { offset: self.pos, len: n, remaining });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn be_u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn be_u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn be_u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self, len: usize) -> Result<String, DecodeError> {
        let off = self.pos;
        let bytes = self.take(len)?;
        std::str::from_utf8(bytes)
            .map(str::to_string)
            .map_err(|_| DecodeError::Utf8(off))
    }

    fn value(&mut self, depth: usize) -> Result<Value, DecodeError> {
        if depth > MAX_DEPTH {
            return Err(DecodeError::TooDeep(MAX_DEPTH));
        }
        let off = self.pos;
        let b = self.u8()?;
        Ok(match b {
            0x00..=0x7f => Value::Int(b as i64),
            0xe0..=0xff => Value::Int(b as i8 as i64),
            0x80..=0x8f => self.map_body((b & 0x0f) as usize, depth)?,
            0x90..=0x9f => self.array_body((b & 0x0f) as usize, depth)?,
            0xa0..=0xbf => Value::Str(self.str((b & 0x1f) as usize)?),
            0xc0 => Value::Nil,
            0xc1 => return Err(DecodeError::BadFormat(b, off)),
            0xc2 => Value::Bool(false),
            0xc3 => Value::Bool(true),
            0xc4 => {
                let n = self.u8()? as usize;
                Value::Bin(self.take(n)?.to_vec())
            }
            0xc5 => {
                let n = self.be_u16()? as usize;
                Value::Bin(self.take(n)?.to_vec())
            }
            0xc6 => {
                let n = self.be_u32()? as usize;
                Value::Bin(self.take(n)?.to_vec())
            }
            0xc7 => {
                let n = self.u8()? as usize;
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(n)?.to_vec())
            }
            0xc8 => {
                let n = self.be_u16()? as usize;
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(n)?.to_vec())
            }
            0xc9 => {
                let n = self.be_u32()? as usize;
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(n)?.to_vec())
            }
            0xca => Value::F32(f32::from_be_bytes(self.take(4)?.try_into().unwrap())),
            0xcb => Value::F64(f64::from_be_bytes(self.take(8)?.try_into().unwrap())),
            0xcc => Value::Int(self.u8()? as i64),
            0xcd => Value::Int(self.be_u16()? as i64),
            0xce => Value::Int(self.be_u32()? as i64),
            0xcf => {
                let u = self.be_u64()?;
                if u <= i64::MAX as u64 {
                    Value::Int(u as i64)
                } else {
                    Value::UInt(u)
                }
            }
            0xd0 => Value::Int(self.u8()? as i8 as i64),
            0xd1 => Value::Int(self.be_u16()? as i16 as i64),
            0xd2 => Value::Int(self.be_u32()? as i32 as i64),
            0xd3 => Value::Int(self.be_u64()? as i64),
            0xd4 => {
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(1)?.to_vec())
            }
            0xd5 => {
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(2)?.to_vec())
            }
            0xd6 => {
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(4)?.to_vec())
            }
            0xd7 => {
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(8)?.to_vec())
            }
            0xd8 => {
                let tag = self.u8()? as i8;
                Value::Ext(tag, self.take(16)?.to_vec())
            }
            0xd9 => {
                let n = self.u8()? as usize;
                Value::Str(self.str(n)?)
            }
            0xda => {
                let n = self.be_u16()? as usize;
                Value::Str(self.str(n)?)
            }
            0xdb => {
                let n = self.be_u32()? as usize;
                Value::Str(self.str(n)?)
            }
            0xdc => {
                let n = self.be_u16()? as usize;
                self.array_body(n, depth)?
            }
            0xdd => {
                let n = self.be_u32()? as usize;
                self.array_body(n, depth)?
            }
            0xde => {
                let n = self.be_u16()? as usize;
                self.map_body(n, depth)?
            }
            0xdf => {
                let n = self.be_u32()? as usize;
                self.map_body(n, depth)?
            }
        })
    }

    fn array_body(&mut self, n: usize, depth: usize) -> Result<Value, DecodeError> {
        // Each element is ≥1 byte; reject impossible counts before allocating.
        let remaining = self.buf.len() - self.pos;
        if n > remaining {
            return Err(DecodeError::LengthOverrun { offset: self.pos, len: n, remaining });
        }
        let mut v = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            v.push(self.value(depth + 1)?);
        }
        Ok(Value::Array(v))
    }

    fn map_body(&mut self, n: usize, depth: usize) -> Result<Value, DecodeError> {
        // Each entry is ≥2 bytes.
        let remaining = self.buf.len() - self.pos;
        if n > remaining / 2 {
            return Err(DecodeError::LengthOverrun { offset: self.pos, len: n, remaining });
        }
        let mut m = BTreeMap::new();
        for _ in 0..n {
            let key_off = self.pos;
            let k = match self.value(depth + 1)? {
                Value::Str(s) => s,
                _ => return Err(DecodeError::NonStringKey(key_off)),
            };
            let v = self.value(depth + 1)?;
            m.insert(k, v);
        }
        Ok(Value::Map(m))
    }
}

/// Decode exactly one value; trailing bytes are an error.
pub fn decode(buf: &[u8]) -> Result<Value, DecodeError> {
    let (v, consumed) = decode_prefix(buf)?;
    if consumed != buf.len() {
        return Err(DecodeError::Trailing(buf.len() - consumed));
    }
    Ok(v)
}

/// Decode one value from the front of `buf`, returning it and the number of
/// bytes consumed (for streaming multiple concatenated values).
pub fn decode_prefix(buf: &[u8]) -> Result<(Value, usize), DecodeError> {
    let mut r = Reader { buf, pos: 0 };
    let v = r.value(0)?;
    Ok((v, r.pos))
}
