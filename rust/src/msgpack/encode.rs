//! MessagePack encoder. Always emits the smallest format that represents the
//! value (canonical encoding), so `encode(decode(bytes))` is byte-identical
//! for canonically-encoded input.
//!
//! Scalar/str/bin/container-header emission delegates to the primitives in
//! [`super::stream`] — the same bytes the streaming [`super::Writer`]
//! produces, so the `Value` tree and the zero-copy codec can never drift.

use super::stream::{write_array_header, write_bin, write_map_header, write_str, write_uint};
use super::Value;

/// Encode a value into a fresh buffer.
pub fn encode(v: &Value) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.size_hint());
    encode_into(v, &mut out);
    out
}

/// Encode a value, appending to `out`. This is the hot-path entry: the
/// protocol layer reuses one buffer per connection.
pub fn encode_into(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::Nil => out.push(0xc0),
        Value::Bool(false) => out.push(0xc2),
        Value::Bool(true) => out.push(0xc3),
        Value::Int(i) => encode_int(*i, out),
        Value::UInt(u) => encode_uint(*u, out),
        Value::F32(f) => {
            out.push(0xca);
            out.extend_from_slice(&f.to_be_bytes());
        }
        Value::F64(f) => {
            out.push(0xcb);
            out.extend_from_slice(&f.to_be_bytes());
        }
        Value::Str(s) => write_str(out, s),
        Value::Bin(b) => write_bin(out, b),
        Value::Ext(tag, b) => {
            match b.len() {
                1 => out.push(0xd4),
                2 => out.push(0xd5),
                4 => out.push(0xd6),
                8 => out.push(0xd7),
                16 => out.push(0xd8),
                0..=255 => {
                    out.push(0xc7);
                    out.push(b.len() as u8);
                }
                256..=65535 => {
                    out.push(0xc8);
                    out.extend_from_slice(&(b.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xc9);
                    out.extend_from_slice(&(b.len() as u32).to_be_bytes());
                }
            }
            out.push(*tag as u8);
            out.extend_from_slice(b);
        }
        Value::Array(a) => {
            write_array_header(out, a.len());
            for v in a {
                encode_into(v, out);
            }
        }
        Value::Map(m) => {
            write_map_header(out, m.len());
            for (k, v) in m {
                write_str(out, k);
                encode_into(v, out);
            }
        }
    }
}

fn encode_int(i: i64, out: &mut Vec<u8>) {
    super::stream::write_int(out, i);
}

fn encode_uint(u: u64, out: &mut Vec<u8>) {
    write_uint(out, u);
}
