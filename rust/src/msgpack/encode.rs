//! MessagePack encoder. Always emits the smallest format that represents the
//! value (canonical encoding), so `encode(decode(bytes))` is byte-identical
//! for canonically-encoded input.

use super::Value;

/// Encode a value into a fresh buffer.
pub fn encode(v: &Value) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.size_hint());
    encode_into(v, &mut out);
    out
}

/// Encode a value, appending to `out`. This is the hot-path entry: the
/// protocol layer reuses one buffer per connection.
pub fn encode_into(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::Nil => out.push(0xc0),
        Value::Bool(false) => out.push(0xc2),
        Value::Bool(true) => out.push(0xc3),
        Value::Int(i) => encode_int(*i, out),
        Value::UInt(u) => encode_uint(*u, out),
        Value::F32(f) => {
            out.push(0xca);
            out.extend_from_slice(&f.to_be_bytes());
        }
        Value::F64(f) => {
            out.push(0xcb);
            out.extend_from_slice(&f.to_be_bytes());
        }
        Value::Str(s) => {
            let b = s.as_bytes();
            match b.len() {
                0..=31 => out.push(0xa0 | b.len() as u8),
                32..=255 => {
                    out.push(0xd9);
                    out.push(b.len() as u8);
                }
                256..=65535 => {
                    out.push(0xda);
                    out.extend_from_slice(&(b.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xdb);
                    out.extend_from_slice(&(b.len() as u32).to_be_bytes());
                }
            }
            out.extend_from_slice(b);
        }
        Value::Bin(b) => {
            match b.len() {
                0..=255 => {
                    out.push(0xc4);
                    out.push(b.len() as u8);
                }
                256..=65535 => {
                    out.push(0xc5);
                    out.extend_from_slice(&(b.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xc6);
                    out.extend_from_slice(&(b.len() as u32).to_be_bytes());
                }
            }
            out.extend_from_slice(b);
        }
        Value::Ext(tag, b) => {
            match b.len() {
                1 => out.push(0xd4),
                2 => out.push(0xd5),
                4 => out.push(0xd6),
                8 => out.push(0xd7),
                16 => out.push(0xd8),
                0..=255 => {
                    out.push(0xc7);
                    out.push(b.len() as u8);
                }
                256..=65535 => {
                    out.push(0xc8);
                    out.extend_from_slice(&(b.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xc9);
                    out.extend_from_slice(&(b.len() as u32).to_be_bytes());
                }
            }
            out.push(*tag as u8);
            out.extend_from_slice(b);
        }
        Value::Array(a) => {
            match a.len() {
                0..=15 => out.push(0x90 | a.len() as u8),
                16..=65535 => {
                    out.push(0xdc);
                    out.extend_from_slice(&(a.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xdd);
                    out.extend_from_slice(&(a.len() as u32).to_be_bytes());
                }
            }
            for v in a {
                encode_into(v, out);
            }
        }
        Value::Map(m) => {
            match m.len() {
                0..=15 => out.push(0x80 | m.len() as u8),
                16..=65535 => {
                    out.push(0xde);
                    out.extend_from_slice(&(m.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xdf);
                    out.extend_from_slice(&(m.len() as u32).to_be_bytes());
                }
            }
            for (k, v) in m {
                // Keys are strings; reuse the str path.
                encode_into(&Value::Str(k.clone()), out);
                encode_into(v, out);
            }
        }
    }
}

fn encode_int(i: i64, out: &mut Vec<u8>) {
    if i >= 0 {
        return encode_uint(i as u64, out);
    }
    if i >= -32 {
        out.push(i as u8); // negative fixint 0xe0..0xff
    } else if i >= i8::MIN as i64 {
        out.push(0xd0);
        out.push(i as i8 as u8);
    } else if i >= i16::MIN as i64 {
        out.push(0xd1);
        out.extend_from_slice(&(i as i16).to_be_bytes());
    } else if i >= i32::MIN as i64 {
        out.push(0xd2);
        out.extend_from_slice(&(i as i32).to_be_bytes());
    } else {
        out.push(0xd3);
        out.extend_from_slice(&i.to_be_bytes());
    }
}

fn encode_uint(u: u64, out: &mut Vec<u8>) {
    if u <= 0x7f {
        out.push(u as u8); // positive fixint
    } else if u <= u8::MAX as u64 {
        out.push(0xcc);
        out.push(u as u8);
    } else if u <= u16::MAX as u64 {
        out.push(0xcd);
        out.extend_from_slice(&(u as u16).to_be_bytes());
    } else if u <= u32::MAX as u64 {
        out.push(0xce);
        out.extend_from_slice(&(u as u32).to_be_bytes());
    } else {
        out.push(0xcf);
        out.extend_from_slice(&u.to_be_bytes());
    }
}
