//! Codec tests: spec-vector checks, roundtrips across all format boundaries,
//! canonical re-encoding, and randomized fuzz (decode never panics; valid
//! trees roundtrip).

use super::*;
use crate::util::Rng;
use std::collections::BTreeMap;

fn rt(v: Value) {
    let bytes = encode(&v);
    let back = decode(&bytes).unwrap_or_else(|e| panic!("decode failed for {v}: {e}"));
    assert_eq!(back, v, "roundtrip mismatch");
    // Canonical: re-encode is byte-identical.
    assert_eq!(encode(&back), bytes, "re-encode not canonical for {v}");
}

#[test]
fn spec_vectors() {
    // Hand-checked against the MessagePack spec.
    assert_eq!(encode(&Value::Nil), [0xc0]);
    assert_eq!(encode(&Value::Bool(true)), [0xc3]);
    assert_eq!(encode(&Value::Int(7)), [0x07]);
    assert_eq!(encode(&Value::Int(-1)), [0xff]);
    assert_eq!(encode(&Value::Int(-32)), [0xe0]);
    assert_eq!(encode(&Value::Int(-33)), [0xd0, 0xdf]);
    assert_eq!(encode(&Value::Int(128)), [0xcc, 0x80]);
    assert_eq!(encode(&Value::Int(65536)), [0xce, 0, 1, 0, 0]);
    assert_eq!(encode(&Value::str("abc")), [0xa3, b'a', b'b', b'c']);
    assert_eq!(
        encode(&Value::Array(vec![Value::Int(1), Value::Int(2)])),
        [0x92, 0x01, 0x02]
    );
    let m = Value::map(vec![("a", Value::Int(1))]);
    assert_eq!(encode(&m), [0x81, 0xa1, b'a', 0x01]);
    assert_eq!(encode(&Value::F64(1.0)), [0xcb, 0x3f, 0xf0, 0, 0, 0, 0, 0, 0]);
}

#[test]
fn int_boundaries_roundtrip() {
    for i in [
        0i64,
        1,
        127,
        128,
        255,
        256,
        65535,
        65536,
        u32::MAX as i64,
        u32::MAX as i64 + 1,
        i64::MAX,
        -1,
        -32,
        -33,
        -128,
        -129,
        -32768,
        -32769,
        i32::MIN as i64,
        i32::MIN as i64 - 1,
        i64::MIN,
    ] {
        rt(Value::Int(i));
    }
    rt(Value::UInt(u64::MAX));
    rt(Value::UInt(i64::MAX as u64 + 1));
}

#[test]
fn uint_normalization() {
    // u64 ≤ i64::MAX decodes to Int (canonical form).
    let bytes = encode(&Value::UInt(42));
    assert_eq!(decode(&bytes).unwrap(), Value::Int(42));
}

#[test]
fn str_length_boundaries() {
    for n in [0usize, 1, 31, 32, 255, 256, 65535, 65536] {
        rt(Value::Str("x".repeat(n)));
    }
}

#[test]
fn bin_length_boundaries() {
    for n in [0usize, 1, 255, 256, 65535, 65536] {
        rt(Value::Bin(vec![0xAB; n]));
    }
}

#[test]
fn array_and_map_length_boundaries() {
    for n in [0usize, 1, 15, 16, 65535, 65536] {
        rt(Value::Array(vec![Value::Int(0); n]));
    }
    for n in [0usize, 1, 15, 16, 70000] {
        let m: BTreeMap<String, Value> =
            (0..n).map(|i| (format!("k{i}"), Value::Int(i as i64))).collect();
        rt(Value::Map(m));
    }
}

#[test]
fn ext_roundtrip() {
    for n in [1usize, 2, 4, 8, 16, 3, 17, 255, 256, 65536] {
        rt(Value::Ext(-1, vec![0x5A; n]));
    }
    rt(Value::Ext(127, vec![]));
}

#[test]
fn floats_roundtrip() {
    rt(Value::F32(1.5));
    rt(Value::F64(std::f64::consts::PI));
    rt(Value::F64(f64::INFINITY));
    rt(Value::F64(-0.0));
    // NaN: compare bit patterns since NaN != NaN.
    let bytes = encode(&Value::F64(f64::NAN));
    match decode(&bytes).unwrap() {
        Value::F64(f) => assert!(f.is_nan()),
        v => panic!("expected F64 NaN, got {v}"),
    }
}

#[test]
fn nested_message_like_dask() {
    // Shape of a Dask-like "compute-task" message.
    let msg = Value::map(vec![
        ("op", Value::str("compute-task")),
        ("key", Value::str("merge-0-1234")),
        ("duration", Value::F64(0.006)),
        ("nbytes", Value::Int(27_648)),
        (
            "who_has",
            Value::map(vec![(
                "dep-0",
                Value::Array(vec![Value::str("tcp://10.0.0.1:9000")]),
            )]),
        ),
        ("payload", Value::Bin(vec![1, 2, 3, 4])),
        ("priority", Value::Array(vec![Value::Int(0), Value::Int(-3)])),
    ]);
    rt(msg);
}

#[test]
fn decode_errors() {
    // Truncated input.
    assert!(matches!(decode(&[0xcc]), Err(DecodeError::Eof(_)) | Err(DecodeError::LengthOverrun { .. })));
    // str16 declaring 1000 bytes with 2 present.
    assert!(matches!(
        decode(&[0xda, 0x03, 0xe8, b'a', b'b']),
        Err(DecodeError::LengthOverrun { .. })
    ));
    // bin32 declaring 4 GiB.
    assert!(matches!(
        decode(&[0xc6, 0xff, 0xff, 0xff, 0xff, 0x00]),
        Err(DecodeError::LengthOverrun { .. })
    ));
    // array32 declaring 1M elements on a short buffer.
    assert!(matches!(
        decode(&[0xdd, 0x00, 0x0f, 0x42, 0x40]),
        Err(DecodeError::LengthOverrun { .. })
    ));
    // reserved byte.
    assert!(matches!(decode(&[0xc1]), Err(DecodeError::BadFormat(0xc1, 0))));
    // trailing garbage.
    assert!(matches!(decode(&[0x01, 0x02]), Err(DecodeError::Trailing(1))));
    // non-string map key.
    assert!(matches!(
        decode(&[0x81, 0x01, 0x02]),
        Err(DecodeError::NonStringKey(1))
    ));
    // invalid utf-8 str.
    assert!(matches!(decode(&[0xa1, 0xff]), Err(DecodeError::Utf8(1))));
}

#[test]
fn deep_nesting_bounded() {
    // 100 nested arrays exceeds MAX_DEPTH=64 and must error, not overflow.
    let mut bytes = vec![0x91u8; 100];
    bytes.push(0xc0);
    assert!(matches!(decode(&bytes), Err(DecodeError::TooDeep(_))));
}

#[test]
fn decode_prefix_streams() {
    let mut buf = encode(&Value::Int(1));
    buf.extend(encode(&Value::str("two")));
    let (v1, n1) = decode_prefix(&buf).unwrap();
    assert_eq!(v1, Value::Int(1));
    let (v2, n2) = decode_prefix(&buf[n1..]).unwrap();
    assert_eq!(v2, Value::str("two"));
    assert_eq!(n1 + n2, buf.len());
}

fn random_value(rng: &mut Rng, depth: usize) -> Value {
    let max_kind = if depth >= 3 { 7 } else { 10 };
    match rng.gen_range(max_kind) {
        0 => Value::Nil,
        1 => Value::Bool(rng.chance(0.5)),
        2 => Value::Int(rng.next_u64() as i64),
        3 => Value::UInt(rng.next_u64() | (1 << 63)),
        4 => Value::F64(rng.range_f64(-1e12, 1e12)),
        5 => {
            let n = rng.range_usize(0, 40);
            Value::Str((0..n).map(|_| (b'a' + rng.gen_range(26) as u8) as char).collect())
        }
        6 => {
            let n = rng.range_usize(0, 300);
            Value::Bin((0..n).map(|_| rng.next_u64() as u8).collect())
        }
        7 => Value::F32(rng.range_f64(-1e6, 1e6) as f32),
        8 => {
            let n = rng.range_usize(0, 8);
            Value::Array((0..n).map(|_| random_value(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.range_usize(0, 8);
            Value::Map(
                (0..n)
                    .map(|i| (format!("key{i}"), random_value(rng, depth + 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn fuzz_roundtrip_random_trees() {
    let mut rng = Rng::new(2020);
    for _ in 0..500 {
        rt(random_value(&mut rng, 0));
    }
}

#[test]
fn fuzz_decode_random_bytes_never_panics() {
    let mut rng = Rng::new(4040);
    for _ in 0..2000 {
        let n = rng.range_usize(0, 64);
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = decode(&bytes); // must not panic; error is fine
    }
}

#[test]
fn fuzz_truncation_of_valid_messages_errors_cleanly() {
    let mut rng = Rng::new(6060);
    for _ in 0..200 {
        let v = random_value(&mut rng, 0);
        let bytes = encode(&v);
        if bytes.len() < 2 {
            continue;
        }
        let cut = rng.range_usize(1, bytes.len());
        // Truncated prefix must either decode to a smaller valid value
        // (when the tree's first element fits) or produce an error — never panic.
        let _ = decode(&bytes[..cut]);
    }
}
