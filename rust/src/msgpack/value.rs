//! Owned MessagePack value tree with convenience accessors used by the
//! protocol layer.

use std::collections::BTreeMap;
use std::fmt;

/// An owned MessagePack value.
///
/// Map keys are restricted to strings (a `BTreeMap<String, Value>`): every
/// message in the Dask protocol is a string-keyed dictionary, and ordered
/// keys make encoding deterministic (byte-identical re-encodes, which the
/// tests rely on).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Nil,
    Bool(bool),
    /// Signed integer. Encoded as the smallest signed/unsigned format that
    /// fits; decodes of unsigned values ≤ i64::MAX normalize here.
    Int(i64),
    /// Unsigned integer that does not fit in `Int` (> i64::MAX).
    UInt(u64),
    F32(f32),
    F64(f64),
    Str(String),
    Bin(Vec<u8>),
    Array(Vec<Value>),
    Map(BTreeMap<String, Value>),
    /// MessagePack ext type: (type tag, payload). Parsed and re-encoded
    /// verbatim; the Dask protocol uses ext for e.g. timestamps.
    Ext(i8, Vec<u8>),
}

impl Value {
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn map(entries: Vec<(&str, Value)>) -> Value {
        Value::Map(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::UInt(u) if *u <= i64::MAX as u64 => Some(*u as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            Value::UInt(u) => Some(*u),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F32(f) => Some(*f as f64),
            Value::F64(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            Value::UInt(u) => Some(*u as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_bin(&self) -> Option<&[u8]> {
        match self {
            Value::Bin(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Map(m) => Some(m),
            _ => None,
        }
    }

    /// Map field lookup: `v.get("op")`.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_map().and_then(|m| m.get(key))
    }

    /// Approximate encoded size in bytes (upper bound within a few bytes per
    /// element); used for backpressure accounting without encoding.
    pub fn size_hint(&self) -> usize {
        match self {
            Value::Nil | Value::Bool(_) => 1,
            Value::Int(_) | Value::UInt(_) => 9,
            Value::F32(_) => 5,
            Value::F64(_) => 9,
            Value::Str(s) => 5 + s.len(),
            Value::Bin(b) => 5 + b.len(),
            Value::Ext(_, b) => 6 + b.len(),
            Value::Array(a) => 5 + a.iter().map(Value::size_hint).sum::<usize>(),
            Value::Map(m) => {
                5 + m
                    .iter()
                    .map(|(k, v)| 5 + k.len() + v.size_hint())
                    .sum::<usize>()
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Nil => write!(f, "nil"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::UInt(u) => write!(f, "{u}"),
            Value::F32(x) => write!(f, "{x}"),
            Value::F64(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Bin(b) => write!(f, "<bin {} bytes>", b.len()),
            Value::Ext(t, b) => write!(f, "<ext {t} {} bytes>", b.len()),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Map(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k:?}: {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}
impl From<u64> for Value {
    fn from(u: u64) -> Self {
        if u <= i64::MAX as u64 {
            Value::Int(u as i64)
        } else {
            Value::UInt(u)
        }
    }
}
impl From<u32> for Value {
    fn from(u: u32) -> Self {
        Value::Int(u as i64)
    }
}
impl From<usize> for Value {
    fn from(u: usize) -> Self {
        Value::from(u as u64)
    }
}
impl From<f64> for Value {
    fn from(f: f64) -> Self {
        Value::F64(f)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<Vec<u8>> for Value {
    fn from(b: Vec<u8>) -> Self {
        Value::Bin(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(a: Vec<Value>) -> Self {
        Value::Array(a)
    }
}
