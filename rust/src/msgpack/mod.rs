//! MessagePack serialization — the Dask wire format (paper §IV-B).
//!
//! Dask's protocol is MessagePack-encoded message dictionaries; the paper's
//! RSDS speaks the same format from Rust ("DASK uses a custom
//! language-agnostic communication protocol serialized by MessagePack").
//! This module is a complete, dependency-free implementation of the
//! MessagePack spec (format family: nil, bool, int/uint, f32/f64, str, bin,
//! array, map — ext is parsed and preserved), built around an owned
//! [`Value`] tree.
//!
//! The codec is on the server's hot path (every task assignment and every
//! status update crosses it), so two layers are exposed:
//!
//! - [`Value`] + [`decode`]/[`encode`]: the owned tree, used for the
//!   structurally dynamic cold path (`submit-graph`, registration) and as
//!   the byte-identical reference codec in tests;
//! - [`Reader`]/[`Writer`] (`stream.rs`): a zero-copy pull-parser and a
//!   direct-to-buffer emitter for the per-task hot path — no `BTreeMap`, no
//!   field-name `String`s, no allocation at all.

mod decode;
mod encode;
mod stream;
mod value;

pub use decode::{decode, decode_prefix, DecodeError};
pub use encode::{encode, encode_into};
pub use stream::{Reader, Writer};
pub use value::Value;

#[cfg(test)]
mod tests;
