//! MessagePack serialization — the Dask wire format (paper §IV-B).
//!
//! Dask's protocol is MessagePack-encoded message dictionaries; the paper's
//! RSDS speaks the same format from Rust ("DASK uses a custom
//! language-agnostic communication protocol serialized by MessagePack").
//! This module is a complete, dependency-free implementation of the
//! MessagePack spec (format family: nil, bool, int/uint, f32/f64, str, bin,
//! array, map — ext is parsed and preserved), built around an owned
//! [`Value`] tree.
//!
//! The codec is on the server's hot path (every task assignment and every
//! status update crosses it), so the decoder is written against a flat byte
//! slice with explicit bounds checks and no intermediate allocation beyond
//! the output tree, and the encoder writes into a caller-owned `Vec<u8>`.

mod decode;
mod encode;
mod value;

pub use decode::{decode, decode_prefix, DecodeError};
pub use encode::{encode, encode_into};
pub use value::Value;

#[cfg(test)]
mod tests;
